#!/usr/bin/env bash
# Repo smoke: the tier-1 correctness gate, the public-API examples, the
# commit-latency record and the commit-path perf gate.
#
#   scripts/smoke.sh            # tests + examples + quick commit bench
#   scripts/smoke.sh --no-bench # tests + examples only
#
# The examples exercise the `Pool` facade end to end (quickstart runs in
# full; the other three run their --smoke pass), so any API drift in the
# public surface fails CI before it reaches a user.  The quick bench
# writes BENCH_commit.fresh.json; scripts/bench_gate.py diffs it against
# the committed BENCH_commit.json baseline (noise-aware wall tolerance,
# tight deterministic-bytes tolerance, the deferred W=16-below-W=1
# structural invariant, and the facade-adds-no-bytes invariant).  Only
# when the gate passes is the fresh record promoted to BENCH_commit.json,
# so a PR diff shows commit-path perf movement alongside test status.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== examples: Pool facade (quickstart + --smoke passes) =="
python examples/quickstart.py
python examples/serve_protected.py --smoke
python examples/train_fault_tolerant.py --smoke
# one r=3 cell: triple-loss survival through the Reed-Solomon stack
python examples/train_fault_tolerant.py --smoke --redundancy 3
python examples/elastic_rescale.py --smoke
# one short chaos scenario: mid-window scribble+loss under traffic,
# recovered online, end state bit-identical to the fault-free run —
# traced, and the trace re-validated offline (every fault span linked)
TRACE_DIR="$(mktemp -d)"
python -m repro.chaos --smoke --trace-dir "$TRACE_DIR"
python scripts/trace_check.py --dir "$TRACE_DIR"
rm -rf "$TRACE_DIR"

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== perf: commit latency + recovery + chaos + obs + tenancy + async (quick) =="
    python -m benchmarks.run --quick \
        --only txn_latency,commit_sweep,deferred,recovery,roofline,chaos,obs_overhead,tenancy,async_pipeline \
        --commit-json BENCH_commit.fresh.json
    echo "== perf: bench gate =="
    python scripts/bench_gate.py
    mv BENCH_commit.fresh.json BENCH_commit.json
fi

echo "smoke OK"
