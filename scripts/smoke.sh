#!/usr/bin/env bash
# Repo smoke: the tier-1 correctness gate plus the commit-latency record.
#
#   scripts/smoke.sh            # full tier-1 suite + quick commit bench
#   scripts/smoke.sh --no-bench # tests only
#
# Leaves BENCH_commit.json at the repo root (see benchmarks/run.py) so a
# PR diff shows commit-path perf movement alongside test status.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== perf: commit latency (quick) =="
    python -m benchmarks.run --quick --only txn_latency,commit_sweep
fi

echo "smoke OK"
