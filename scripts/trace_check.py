#!/usr/bin/env python
"""Offline validator for chaos/pool JSONL span traces.

Checks every trace file against the well-formedness rules in
repro/obs/trace.py (`validate_events` is the single source of truth):

  * every span begin has exactly one matching end (no dangling spans —
    a crashed recovery would leave one, which is exactly the signal);
  * every fault event id is referenced by >= 1 resolving span (a
    recovery, or a scrub whose repair fixed the damage) — no fault is
    silently forgotten;
  * no span references an unknown fault id (no orphan links).

Rotated traces (obs.Tracer rotate_lines/rotate_bytes) write numbered
segments `<stem>-0001.jsonl`, `<stem>-0002.jsonl`, …; a span may begin
in one segment and end in the next, so the segments of one family are
concatenated (in index order) and validated as ONE logical event
stream.  Unrotated files are validated individually, as before.

With `--prom METRICS.prom`, the OpenMetrics exemplar suffixes the
exporter attaches to histogram buckets (` # {span_id="N"} value`) are
cross-checked against the traces: every exemplar's span id must exist
as an event id in the trace stream, so a p99 commit sample in the
metrics surface always links back to a real dispatch span — a dangling
exemplar means the metrics and trace planes disagree about what ran.

Usage:
    python scripts/trace_check.py TRACE.jsonl [...]
    python scripts/trace_check.py --dir TRACE_DIR    # every *.jsonl
    python scripts/trace_check.py --dir TRACE_DIR --prom METRICS.prom

Exit 0 = every trace valid; exit 1 = violations (printed per file).
This module is jax-free (repro.obs imports no jax), so it runs anywhere
python does — a monitoring host does not need the accelerator stack.
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.trace import load_jsonl, validate_events  # noqa: E402

_SEGMENT = re.compile(r"^(?P<stem>.+)-(?P<idx>\d{4})(?P<ext>\.jsonl)$")
_EXEMPLAR = re.compile(r'#\s*\{span_id="(?P<id>[^"]+)"\}')


def group_segments(paths: list) -> list:
    """Group rotated-segment paths into families.

    Returns [(display_name, [paths...])]: segments sharing a stem become
    one family sorted by index; everything else stays a singleton.
    Order follows first appearance in `paths`.
    """
    families: dict = {}
    order: list = []
    for path in paths:
        m = _SEGMENT.match(os.path.basename(path))
        key = (os.path.join(os.path.dirname(path),
                            m.group("stem") + m.group("ext"))
               if m else path)
        if key not in families:
            families[key] = []
            order.append(key)
        families[key].append(path)
    out = []
    for key in order:
        segs = sorted(families[key])
        name = key if len(segs) == 1 and segs[0] == key else (
            f"{key} [{len(segs)} segment(s)]")
        out.append((name, segs))
    return out


def check_files(paths: list) -> list:
    events = []
    for path in paths:
        try:
            events += load_jsonl(path)
        except Exception as e:  # malformed JSON is a violation, not a crash
            return [f"unreadable {path}: {e}"]
    if not events:
        return ["empty trace"]
    return validate_events(events)


def check_file(path: str) -> list:
    return check_files([path])


def check_exemplars(prom_path: str, trace_paths: list) -> list:
    """Cross-check exporter exemplars against the trace id space.

    Every ` # {span_id="N"}` suffix in the .prom text must name an id
    that exists as a trace event id; returns violations (empty = ok).
    A .prom with zero exemplar suffixes is itself a violation when this
    check was requested — it means the p99 sample lost its span link.
    """
    try:
        with open(prom_path) as f:
            text = f.read()
    except OSError as e:
        return [f"unreadable {prom_path}: {e}"]
    span_ids = [m.group("id") for m in _EXEMPLAR.finditer(text)]
    if not span_ids:
        return [f"{prom_path}: no exemplar suffixes found"]
    known = set()
    for path in trace_paths:
        try:
            for e in load_jsonl(path):
                if e.get("id") is not None:
                    known.add(str(e["id"]))
        except Exception as e:
            return [f"unreadable {path}: {e}"]
    bad = []
    for sid in span_ids:
        if sid not in known:
            bad.append(f"exemplar span_id={sid!r} matches no trace event")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="trace_check")
    ap.add_argument("paths", nargs="*", help="trace .jsonl files")
    ap.add_argument("--dir", default=None,
                    help="validate every *.jsonl under this directory")
    ap.add_argument("--prom", default=None,
                    help="also cross-check this OpenMetrics text file's "
                         "exemplar span ids against the trace event ids")
    args = ap.parse_args(argv)

    paths = list(args.paths)
    if args.dir:
        paths += sorted(glob.glob(os.path.join(args.dir, "*.jsonl")))
    if not paths:
        ap.error("no trace files given (pass paths or --dir)")

    rc = 0
    for name, segs in group_segments(paths):
        violations = check_files(segs)
        n = sum(len(load_jsonl(p)) for p in segs if os.path.exists(p))
        if violations:
            rc = 1
            print(f"FAIL {name} ({n} events)")
            for v in violations:
                print(f"  - {v}")
        else:
            print(f"ok   {name} ({n} events)")
    if args.prom:
        violations = check_exemplars(args.prom, paths)
        if violations:
            rc = 1
            print(f"FAIL {args.prom} (exemplar linkage)")
            for v in violations:
                print(f"  - {v}")
        else:
            print(f"ok   {args.prom} (exemplar linkage)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
