#!/usr/bin/env python
"""Offline validator for chaos/pool JSONL span traces.

Checks every trace file against the well-formedness rules in
repro/obs/trace.py (`validate_events` is the single source of truth):

  * every span begin has exactly one matching end (no dangling spans —
    a crashed recovery would leave one, which is exactly the signal);
  * every fault event id is referenced by >= 1 resolving span (a
    recovery, or a scrub whose repair fixed the damage) — no fault is
    silently forgotten;
  * no span references an unknown fault id (no orphan links).

Usage:
    python scripts/trace_check.py TRACE.jsonl [...]
    python scripts/trace_check.py --dir TRACE_DIR    # every *.jsonl

Exit 0 = every trace valid; exit 1 = violations (printed per file).
This module is jax-free (repro.obs imports no jax), so it runs anywhere
python does — a monitoring host does not need the accelerator stack.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.trace import load_jsonl, validate_events  # noqa: E402


def check_file(path: str) -> list:
    try:
        events = load_jsonl(path)
    except Exception as e:  # malformed JSON is a violation, not a crash
        return [f"unreadable: {e}"]
    if not events:
        return ["empty trace"]
    return validate_events(events)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="trace_check")
    ap.add_argument("paths", nargs="*", help="trace .jsonl files")
    ap.add_argument("--dir", default=None,
                    help="validate every *.jsonl under this directory")
    args = ap.parse_args(argv)

    paths = list(args.paths)
    if args.dir:
        paths += sorted(glob.glob(os.path.join(args.dir, "*.jsonl")))
    if not paths:
        ap.error("no trace files given (pass paths or --dir)")

    rc = 0
    for path in paths:
        violations = check_file(path)
        n = len(load_jsonl(path)) if os.path.exists(path) else 0
        if violations:
            rc = 1
            print(f"FAIL {path} ({n} events)")
            for v in violations:
                print(f"  - {v}")
        else:
            print(f"ok   {path} ({n} events)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
