#!/usr/bin/env python
"""Commit-path perf gate: diff a fresh BENCH_commit.json against the
committed baseline and fail on regression.

Two classes of signal, gated differently:

  * compiled "bytes accessed" cells are deterministic, so they gate
    tightly (--bytes-tol, default 0.02 = 2% compiler drift) AND the
    deferred section must keep its structural invariant: W=16 amortized
    bytes per step strictly below the W=1 synchronous engine for every
    (size, mode) — the acceptance property that must never regress.
    These are the perf gate.
  * wall-clock cells (overwrite_us, deferred wall_us_per_step) swing
    with ambient load far beyond any useful tolerance between runs
    (EXPERIMENTS.md §Perf measured >10x on this box; its standing rule
    is "never compare two separate runs"), so by default they only trip
    a pathology catch-all (--wall-tol 9.0 = fail past 10x — a hang or
    accidental O(n) blowup, not a perf comparison).  Tighten --wall-tol
    on a quiet, pinned box if wall gating is wanted.

Usage:  python scripts/bench_gate.py [--fresh PATH] [--baseline PATH]
Exit 0 = no regression; exit 1 = regression (each violation printed).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _index(rows, keys):
    out = {}
    for r in rows:
        out[tuple(r[k] for k in keys)] = r
    return out


def check(fresh: dict, base: dict, wall_tol: float,
          bytes_tol: float, obs_wall_pct: float = 10.0) -> list:
    bad = []

    # -- wall: overwrite ladder ------------------------------------------------
    for size, modes in fresh.get("overwrite_us", {}).items():
        for mode, us in modes.items():
            ref = base.get("overwrite_us", {}).get(size, {}).get(mode)
            if ref and us > ref * (1 + wall_tol):
                bad.append(f"overwrite_us[{size}][{mode}]: {us} vs "
                           f"baseline {ref} (> {1 + wall_tol:.1f}x)")

    # -- bytes: fused A/B ------------------------------------------------------
    fab = _index(fresh.get("ab_interleaved", []),
                 ("size_B", "mode", "scenario"))
    bab = _index(base.get("ab_interleaved", []),
                 ("size_B", "mode", "scenario"))
    for key, row in fab.items():
        ref = bab.get(key)
        if ref and row["fused_MB"] > ref["fused_MB"] * (1 + bytes_tol):
            bad.append(f"ab_interleaved{key}: fused_MB {row['fused_MB']} "
                       f"vs baseline {ref['fused_MB']}")

    # -- deferred section ------------------------------------------------------
    fd = _index(fresh.get("deferred", []), ("size_B", "mode", "window"))
    bd = _index(base.get("deferred", []), ("size_B", "mode", "window"))
    for key, row in fd.items():
        ref = bd.get(key)
        if ref and (row["bytes_per_step_MB"]
                    > ref["bytes_per_step_MB"] * (1 + bytes_tol)):
            bad.append(f"deferred{key}: bytes_per_step_MB "
                       f"{row['bytes_per_step_MB']} vs baseline "
                       f"{ref['bytes_per_step_MB']}")
        if ref and (row["wall_us_per_step"]
                    > ref["wall_us_per_step"] * (1 + wall_tol)):
            bad.append(f"deferred{key}: wall_us_per_step "
                       f"{row['wall_us_per_step']} vs baseline "
                       f"{ref['wall_us_per_step']} (> {1 + wall_tol:.1f}x)")
    # structural invariant: deferred W=16 strictly under synchronous W=1
    for (size, mode, w), row in fd.items():
        if w == 16:
            sync = fd.get((size, mode, 1))
            if sync and not (row["bytes_per_step_MB"]
                             < sync["bytes_per_step_MB"]):
                bad.append(
                    f"deferred[{size},{mode}]: W=16 bytes/step "
                    f"{row['bytes_per_step_MB']} not below W=1 "
                    f"{sync['bytes_per_step_MB']} — deferral win lost")

    # -- facade section --------------------------------------------------------
    # structural invariant: the Pool facade routes commits to the SAME
    # compiled program as direct engine use, so its bytes may never
    # exceed the direct engine's (tol covers rounding only)
    ff = _index(fresh.get("facade", []), ("size_B", "mode"))
    if base.get("facade") and not ff:
        bad.append("facade: record missing from fresh run (facade-vs-"
                   "direct bytes no longer measured)")
    for key, row in ff.items():
        if row["facade_MB"] > row["direct_MB"] * (1 + bytes_tol):
            bad.append(f"facade{key}: facade_MB {row['facade_MB']} vs "
                       f"direct_MB {row['direct_MB']} — the Pool facade "
                       "added compiled bytes over the direct engine")

    # -- dual-parity recovery section ------------------------------------------
    fr = _index(fresh.get("recovery", {}).get("double_loss", []),
                ("state_B",))
    br = _index(base.get("recovery", {}).get("double_loss", []),
                ("state_B",))
    if br and not fr:
        bad.append("recovery.double_loss: record missing from fresh run "
                   "(double-loss reconstruction no longer measured)")
    for key, row in fr.items():
        # structural: Q storage tax must stay <= 2x P (it is exactly 1x
        # by construction — one seg_words row per syndrome); exactness
        # is asserted inside the benchmark itself
        if row["q_over_p"] > 2.0:
            bad.append(f"recovery.double_loss{key}: q_over_p "
                       f"{row['q_over_p']} > 2.0 — Q storage blew past "
                       "the dual-parity budget")
        ref = br.get(key)
        # wall: pathology catch-all only (same rule as the other walls)
        if ref and (row["double_recover_ms"]
                    > ref["double_recover_ms"] * (1 + wall_tol)):
            bad.append(f"recovery.double_loss{key}: double_recover_ms "
                       f"{row['double_recover_ms']} vs baseline "
                       f"{ref['double_recover_ms']} (> {1 + wall_tol:.1f}x)")

    # -- §roofline: streamed-vs-flat commit sweep ------------------------------
    fro = _index(fresh.get("roofline", []), ("size_B", "path"))
    bro = _index(base.get("roofline", []), ("size_B", "path"))
    if bro and not fro:
        bad.append("roofline: record missing from fresh run (the streamed"
                   "-vs-flat commit sweep is no longer measured)")
    if fro:
        for size in {k[0] for k in fro}:
            flat, stream = fro.get((size, "flat")), fro.get((size, "stream"))
            if flat is None or stream is None:
                bad.append(f"roofline[{size}]: needs both a flat and a "
                           "stream row (one path missing)")
                continue
            # deterministic + structural: one streamed dispatch must
            # touch fewer compiled bytes than the flat cadence it
            # replaced (it saves the delta-row round trip)
            if stream["xla_MB"] > flat["xla_MB"] * (1 + bytes_tol):
                bad.append(f"roofline[{size}]: stream xla_MB "
                           f"{stream['xla_MB']} not below flat "
                           f"{flat['xla_MB']} — the streamed pipeline "
                           "re-reads the row")
            # acceptance: streamed bandwidth-efficiency fraction (useful
            # bytes over compiled bytes accessed — the deterministic
            # form of the bytes/s fraction; same useful numerator, so
            # this is exactly "stream moves fewer bytes per committed
            # row") strictly above the flat baseline at the 1 MB pool
            if size == 1024 * 1024 and not (stream["useful_frac"]
                                            > flat["useful_frac"]):
                bad.append(f"roofline[{size}]: stream useful_frac "
                           f"{stream['useful_frac']} not above flat "
                           f"{flat['useful_frac']} — the streamed sweep "
                           "lost its bandwidth win")
    for key, row in fro.items():
        ref = bro.get(key)
        if ref and row["xla_MB"] > ref["xla_MB"] * (1 + bytes_tol):
            bad.append(f"roofline{key}: xla_MB {row['xla_MB']} vs "
                       f"baseline {ref['xla_MB']}")
        # wall: pathology catch-all only (same rule as the other walls)
        if ref and row["wall_us"] > ref["wall_us"] * (1 + wall_tol):
            bad.append(f"roofline{key}: wall_us {row['wall_us']} vs "
                       f"baseline {ref['wall_us']} (> {1 + wall_tol:.1f}x)")

    # -- §chaos: scripted fault scenarios under live traffic -------------------
    fc = _index(fresh.get("chaos", []), ("scenario",))
    bc = _index(base.get("chaos", []), ("scenario",))
    if bc and not fc:
        bad.append("chaos: record missing from fresh run (the chaos "
                   "campaign is no longer measured)")
    if fc:
        required = {"rescale_under_traffic", "straggler",
                    "midwindow_scribble_loss", "budget_exhaust_rearm"}
        missing = required - {k[0] for k in fc}
        if missing:
            bad.append(f"chaos: core scenarios missing from fresh run: "
                       f"{sorted(missing)}")
    for key, row in fc.items():
        # structural: every scenario must end bit-identical to its
        # fault-free golden run — chaos may cost latency, never bytes
        if not row.get("golden_exact"):
            bad.append(f"chaos{key}: golden_exact is false — the "
                       "recovered end state drifted from the fault-free "
                       "run")
        ref = bc.get(key)
        # wall: during-disturbance tail gates as pathology catch-all
        # (a recovery stalling traffic past wall_tol x the captured
        # baseline is a hang, not noise)
        for cell in ("during_p99_ms", "recovery_p99_ms"):
            val, refv = row.get(cell), ref.get(cell) if ref else None
            if val and refv and val > refv * (1 + wall_tol):
                bad.append(f"chaos{key}: {cell} {val} vs baseline "
                           f"{refv} (> {1 + wall_tol:.1f}x)")

    # -- §obs: telemetry-plane instrumented-vs-bare A/B ------------------------
    fo, bo = fresh.get("obs", {}), base.get("obs", {})
    if bo and not fo:
        bad.append("obs: record missing from fresh run (the telemetry "
                   "zero-overhead A/B is no longer measured)")
    for row in fo.get("bytes", []):
        # structural: an instrumented pool must compile the SAME program
        # as a bare engine — publication is host-side, so the compiled
        # byte delta is exactly zero, not merely small
        if row.get("byte_delta") != 0:
            bad.append(f"obs.bytes[{row.get('engine')}]: byte_delta "
                       f"{row.get('byte_delta')} != 0 — telemetry "
                       "leaked into the compiled commit program")
    if fo.get("wall"):
        # wall: pathology bound, not a microbenchmark — the A/B is
        # interleaved min-of-batches on the SAME run, but the in-suite
        # dispatch wall rides the device queue and the arms swing ~8%
        # run-to-run regardless; the bound only has to catch telemetry
        # becoming real work (a device fetch per commit costs 40%+).
        # The tight zero-overhead cell is byte_delta == 0 above.
        pct = fo["wall"].get("overhead_pct", 0.0)
        if pct > obs_wall_pct:
            bad.append(f"obs.wall: overhead_pct {pct} > "
                       f"{obs_wall_pct} — commit-path telemetry became "
                       "a measurable fraction of dispatch wall")

    # -- §rs: generalized Reed-Solomon sweep -----------------------------------
    frs = _index(fresh.get("rs", []), ("r",))
    brs = _index(base.get("rs", []), ("r",))
    if brs and not frs:
        bad.append("rs: record missing from fresh run (the r-sweep is no "
                   "longer measured)")
    for key, row in frs.items():
        # structural: the stack's storage tax is exactly r parity rows —
        # anything above r means a syndrome buffer grew beyond one
        # seg_words row per rank
        if row["syndrome_r_over_p"] > row["r"] + 1e-9:
            bad.append(f"rs{key}: syndrome_r_over_p "
                       f"{row['syndrome_r_over_p']} > r={row['r']} — the "
                       "stack blew past its r-parity-rows budget")
        ref = brs.get(key)
        # wall: pathology catch-all only (same rule as the other walls)
        if ref and (row["recover_ms"]
                    > ref["recover_ms"] * (1 + wall_tol)):
            bad.append(f"rs{key}: recover_ms {row['recover_ms']} vs "
                       f"baseline {ref['recover_ms']} "
                       f"(> {1 + wall_tol:.1f}x)")

    # -- §tenancy: multi-tenant PoolGroup A/B ----------------------------------
    ften, bten = fresh.get("tenancy", {}), base.get("tenancy", {})
    if bten and not ften:
        bad.append("tenancy: record missing from fresh run (the "
                   "multi-tenant batched-vs-looped A/B is no longer "
                   "measured)")
    ftr = _index(ften.get("throughput", []), ("n_tenants",))
    btr = _index(bten.get("throughput", []), ("n_tenants",))
    for key, row in ftr.items():
        # structural: at N >= 8 the batched stacked program (ONE
        # dispatch per cohort wave) must move at least the aggregate
        # commits/s of the N-dispatch loop it replaces — the two sides
        # interleave rep-by-rep in the SAME run over the SAME group
        # (shared protector + programs), so ambient load cancels and
        # the ordering is the dispatch-amortization claim itself
        if key[0] >= 8 and not (row["batched_commits_per_s"]
                                >= row["looped_commits_per_s"]):
            bad.append(f"tenancy.throughput{key}: batched "
                       f"{row['batched_commits_per_s']:.0f} commits/s "
                       f"below looped {row['looped_commits_per_s']:.0f} "
                       "— the stacked program lost to N dispatches")
        ref = btr.get(key)
        # wall: pathology catch-all only (same rule as the other walls)
        if ref and row["batched_ms"] > ref["batched_ms"] * (1 + wall_tol):
            bad.append(f"tenancy.throughput{key}: batched_ms "
                       f"{row['batched_ms']} vs baseline "
                       f"{ref['batched_ms']} (> {1 + wall_tol:.1f}x)")
    # -- §async: commit-ring depth sweep ---------------------------------------
    fas = _index(fresh.get("async", {}).get("depths", []), ("depth",))
    bas = _index(base.get("async", {}).get("depths", []), ("depth",))
    if bas and not fas:
        bad.append("async: record missing from fresh run (the commit-"
                   "ring depth sweep is no longer measured)")
    if fas:
        d1 = fas.get((1,))
        deep = [r for (d,), r in fas.items() if d >= 4]
        if d1 is None or not deep:
            bad.append("async: the depth sweep needs a depth=1 row and "
                       "at least one depth>=4 row")
        else:
            # structural: the ring must pay for itself — the best
            # depth >= 4 configuration's aggregate commits/s at least
            # the resolve-per-commit baseline's.  The depths interleave
            # rep-by-rep in the SAME run over the SAME Protector
            # (shared compiled commit program), so ambient load cancels
            # and the ordering is the pipelining claim itself.
            best = max(r["commits_per_s"] for r in deep)
            if not best >= d1["commits_per_s"]:
                bad.append(
                    f"async: best depth>=4 throughput {best:.0f} "
                    f"commits/s below depth=1 "
                    f"{d1['commits_per_s']:.0f} — the commit ring "
                    "lost to resolve-per-commit")
    for key, row in fas.items():
        ref = bas.get(key)
        # wall: resolve-latency tail gates as pathology catch-all only
        # (the ring trades per-commit resolve latency for throughput
        # by design; only a hang-class blowup should trip)
        if (ref and row.get("resolve_p99_ms") and ref.get("resolve_p99_ms")
                and row["resolve_p99_ms"]
                > ref["resolve_p99_ms"] * (1 + wall_tol)):
            bad.append(f"async{key}: resolve_p99_ms "
                       f"{row['resolve_p99_ms']} vs baseline "
                       f"{ref['resolve_p99_ms']} (> {1 + wall_tol:.1f}x)")
        if ref and row["wall_ms"] > ref["wall_ms"] * (1 + wall_tol):
            bad.append(f"async{key}: wall_ms {row['wall_ms']} vs "
                       f"baseline {ref['wall_ms']} "
                       f"(> {1 + wall_tol:.1f}x)")

    fint = ften.get("interference")
    if fint:
        # wall: the scrub storm on one tenant may cost scrub time,
        # never neighbor commit tails — interleaved waves in one run,
        # but p99-of-p99 is still noisy, so it gates as pathology
        if fint["p99_ratio"] > 1 + wall_tol:
            bad.append(f"tenancy.interference: storm p99 "
                       f"{fint['storm_p99_ms']} vs base "
                       f"{fint['base_p99_ms']} (ratio "
                       f"{fint['p99_ratio']:.2f} > {1 + wall_tol:.1f}) "
                       "— the shared scrub scheduler is stalling "
                       "neighbor commits")
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh",
                    default=os.path.join(REPO, "BENCH_commit.fresh.json"))
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "BENCH_commit.json"))
    ap.add_argument("--wall-tol", type=float, default=9.0,
                    help="wall cells fail past (1+tol)x baseline "
                         "(pathology catch-all; see module docstring)")
    ap.add_argument("--bytes-tol", type=float, default=0.02,
                    help="deterministic byte cells fail past (1+tol)x")
    ap.add_argument("--obs-wall-pct", type=float, default=10.0,
                    help="§obs commit-dispatch overhead bound in percent "
                         "(pathology bound: the in-suite dispatch wall "
                         "rides the device queue and swings ~8% between "
                         "arms even interleaved; a real leak — any "
                         "device fetch on the commit path — costs 40%+. "
                         "byte_delta==0 is the tight zero-overhead cell)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    bad = check(fresh, base, args.wall_tol, args.bytes_tol,
                args.obs_wall_pct)
    if bad:
        print("bench gate: REGRESSION")
        for b in bad:
            print("  -", b)
        return 1
    print("bench gate: ok "
          f"({len(fresh.get('deferred', []))} deferred cells, "
          f"{len(fresh.get('ab_interleaved', []))} A/B cells, "
          f"{len(fresh.get('recovery', {}).get('double_loss', []))} "
          "double-loss cells, "
          f"{len(fresh.get('rs', []))} rs cells, "
          f"{len(fresh.get('facade', []))} facade cells, "
          f"{len(fresh.get('roofline', []))} roofline cells, "
          f"{len(fresh.get('chaos', []))} chaos cells, "
          f"{len(fresh.get('obs', {}).get('bytes', []))} obs cells, "
          f"{len(fresh.get('tenancy', {}).get('throughput', []))} "
          "tenancy cells, "
          f"{len(fresh.get('async', {}).get('depths', []))} async cells, "
          f"wall tol {args.wall_tol}, bytes tol {args.bytes_tol})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
