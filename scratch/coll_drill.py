import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, collections, re
import jax
from repro.launch import hlo_cost
from repro.launch.dryrun import dryrun_cell

# capture per-collective-shape wire bytes
orig = hlo_cost.HloCostModel._collective
BY_SHAPE = collections.Counter()
MULT = {}
def patched(self, ins, tot):
    before = dict(tot.wire_bytes)
    orig(self, ins, tot)
    delta = sum(tot.wire_bytes.values()) - sum(before.values())
    if delta:
        BY_SHAPE[f"{ins.opcode}:{ins.type_str[:70]}"] += delta
hlo_cost.HloCostModel._collective = patched
rec = dryrun_cell(sys.argv[1], sys.argv[2], multi_pod=False, verbose=True)
print("\nun-multiplied wire bytes by collective shape:")
for k, v in BY_SHAPE.most_common(15):
    print(f"  {v/1e9:10.2f} GB  {k}")
print("\ncounts:", rec["collectives"]["counts"])
print("wire GB:", {k: round(v*512/1e9,1) for k,v in rec["collectives"]["wire_bytes"].items()})
