"""Scratch: tiny-config forward/loss/grad for each model family on CPU."""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoESpec
from repro.models.transformer import build_model

def check(name, cfg, batch_extra=None):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    B, S = 2, 32
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.mm_positions:
        batch["mm_embeds"] = jnp.ones((B, cfg.mm_positions, cfg.d_model),
                                      jnp.bfloat16) * 0.01
    if cfg.enc_layers:
        batch["src_embeds"] = jnp.ones((B, S, cfg.d_model), jnp.bfloat16) * 0.01
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), (name, loss)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), (name, "gradnorm")
    # decode consistency: greedy decode logits at pos t == forward logits at t
    T = 16
    cache = model.init_cache(B, T)
    if cfg.enc_layers:
        enc_out = model.encode(params, batch["src_embeds"])
        cache["cross"] = model.build_cross_cache(params, enc_out)
    dec_step = jax.jit(model.decode_step)
    logits_seq = []
    for t in range(8):
        lg, cache = dec_step(params, tok[:, t], cache,
                             jnp.asarray(t, jnp.int32))
        logits_seq.append(lg)
    dec_logits = jnp.stack(logits_seq, axis=1)         # (B, 8, V)
    fwd_batch = dict(batch)
    fwd_batch["tokens"] = tok[:, :8]
    if cfg.mm_positions:
        # decode path has no mm prefix in this test; compare without mm
        fwd_batch.pop("mm_embeds")
        import dataclasses
        cfg2 = dataclasses.replace(cfg, mm_positions=0)
        model2 = build_model(cfg2)
        fwd_logits, _ = jax.jit(model2.forward)(params, fwd_batch)
    else:
        fwd_logits, _ = jax.jit(model.forward)(params, fwd_batch)
    err = np.max(np.abs(np.asarray(dec_logits, np.float32)
                        - np.asarray(fwd_logits, np.float32)))
    rel = err / (np.max(np.abs(np.asarray(fwd_logits, np.float32))) + 1e-9)
    print(f"[{name}] params={n:,} loss={float(loss):.4f} "
          f"gnorm={float(gnorm):.3f} decode-vs-fwd max rel err={rel:.2e}")
    assert rel < 0.05, (name, rel)  # bf16 chunked-vs-decode tolerance

common = dict(n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
              param_dtype="float32", compute_dtype="float32")

check("dense", ModelConfig(name="t_dense", family="dense", **common))
check("qknorm+bias", ModelConfig(name="t_qn", family="dense", qk_norm=True,
                                 qkv_bias=True, **common))
check("moe_top1_interleave", ModelConfig(
    name="t_moe", family="moe",
    moe=MoESpec(num_experts=4, top_k=1, d_expert=128, interleave=2,
                shared_expert=True, capacity_factor=4.0), **common))
check("moe_top2", ModelConfig(
    name="t_moe2", family="moe",
    moe=MoESpec(num_experts=4, top_k=2, d_expert=128, capacity_factor=4.0),
    **common))
check("hybrid_rglru", ModelConfig(
    name="t_rg", family="hybrid", block_pattern=("rglru", "rglru", "attn"),
    window=8, subquadratic=True,
    n_layers=5, d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=256,
    param_dtype="float32", compute_dtype="float32"))
check("ssm_xlstm", ModelConfig(
    name="t_xl", family="ssm",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"), subquadratic=True,
    n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=0, vocab=256,
    param_dtype="float32", compute_dtype="float32"))
check("vlm_stub", ModelConfig(name="t_vlm", family="vlm", mm_positions=4,
                              **common))
check("encdec", ModelConfig(name="t_ed", family="audio", enc_layers=2,
                            n_layers=2, d_model=64, n_heads=4, n_kv=2,
                            d_ff=128, vocab=256, param_dtype="float32",
                            compute_dtype="float32"))
print("ALL MODEL SMOKE CHECKS PASSED")
