"""Scratch validation of the core protection library on 8 host devices."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import txn as txn_mod
from repro.core.txn import Mode, Protector

mesh = jax.make_mesh((4, 2), ("data", "model"))

# A heterogeneous state: f32 FSDP-sharded, bf16 TP-sharded, replicated scalar.
state = {
    "w1": jnp.arange(4 * 2 * 64, dtype=jnp.float32).reshape(8, 64) * 0.1,
    "w2": (jnp.arange(16 * 32, dtype=jnp.float32) * 0.01
           ).astype(jnp.bfloat16).reshape(16, 32),
    "step_scale": jnp.float32(3.25),
}
specs = {
    "w1": P("data", "model"),
    "w2": P(None, "model"),
    "step_scale": P(),
}
shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                         is_leaf=lambda x: isinstance(x, P))
state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)

for mode in [Mode.MLPC, Mode.MLP, Mode.ML, Mode.NONE, Mode.REPLICA]:
    prot_obj = Protector(mesh, jax.eval_shape(lambda: state), specs,
                         mode=mode, block_words=64)
    prot = prot_obj.init(state)
    print(f"[{mode.value}] init ok; row_words={prot_obj.layout.row_words} "
          f"n_blocks={prot_obj.layout.n_blocks}")

    # commit an update
    new_state = jax.tree.map(lambda x: (x * 1.5 + 1).astype(x.dtype), state)
    commit = jax.jit(prot_obj.make_commit())
    prot2, ok = commit(prot, new_state, rng_key=jax.random.PRNGKey(1))
    assert bool(ok), mode
    np.testing.assert_array_equal(np.asarray(prot2.state["w1"]),
                                  np.asarray(new_state["w1"]))
    print(f"[{mode.value}] commit ok, step={prot2.step}")

    # canary-abort: state must not change
    prot3, ok3 = commit(prot2, jax.tree.map(lambda x: x * 0, new_state),
                        canary_ok=False)
    assert not bool(ok3)
    assert np.array_equal(np.asarray(prot3.state["w1"]),
                          np.asarray(prot2.state["w1"]))
    print(f"[{mode.value}] abort-on-canary ok")

    if mode.has_cksums:
        rep = prot_obj.scrub(prot2)
        assert not np.any(np.asarray(rep["bad_pages"])), "clean scrub"
        assert bool(rep["parity_ok"])
        print(f"[{mode.value}] scrub clean ok")

    if mode.has_parity:
        # rank loss: garble data-rank 2's shard of w1 and recover
        w1 = np.asarray(prot2.state["w1"]).copy()
        garbled = w1.copy()
        garbled[4:6, :] = np.nan  # rows 4:6 = data-rank 2 of 4 (8 rows / 4)
        bad_state = dict(prot2.state)
        bad_state["w1"] = jax.device_put(garbled, shardings["w1"])
        import dataclasses
        prot_bad = dataclasses.replace(prot2, state=bad_state)
        prot_rec, okr = prot_obj.recover_rank(prot_bad, 2)
        assert bool(okr) or not mode.has_cksums, f"recover verify {mode}"
        np.testing.assert_array_equal(np.asarray(prot_rec.state["w1"]), w1)
        # bf16 leaf also restored bit-exactly
        np.testing.assert_array_equal(
            np.asarray(prot_rec.state["w2"]).view(np.uint16),
            np.asarray(prot2.state["w2"]).view(np.uint16))
        print(f"[{mode.value}] rank-loss recovery ok")

    if mode.has_cksums:
        # scribble: flip bits in one page of rank 1's row, detect via scrub,
        # repair via parity.
        from repro.core import layout as layout_mod
        w1 = np.asarray(prot2.state["w1"]).copy()
        scr = w1.copy()
        scr[2, 3] = -1234.5  # data-rank 1 holds rows 2:4
        bad_state = dict(prot2.state)
        bad_state["w1"] = jax.device_put(scr, shardings["w1"])
        import dataclasses
        prot_bad = dataclasses.replace(prot2, state=bad_state)
        rep = prot_obj.scrub(prot_bad)
        bad = np.asarray(rep["bad_pages"])
        assert bad.any(), "scrub must detect the scribble"
        locs = []
        for idx in np.argwhere(bad):
            locs.append((int(idx[0]), int(idx[-1])))
        print(f"[{mode.value}] scrub detected {locs}")
        prot_fix, okf = prot_obj.repair_pages(
            prot_bad, [r for r, _ in locs], [p for _, p in locs])
        assert bool(okf), "post-repair verification"
        np.testing.assert_array_equal(np.asarray(prot_fix.state["w1"]), w1)
        print(f"[{mode.value}] scribble repair ok")

print("ALL CORE SMOKE CHECKS PASSED")
