"""Render the final §Roofline markdown table from dryrun_v2.json."""
import json

d = json.load(open('/root/repo/scratch/dryrun_v2.json'))
rows = [r for r in d if r.get('status') == 'ok' and r['mesh'] == '16x16']
rows.sort(key=lambda r: (r['workload'], r['arch']))
print("| arch | workload | compute_s | memory_s | coll_s | bound | useful | GiB/dev | next lever |")
print("|---|---|---|---|---|---|---|---|---|")
LEVERS = {
    ("memory", "train"): "fuse optimizer+commit sweeps; bf16 activations",
    ("memory", "prefill"): "Pallas flash kernel (tiles VMEM-resident)",
    ("memory", "decode"): "KV cache quantization (int8) halves the read",
    ("collective", "train"): "overlap grad RS with bwd compute; bf16 grads",
    ("collective", "prefill"): "widen expert groups; overlap a2a with expert FFN",
    ("collective", "decode"): "batch KV patches across steps",
    ("compute", "train"): "-",
}
for r in rows:
    ro = r['roofline']
    kind = 'train' if 'train' in r['workload'] else (
        'prefill' if 'prefill' in r['workload'] else 'decode')
    lever = LEVERS.get((ro['bound'], kind), '-')
    print(f"| {r['arch']} | {r['workload']} | {ro['compute_s']:.2f} | "
          f"{ro['memory_s']:.2f} | {ro['collective_s']:.2f} | {ro['bound']} | "
          f"{ro.get('useful_ratio',0):.3f} | "
          f"{r['memory']['total_bytes_per_device']/2**30:.2f} | {lever} |")
# multi-pod proof line
mp = [r for r in d if r.get('status') == 'ok' and r['mesh'] == '2x16x16']
sk = [r for r in d if r.get('status') == 'skip']
print(f"\nmulti-pod 2x16x16: {len(mp)} cells compiled ok; skips: {len(sk)//2} per mesh (long_500k x full-attention archs)")
