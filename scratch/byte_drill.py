import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, collections
import jax, numpy as np
from repro.configs import WORKLOADS
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.core.txn import Mode, Protector
from repro.launch import hlo_cost
from repro.launch.dryrun import MICROBATCHES, _specs_to_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.transformer import build_model
from repro.optim import build_optimizer

arch, wl_name = sys.argv[1], sys.argv[2]
cfg = get_config(arch); wl = WORKLOADS[wl_name]
mesh = make_production_mesh()
model = build_model(cfg, mesh)
train_cfg = TrainConfig(microbatches=MICROBATCHES.get(arch, 1))
optimizer = build_optimizer(train_cfg, cfg)
abstract_state = api.abstract_train_state(model, optimizer)
state_specs = api.train_state_specs(model, optimizer, mesh)
protector = Protector(mesh, abstract_state, state_specs, mode=Mode.MLPC)
commit = protector.make_commit()
train_step = api.make_train_step(model, optimizer, train_cfg)
def step(prot, batch):
    new_state, metrics = train_step(prot.state, batch)
    prot2, ok = commit(prot, new_state, data_cursor=prot.step, rng_key=jax.random.PRNGKey(0))
    return prot2, (metrics["loss"], ok)
prot_abs = protector.abstract_protected(abstract_state)
prot_specs = protector.protected_specs()
batch_abs = api.batch_abstract(cfg, wl)
b_specs = api.batch_specs(cfg, mesh, wl.global_batch)
in_sh = (_specs_to_shardings(prot_specs, mesh), _specs_to_shardings(b_specs, mesh))
fn = jax.jit(step, in_shardings=in_sh)
text = fn.lower(prot_abs, batch_abs).compile().as_text()
open('/root/repo/scratch/drill_hlo.txt','w').write(text)

m = hlo_cost.HloCostModel(text)
# self bytes per computation (unrolled into sub-calls? no: only own instrs)
def self_cost(comp):
    tot = 0.0
    instr_bytes = collections.Counter()
    for ins in comp.instrs:
        if ins.opcode in hlo_cost._NO_BYTES or ins.opcode in hlo_cost._ELEMENTWISE:
            continue
        ob = sum(hlo_cost._bytes_of(m.shapes.get(o, "")) for o in ins.operands if o in m.shapes)
        nb = ob + hlo_cost._bytes_of(ins.type_str)
        tot += nb
        instr_bytes[f"{ins.opcode}:{ins.type_str[:60]}"] += nb
    return tot, instr_bytes

rows = []
for name, comp in m.comps.items():
    if name in m.fused:  continue
    t, ib = self_cost(comp)
    rows.append((t, name, ib))
rows.sort(reverse=True)
for t, name, ib in rows[:6]:
    print(f"\n=== {name}  self_bytes={t/1e9:.2f} GB ===")
    for k, v in ib.most_common(8):
        print(f"   {v/1e9:10.2f} GB  {k}")
