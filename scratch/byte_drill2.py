import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, collections
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import WORKLOADS
from repro.configs.registry import get_config
from repro.launch import hlo_cost
from repro.launch.dryrun import _specs_to_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.transformer import build_model

arch, wl_name = sys.argv[1], sys.argv[2]
cfg = get_config(arch); wl = WORKLOADS[wl_name]
mesh = make_production_mesh()
model = build_model(cfg, mesh)
forward = api.make_forward(model)
pspecs = model.param_specs(mesh)
abstract_params = model.abstract_params()
batch_abs = api.batch_abstract(cfg, wl)
b_specs = api.batch_specs(cfg, mesh, wl.global_batch)
in_sh = (_specs_to_shardings(pspecs, mesh), _specs_to_shardings(b_specs, mesh))
fn = jax.jit(forward, in_shardings=in_sh)
text = fn.lower(abstract_params, batch_abs).compile().as_text()
open('/root/repo/scratch/drill2_hlo.txt','w').write(text)
m = hlo_cost.HloCostModel(text)
def self_cost(comp):
    tot = 0.0; instr_bytes = collections.Counter()
    for ins in comp.instrs:
        if ins.opcode in hlo_cost._NO_BYTES or ins.opcode in hlo_cost._ELEMENTWISE:
            continue
        ob = sum(hlo_cost._bytes_of(m.shapes.get(o, "")) for o in ins.operands if o in m.shapes)
        nb = ob + hlo_cost._bytes_of(ins.type_str)
        tot += nb; instr_bytes[f"{ins.opcode}:{ins.type_str[:58]}"] += nb
    return tot, instr_bytes
rows = []
for name, comp in m.comps.items():
    if name in m.fused: continue
    t, ib = self_cost(comp)
    rows.append((t, name, ib))
rows.sort(reverse=True)
for t, name, ib in rows[:5]:
    print(f"\n=== {name}  self_bytes={t/1e9:.2f} GB ===")
    for kk, vv in ib.most_common(6):
        print(f"   {vv/1e9:10.2f} GB  {kk}")
tot = m.entry_cost()
print("\nentry totals: flops", f"{tot.flops:.3g}", "bytes", f"{tot.hbm_bytes:.3g}")
