"""Attribute hlo_cost byte counts by opcode for one dry-run cell."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, collections
import jax, numpy as np
from repro.launch.dryrun import dryrun_cell
from repro.launch import hlo_cost

# monkeypatch analyze_text to capture per-opcode byte attribution
orig = hlo_cost.HloCostModel.comp_cost
BYTES_BY_OP = collections.Counter()
FLOPS_BY_OP = collections.Counter()

class Model2(hlo_cost.HloCostModel):
    def comp_cost(self, name):
        if name in self._memo: return self._memo[name]
        comp = self.comps.get(name)
        tot = hlo_cost.CostTotals()
        self._memo[name] = tot
        if comp is None: return tot
        count_bytes = name not in self.fused
        for ins in comp.instrs:
            dt0 = hlo_cost._tuple_shapes(ins.type_str)
            is_float = bool(dt0) and dt0[0][0] in hlo_cost._FLOAT_DTYPES
            if ins.opcode in ("dot", "convolution"):
                tot.flops += self._dot_flops(ins)
            elif is_float and ins.opcode not in hlo_cost._NO_BYTES:
                tot.flops += hlo_cost._elems_of(ins.type_str)
            self._collective(ins, tot)
            if count_bytes and ins.opcode not in hlo_cost._NO_BYTES:
                ob = sum(hlo_cost._bytes_of(self.shapes.get(o, ""))
                         for o in ins.operands if o in self.shapes)
                nbytes = ob + hlo_cost._bytes_of(ins.type_str)
                tot.raw_hbm_bytes += nbytes
                if ins.opcode not in hlo_cost._ELEMENTWISE:
                    tot.hbm_bytes += nbytes
                    BYTES_BY_OP[ins.opcode] += nbytes  # un-multiplied
            trip = 1
            tm = hlo_cost._TRIP_RE.search(ins.line)
            if tm: trip = int(tm.group(1))
            elif ins.opcode == "while": trip = self._trip_from_cond(ins)
            bm = hlo_cost._ATTR_BODY.search(ins.line)
            if bm:
                sub = self.comp_cost(bm.group(1))
                tot.add(sub, trip)
                BYTES_BY_OP[f"__body_{bm.group(1)[:40]}_x{trip}"] += sub.hbm_bytes * trip
                cm = hlo_cost._ATTR_COND.search(ins.line)
                if cm: tot.add(self.comp_cost(cm.group(1)), trip + 1)
            for m in hlo_cost._ATTR_CALLS.finditer(ins.line):
                tot.add(self.comp_cost(m.group(1)), 1)
            brm = hlo_cost._ATTR_BRANCHES.search(ins.line)
            if brm:
                for b in hlo_cost._OPERAND_RE.findall(brm.group(1)):
                    tot.add(self.comp_cost(b), 1.0)
        self._memo[name] = tot
        return tot

hlo_cost.HloCostModel = Model2
arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-0.5b"
wl = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
rec = dryrun_cell(arch, wl, multi_pod=False, verbose=True)
print("\ntop byte contributors (body entries show rolled-up xtrip):")
for op, b in BYTES_BY_OP.most_common(25):
    print(f"  {op:55s} {b/1e9:12.1f} GB")
print("\ncost:", {k: f"{v:.3g}" for k, v in rec["cost"].items()})
