"""Elastic rescale demo: move a protected training job between meshes.

    PYTHONPATH=src python examples/elastic_rescale.py

A job training on a (4, 2) mesh loses nodes and continues on (2, 2); later
it scales back up to (4, 2).  The divisibility-fallback sharding rules keep
the same model valid on every mesh; protection (zone geometry depends on G)
is rebuilt after each move, exactly as Pangolin rebuilds parity when row
geometry changes.  Loss history continues seamlessly across both moves.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.dist.elastic import reshard_state
from repro.runtime.trainer import Trainer


def make_trainer(mesh, seed=0):
    cfg = ModelConfig(
        name="elastic-demo", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=512, param_dtype="float32",
        compute_dtype="float32")
    t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=5,
                                 total_steps=200),
                ProtectConfig(mode="mlpc", block_words=64),
                mesh, seq_len=64, global_batch=8, seed=seed)
    return t


def move(trainer_old, new_mesh):
    """Re-shard state onto the new mesh and rebuild protection there."""
    t_new = make_trainer(new_mesh, seed=0)
    state = reshard_state(
        trainer_old.prot.state, new_mesh,
        t_new.protector.state_specs)
    t_new.prot = t_new.protector.init(state)
    import dataclasses
    import jax.numpy as jnp
    # the step counter moves as a host value — device arrays must not leak
    # across meshes
    t_new.prot = dataclasses.replace(
        t_new.prot,
        step=jnp.asarray(int(jax.device_get(trainer_old.prot.step)),
                         jnp.uint32))
    t_new.cursor = trainer_old.cursor
    return t_new


def main():
    mesh_full = jax.make_mesh((4, 2), ("data", "model"))
    mesh_small = jax.make_mesh((2, 2), ("data", "model"))

    t = make_trainer(mesh_full)
    t.initialize()
    losses = [o["loss"] for o in t.run(10)]
    print(f"phase 1 (4x2, G=4):  steps 1-10,  loss -> {losses[-1]:.4f}, "
          f"parity overhead {t.protector.overhead_report()['parity_fraction']:.3f}")

    # nodes evicted: shrink to 2x2 (G=2), protection rebuilt
    t = move(t, mesh_small)
    losses += [o["loss"] for o in t.run(10)]
    print(f"phase 2 (2x2, G=2):  steps 11-20, loss -> {losses[-1]:.4f}, "
          f"parity overhead {t.protector.overhead_report()['parity_fraction']:.3f}")

    # capacity restored: scale back up, verify recovery still works
    t = move(t, mesh_full)
    losses += [o["loss"] for o in t.run(10)]
    print(f"phase 3 (4x2, G=4):  steps 21-30, loss -> {losses[-1]:.4f}")

    from repro.runtime import failure
    t.prot, ev = failure.inject_rank_loss(t.protector, t.prot, rank=1)
    rep = t.on_failure(ev)
    print(f"post-rescale rank loss: recovered, verified={rep['verified']}")

    assert np.mean(losses[-5:]) < np.mean(losses[:5]), "loss must decrease"
    assert int(jax.device_get(t.prot.step)) == 30
    print("elastic rescale demo passed: 30 contiguous steps across 3 meshes")


if __name__ == "__main__":
    main()
