"""Elastic rescale demo: move a protected training job between meshes.

    PYTHONPATH=src python examples/elastic_rescale.py [--smoke]

A job training on a (4, 2) mesh loses nodes and continues on (2, 2); later
it scales back up to (4, 2).  The divisibility-fallback sharding rules keep
the same model valid on every mesh; protection (zone geometry depends on G)
is rebuilt after each move by `Pool.rescale` — flush any open window,
reshard the state bit-exactly, rebuild parity/checksums on the new
geometry, carry the step counter — exactly as Pangolin rebuilds parity
when row geometry changes.  Loss history continues seamlessly across both
moves.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.runtime.trainer import Trainer


def make_trainer(mesh, seed=0):
    cfg = ModelConfig(
        name="elastic-demo", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv=2, d_ff=128, vocab=512, param_dtype="float32",
        compute_dtype="float32")
    t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=5,
                                 total_steps=200),
                ProtectConfig(mode="mlpc", block_words=64),
                mesh, seq_len=64, global_batch=8, seed=seed)
    return t


def move(trainer_old, new_mesh):
    """Move the protected job: one `Pool.rescale` call does the flush,
    the bit-exact reshard, the protection rebuild on the new zone
    geometry and the host-side step-counter carry."""
    t_new = make_trainer(new_mesh, seed=0)
    t_new.pool = trainer_old.pool.rescale(new_mesh, into=t_new.pool)
    t_new.cursor = trainer_old.cursor
    return t_new


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps per phase)")
    args = ap.parse_args()
    n = 4 if args.smoke else 10

    mesh_full = jax.make_mesh((4, 2), ("data", "model"))
    mesh_small = jax.make_mesh((2, 2), ("data", "model"))

    t = make_trainer(mesh_full)
    t.initialize()
    losses = [o["loss"] for o in t.run(n)]
    print(f"phase 1 (4x2, G=4):  steps 1-{n},  loss -> {losses[-1]:.4f}, "
          f"parity overhead "
          f"{t.pool.overhead_report()['parity_fraction']:.3f}")

    # nodes evicted: shrink to 2x2 (G=2), protection rebuilt
    t = move(t, mesh_small)
    losses += [o["loss"] for o in t.run(n)]
    print(f"phase 2 (2x2, G=2):  steps {n + 1}-{2 * n}, loss -> "
          f"{losses[-1]:.4f}, parity overhead "
          f"{t.pool.overhead_report()['parity_fraction']:.3f}")

    # capacity restored: scale back up, verify recovery still works
    t = move(t, mesh_full)
    losses += [o["loss"] for o in t.run(n)]
    print(f"phase 3 (4x2, G=4):  steps {2 * n + 1}-{3 * n}, loss -> "
          f"{losses[-1]:.4f}")

    from repro.runtime import failure
    t.prot, ev = failure.inject_rank_loss(t.protector, t.prot, rank=1)
    rep = t.on_failure(ev)
    print(f"post-rescale rank loss: recovered, verified={rep['verified']}")

    if not args.smoke:         # too few steps to demand descent in CI
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), \
            "loss must decrease"
    assert int(jax.device_get(t.prot.step)) == 3 * n
    print(f"elastic rescale demo passed: {3 * n} contiguous steps across "
          "3 meshes")


if __name__ == "__main__":
    main()
