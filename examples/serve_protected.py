"""Serving demo: batched decode with Pangolin protection of the KV cache.

    PYTHONPATH=src python examples/serve_protected.py [--tokens 64] [--smoke]

Decode is the paper's *atomic-style small update*: each step touches a tiny
known range of the cache, so the server's pool uses the incremental (patch)
side of the hybrid scheme — checksums refresh per dirty page, parity via
XOR patch.  Mid-stream, the demo corrupts the live cache and shows the
pool's scrub+repair keeping the generation identical to an uncorrupted
run.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig, ProtectConfig
from repro.runtime import failure
from repro.runtime.server import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer tokens, smaller batch)")
    args = ap.parse_args()
    if args.smoke:
        args.tokens, args.batch = 16, 4

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = ModelConfig(
        name="srv-demo", family="dense", n_layers=4, d_model=128, n_heads=8,
        n_kv=2, d_ff=256, vocab=1024, param_dtype="float32",
        compute_dtype="float32")
    from repro.models.transformer import build_model
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 8),
                                0, cfg.vocab)

    # reference: protected run with no faults
    ref_srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=256), mesh,
                     batch=args.batch, max_len=args.tokens + 16)
    ref_srv.start(params)
    t0 = time.time()
    ref = ref_srv.generate(prompt, n_new=args.tokens)
    dt = time.time() - t0
    print(f"reference generation: {args.batch}x{args.tokens} tokens "
          f"({args.batch * args.tokens / dt:.0f} tok/s) | cache overhead: "
          f"{ref_srv.pool.overhead_report()['protection_fraction']:.3f}")

    # faulted run: corrupt the live cache mid-generation, repair online
    srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=256), mesh,
                 batch=args.batch, max_len=args.tokens + 16)
    srv.start(params)
    tok = srv.prefill(prompt)
    out = [np.asarray(jax.device_get(tok))]
    for i in range(args.tokens - 1):
        if i == args.tokens // 2:
            srv.prot, _ = failure.inject_scribble(
                srv.protector, srv.prot, rank=2, word_offsets=[31, 77])
            rep = srv.pool.scrub()
            print(f"[token {i}] cache scribbled -> scrub found "
                  f"{rep.bad_locations}, repaired={rep.repair_ok}")
        tok = srv.step(tok)
        out.append(np.asarray(jax.device_get(tok)))
    got = np.stack(out, axis=1)
    assert np.array_equal(got, ref), "faulted run must match reference"
    print("faulted generation matches reference bit-for-bit — "
          "online cache repair is transparent to serving")


if __name__ == "__main__":
    main()
