"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps with the full Pangolin protection stack, surviving injected
failures along the way.

    PYTHONPATH=src python examples/train_fault_tolerant.py \
        [--steps 300] [--mode mlpc] [--d-model 512] [--no-faults] [--smoke]

Timeline (default):
  step  60   silent scribble injected -> caught by the periodic scrub,
             repaired online, training unaffected
  step 120   rank loss (chip failure) -> SIGBUS-analog event -> freeze,
             parity reconstruction, resume — no checkpoint restore
  step 180   staged-buffer overrun -> canary aborts the commit; the step
             re-executes
  step 240   crash (process state dropped) -> restore newest checkpoint +
             replay the redo log; digests verify bit-exact replay
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.runtime import failure
from repro.runtime.trainer import Trainer


def build_cfg(d_model: int) -> ModelConfig:
    # qwen2-family block at ~100M scale (d=512: ~103M params with vocab 32k)
    return ModelConfig(
        name="qwen2-100m", family="dense", n_layers=8, d_model=d_model,
        n_heads=8, n_kv=2, d_ff=4 * d_model, vocab=32768, qkv_bias=True,
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--mode", default="mlpc")
    ap.add_argument("--redundancy", type=int, default=1,
                    choices=[1, 2, 3],
                    help="syndrome stack height r (losses survived per "
                         "4-rank zone; r <= 3 here since G = 4)")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: a fresh temp dir (stale checkpoints from "
                         "other configs must not be restored into this run)")
    ap.add_argument("--no-faults", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: a tiny model for a few dozen steps "
                         "through the same fault timeline")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.d_model = 30, 64
        args.seq_len, args.batch = 64, 4

    if args.ckpt_dir is None:
        import tempfile
        args.ckpt_dir = tempfile.mkdtemp(prefix="pangolin_ckpt_")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = build_cfg(args.d_model)
    trainer = Trainer(
        cfg, TrainConfig(learning_rate=1e-3, warmup_steps=20,
                         total_steps=args.steps),
        ProtectConfig(mode=args.mode, redundancy=args.redundancy,
                      scrub_period=50),
        mesh, seq_len=args.seq_len, global_batch=args.batch,
        checkpoint_dir=args.ckpt_dir, seed=0)
    trainer.initialize()
    n_params = sum(x.size for x in
                   jax.tree.leaves(trainer.prot.state["params"]))
    print(f"model: {n_params / 1e6:.1f}M params | mode={args.mode} | "
          f"overhead: {trainer.pool.overhead_report()}")

    q = max(args.steps // 5, 1)
    faults = {} if args.no_faults else {
        q: "scribble", 2 * q: "rank_loss", 3 * q: "canary", 4 * q: "crash"}
    t0 = time.time()
    losses = []
    step = 0
    while step < args.steps:
        fault = faults.get(step)
        if fault == "scribble":
            trainer.prot, ev = failure.inject_scribble(
                trainer.protector, trainer.prot, rank=1,
                word_offsets=[1009, 4096])
            print(f"[{step}] injected silent scribble "
                  f"(will be caught by scrub at the period boundary)")
            # force an immediate scrub (as the periodic task would)
            rep = trainer.pool.scrub()
            print(f"[{step}] scrub: bad={rep.bad_locations} "
                  f"repaired={rep.repaired} verified={rep.repair_ok}")
        elif fault == "rank_loss":
            r = trainer.protector.redundancy
            if r >= 2:
                # a syndrome stack survives r simultaneous losses: take
                # down r ranks at once and solve them all
                dead = tuple(range(r))
                trainer.prot, ev = failure.inject_multi_rank_loss(
                    trainer.protector, trainer.prot, dead)
                rep = trainer.on_failure(ev)
                print(f"[{step}] ranks {list(dead)} lost -> online "
                      f"e={r}-erasure recovery verified={rep['verified']}")
            else:
                trainer.prot, ev = failure.inject_rank_loss(
                    trainer.protector, trainer.prot, rank=2)
                rep = trainer.on_failure(ev)
                print(f"[{step}] rank 2 lost -> online recovery "
                      f"verified={rep['verified']}")
        elif fault == "canary":
            out = trainer.step(canary_ok=False)
            print(f"[{step}] canary smash -> commit aborted "
                  f"(committed={out['committed']}); re-executing step")
        elif fault == "crash":
            trainer.save_checkpoint(wait=True)
            print(f"[{step}] simulated crash: restoring from checkpoint "
                  f"+ redo-log replay")
            info = trainer.restore_from_checkpoint()
            print(f"[{step}] restored step {info['restored_step']}, "
                  f"replayed {info['replayed']}")
        out = trainer.step()
        losses.append(out["loss"])
        step = out["step"]
        if step % 20 == 0:
            dt = time.time() - t0
            print(f"step {step:4d}  loss {out['loss']:.4f}  "
                  f"({step / dt:.2f} steps/s)")
        if step % 100 == 0:
            trainer.save_checkpoint()

    w = max(min(20, args.steps // 3), 1)
    first, last = np.mean(losses[:w]), np.mean(losses[-w:])
    print(f"\ndone: loss {first:.4f} -> {last:.4f} over {args.steps} steps "
          f"with {len(faults)} faults survived")
    if args.steps >= 60:
        assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
