"""Quickstart: protect any sharded JAX state with Pangolin-JAX.

    PYTHONPATH=src python examples/quickstart.py

The whole public surface is the `Pool` facade — the analogue of
Pangolin's three-call API (paper Listing 2):

    pgl_open            ->  Pool.open(state, specs, mesh=..., config=...)
    pgl_tx_begin/commit ->  with pool.transaction() as tx: tx.stage(new)
    pgl_tx_abort        ->  canary mismatch inside the context
    async commit (FliT) ->  pool.commit_async(new) -> CommitTicket;
                            pool.drain() at any boundary
    SIGBUS handler      ->  pool.recover(Fault.rank_loss(r))
    scrubbing thread    ->  pool.scrub() / pool.maybe_scrub()

`ProtectConfig` is the single knob: mode ladder (none < ml < mlp < mlpc,
plus replica), the Reed-Solomon syndrome stack height (redundancy r in
1..4 — any e <= r simultaneous rank losses reconstruct), the deferred
window W, and the scrub cadence.  This demo: build a pool over a sharded
pytree, commit a transactional update, lose a rank, recover it online,
scribble a page, scrub-detect + repair it, and abort a transaction whose
staging buffer smashed its canary.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import Fault, Pool, ProtectConfig
from repro.runtime import failure

# 1. a sharded state pytree: FSDP weights, TP weights, a replicated scalar
mesh = jax.make_mesh((4, 2), ("data", "model"))
specs = {"w_fsdp": P("data", "model"), "w_tp": P(None, "model"),
         "scale": P()}
state = {
    "w_fsdp": jnp.arange(16 * 64, dtype=jnp.float32).reshape(16, 64) * .01,
    "w_tp": jnp.ones((8, 32), jnp.bfloat16),
    "scale": jnp.float32(1.0),
}
state = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs)

# 2. pgl_open: checksums detect corruption, XOR parity across the 4-rank
#    zone repairs it, at 1/4 storage overhead (1/G; 1% at G=100)
pool = Pool.open(state, specs, mesh=mesh,
                 config=ProtectConfig(mode="mlpc", block_words=64))
print("protected:", pool.overhead_report())

# 3. transactional update (open -> mutate the micro-buffer -> commit)
new_state = jax.tree.map(lambda x: (x * 2).astype(x.dtype), state)
with pool.transaction(rng_key=jax.random.PRNGKey(0)) as tx:
    tx.stage(new_state)
print(f"commit ok={tx.ok} step={pool.step}")

# 4. media error: lose data-rank 2 entirely; rebuild online from parity
want = np.asarray(pool.state["w_fsdp"]).copy()
pool.prot, event = failure.inject_rank_loss(pool.protector, pool.prot,
                                            rank=2)
rep = pool.recover(Fault.rank_loss(event.lost_rank))
assert rep.verified
assert np.array_equal(np.asarray(pool.state["w_fsdp"]), want)
print("rank-loss recovery: bit-exact")

# 5. silent scribble: flip bits, detect by scrub, repair the page
pool.prot, event = failure.inject_scribble(pool.protector, pool.prot,
                                           rank=1, word_offsets=[7])
report = pool.scrub()
print("scrub found corrupted (rank, page):", report.bad_locations)
assert report.repaired and report.repair_ok
assert np.array_equal(np.asarray(pool.state["w_fsdp"]), want)
print("scribble repair: bit-exact")

# 6. canary: a staged buffer overrun aborts the commit, state untouched
step_before = pool.step
with pool.transaction() as tx:
    tx.watch(failure.smashed_canary_buffer(4096))   # overrun staging buf
    tx.stage(jax.tree.map(jnp.zeros_like, new_state))
assert tx.aborted and not tx.ok and pool.step == step_before
assert np.array_equal(np.asarray(pool.state["w_fsdp"]), want)
print("canary abort: state untouched")

# 7. telemetry: every pool publishes into a host-side metrics registry
#    (zero compiled-byte overhead — benchmarks/obs_overhead.py proves
#    it) and folds its degradation signals into a HealthReport.  The
#    same surface backs the --metrics-dir / --trace-dir launch flags
#    (repro.launch.train / repro.launch.serve) and a Prometheus scrape.
stats = pool.stats()                    # host-only snapshot, no device sync
print(f"stats: commits={stats['commits']} recoveries="
      f"{stats['recoveries']} scrub_coverage="
      f"{stats['scrub']['full_fraction']:.2f}")
health = pool.health()                  # green | degraded | critical
print(f"health: {health.status} {health.reasons}")
assert health.status == "degraded"      # the repairing scrub left
assert health.suspect                   # failure suspicion outstanding
pool.scrub()                            # ...which a clean scrub heals
print(f"health after clean scrub: {pool.health().status}")
assert pool.health().status == "green"
assert stats["recoveries"] == 1 and stats["aborted_commits"] == 1
from repro.obs import prometheus_text   # the scrape-endpoint text format
assert "pool_commits_total" in prometheus_text(pool.metrics)
print("telemetry surface live")

# 8. multi-tenant: a PoolGroup hosts many pools at once.  Same-shape
#    same-config tenants share one cohort — one Protector, one compiled
#    program — and a commit wave lands them in ONE batched dispatch,
#    bit-identical to N separate pool.commit calls; a shared scrub
#    scheduler spreads verification over tenants under a page budget,
#    and QoS presets (GOLD/SILVER/BRONZE) pick protection + scrub weight.
from repro.tenancy import GOLD, PoolGroup


def make_state(k):                      # fresh buffers per tenant (the
    st = {                              # earlier steps donated `state`)
        "w_fsdp": jnp.arange(16 * 64, dtype=jnp.float32)
        .reshape(16, 64) * (.01 * k),
        "w_tp": jnp.ones((8, 32), jnp.bfloat16) * k,
        "scale": jnp.float32(k),
    }
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), st, specs)


grp = PoolGroup(mesh)
for k, tid in enumerate(("alice", "bob"), start=1):
    grp.admit(tid, make_state(k), specs, qos=GOLD)
updates = {tid: make_state(k + 10)
           for k, tid in enumerate(("alice", "bob"), start=1)}
verdicts = grp.commit(updates)          # ONE batched dispatch
assert all(bool(v) for v in verdicts.values())
grp.scrub_tick()                        # shared-scheduler scrub pass
assert grp.health()["status"] == "green"
assert np.array_equal(
    np.asarray(grp["alice"].pool.state["w_fsdp"]),
    np.asarray(updates["alice"]["w_fsdp"]))
print(f"pool group: {len(grp)} tenants, 1 cohort, batched commit ok")

# 9. async commit pipeline: `commit_async` returns a CommitTicket — a
#    future over the commit program's device verdict — and up to
#    `ProtectConfig.pipeline_depth` commits stay in flight at once, so
#    the host dispatches commit t+k while the device still runs commit
#    t.  Verdicts resolve out of dispatch order (`poll`), and `drain()`
#    at any boundary lands the pipeline bit-identical to synchronous
#    commits (flush / scrub / recover all drain first, automatically).
apool = Pool.open(make_state(5), specs, mesh=mesh,
                  config=ProtectConfig(mode="mlpc", block_words=64,
                                       pipeline_depth=4))
tickets = []
cur = make_state(5)
for i in range(4):
    cur = jax.tree.map(lambda x: (x * 1.01).astype(x.dtype), cur)
    tickets.append(apool.commit_async(cur, data_cursor=i))
print(f"async: {apool.in_flight} commits in flight")
apool.drain()
assert all(t.result() for t in tickets)          # every verdict landed
lat = apool.stats()["commit_resolve_ms"]
print(f"async: drained, resolve p99={lat['p99']:.2f} ms "
      f"(span id of last dispatch: {tickets[-1].span_id})")
print("all quickstart checks passed")
