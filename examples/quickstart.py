"""Quickstart: protect any sharded JAX state with Pangolin-JAX.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the whole public surface in ~60 lines: build a Protector over
a sharded pytree, commit a transactional update, lose a rank, recover it
online, scribble a page, scrub-detect it, repair it.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.txn import Mode, Protector
from repro.runtime import failure

# 1. a sharded state pytree: FSDP weights, TP weights, a replicated scalar
mesh = jax.make_mesh((4, 2), ("data", "model"))
specs = {"w_fsdp": P("data", "model"), "w_tp": P(None, "model"),
         "scale": P()}
state = {
    "w_fsdp": jnp.arange(16 * 64, dtype=jnp.float32).reshape(16, 64) * .01,
    "w_tp": jnp.ones((8, 32), jnp.bfloat16),
    "scale": jnp.float32(1.0),
}
state = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), state, specs)

# 2. protect it: checksums detect corruption, XOR parity across the 4-rank
#    zone repairs it, at 1/4 storage overhead (1/G; 1% at G=100)
protector = Protector(mesh, jax.eval_shape(lambda: state), specs,
                      mode=Mode.MLPC, block_words=64)
prot = protector.init(state)
print("protected:", protector.overhead_report())

# 3. transactional update (the paper's Listing 2: open -> mutate -> commit)
commit = jax.jit(protector.make_commit())
new_state = jax.tree.map(lambda x: (x * 2).astype(x.dtype), state)
prot, ok = commit(prot, new_state, rng_key=jax.random.PRNGKey(0))
print(f"commit ok={bool(ok)} step={int(prot.step)}")

# 4. media error: lose data-rank 2 entirely; rebuild online from parity
want = np.asarray(prot.state["w_fsdp"]).copy()
prot, event = failure.inject_rank_loss(protector, prot, rank=2)
prot, ok = protector.recover_rank(prot, event.lost_rank)
assert bool(ok)
assert np.array_equal(np.asarray(prot.state["w_fsdp"]), want)
print("rank-loss recovery: bit-exact")

# 5. silent scribble: flip bits, detect by scrub, repair the page
prot, event = failure.inject_scribble(protector, prot, rank=1,
                                      word_offsets=[7])
report = protector.scrub(prot)
locs = np.argwhere(np.asarray(report["bad_pages"]))
print("scrub found corrupted (mesh-pos..., page):", locs.tolist())
prot, ok = protector.repair_pages(
    prot, [int(locs[0][0])], [int(locs[0][-1])])
assert bool(ok)
assert np.array_equal(np.asarray(prot.state["w_fsdp"]), want)
print("scribble repair: bit-exact")

# 6. canary: a staged buffer overrun aborts the commit, state untouched
prot2, ok = commit(prot, new_state, canary_ok=False)
assert not bool(ok) and int(prot2.step) == int(prot.step)
print("canary abort: state untouched — all quickstart checks passed")
