"""Deferred-epoch vs synchronous commit engine — interleaved A/B.

The acceptance comparison for the deferred-epoch engine (core/epoch.py):
the decode scenario (leafy state, one leaf dirty per step — the serving
hot path) run with window W in {1, 4, 16}, where W=1 is the synchronous
single-sweep engine (`Protector.make_commit(dirty_pages=...)`) and W>1
the DeferredProtector.  Three measurements per cell:

  * amortized wall time per step, interleaved across engines rep by rep
    so ambient machine noise hits every engine equally (each rep runs a
    full window: W-1 in-window commits + the flush);
  * amortized XLA "bytes accessed" per step, ((W-1)*step + step+flush)/W
    — deterministic, machine-state-free;
  * bit-identity: at every epoch boundary the deferred engine's parity /
    cksums / digest / row must equal the synchronous engine's exactly.

Both engines run with the static (host-known) canary, so the A/B
isolates the deferral itself, not abort-gating differences.
"""
from __future__ import annotations

import sys

try:
    from benchmarks import _bootstrap  # noqa: F401  (run as a module)
except ImportError:
    import _bootstrap                  # noqa: F401  (run as a script)

import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.commit_sweep import _leafy_state, _xla_bytes
from repro.configs.base import ProtectConfig
from repro.core import layout as layout_mod
from repro.core.txn import Mode
from repro.pool import Pool

SIZES = [256 * 1024, 1024 * 1024]
WINDOWS = [1, 4, 16]
MODES = [Mode.MLPC, Mode.MLP]


def _check_boundary_equal(pr_sync, est, mode):
    np.testing.assert_array_equal(np.asarray(pr_sync.parity),
                                  np.asarray(est.prot.parity))
    np.testing.assert_array_equal(np.asarray(pr_sync.digest),
                                  np.asarray(est.prot.digest))
    np.testing.assert_array_equal(np.asarray(pr_sync.row),
                                  np.asarray(est.prot.row))
    if mode.has_cksums:
        np.testing.assert_array_equal(np.asarray(pr_sync.cksums),
                                      np.asarray(est.prot.cksums))


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    reps = 12 if quick else 25
    span = 16                      # steps per timed rep, every engine
    rows = []
    for size in SIZES:
        for mode in MODES:
            state, specs = _leafy_state(size, mesh)
            base = Pool.open(state, specs, mesh=mesh,
                             config=ProtectConfig(mode=mode.value,
                                                  block_words=64),
                             donate=False)
            p = base.protector
            lo = p.layout
            dirty = layout_mod.leaf_pages(lo, 3).tolist()
            new = dict(state)
            new["l03"] = state["l03"] * 1.01
            sync = jax.jit(p.make_commit(dirty_pages=dirty),
                           static_argnames=("canary_ok",))

            engines = {}
            for w in WINDOWS:
                if w == 1:
                    prot = p.init(state)

                    def run_sync(prot=prot):
                        pr = prot
                        for _ in range(span):
                            pr, ok = sync(pr, new)
                        return pr

                    engines[w] = run_sync
                    bytes_step = _xla_bytes(sync, prot, new)
                else:
                    # one pool per window size: engine programs compile
                    # per engine either way, so the only extra cost over
                    # sharing the base protector is a host-side layout
                    # build — and benchmarks stay on the public facade
                    eng = Pool(mesh, base.abstract_state, specs,
                               ProtectConfig(mode=mode.value,
                                             block_words=64, window=w),
                               dirty_leaf_idx=[3], donate=False).engine
                    est0 = eng.init(state)
                    est0, _ = eng.commit(est0, new)     # compile both
                    eng._since = 0

                    def run_def(eng=eng, est0=est0):
                        est = est0
                        eng._since = 0
                        for _ in range(span):
                            est, ok = eng.commit(est, new)
                        return est

                    engines[w] = run_def
                    step_b = _xla_bytes(
                        eng._jit["step"], est0.prot, est0.dirty,
                        est0.pending, est0.acc, new, None, 0, None, True)
                    flush_b = _xla_bytes(
                        eng._jitted("flush", eng.make_flush), est0)
                    bytes_step = (step_b * w + flush_b) / w
                rows.append({"size_B": size, "mode": mode.value,
                             "window": w,
                             "bytes_per_step_MB": round(bytes_step / 2**20,
                                                        3)})

            # interleaved wall: rep r runs every engine back to back
            for fn in engines.values():
                for _ in range(2):
                    jax.block_until_ready(jax.tree.leaves(fn())[0])
            times = {w: [] for w in engines}
            for _ in range(reps):
                for w, fn in engines.items():
                    t0 = time.perf_counter()
                    jax.block_until_ready(jax.tree.leaves(fn())[0])
                    times[w].append(time.perf_counter() - t0)
            for row in rows[-len(engines):]:
                med = float(np.median(times[row["window"]]))
                row["wall_us_per_step"] = round(med / span * 1e6, 1)

            # bit-identity at the epoch boundary (16 commits everywhere)
            pr_sync = engines[1]()
            for w in WINDOWS[1:]:
                _check_boundary_equal(pr_sync, engines[w](), mode)
    common.print_table(
        "deferred-epoch A/B (interleaved reps; W=1 == synchronous)",
        rows, ["size_B", "mode", "window", "wall_us_per_step",
               "bytes_per_step_MB"])
    out = {"rows": rows, "reps": reps, "span": span}
    common.save_result("deferred", out)
    return out


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
