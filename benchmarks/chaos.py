"""Chaos campaign under live traffic (BENCH_commit.json §chaos).

Runs the scripted fault scenarios from repro.chaos.scenarios — rescale
under traffic, straggler degradation, mid-window scribble+loss,
syndrome-budget exhaustion + re-arm, and the crash/replay storm matrix
over r x W — against sustained synthetic commit traffic, and distills
per-scenario tail latency (commit p50/p99, clean vs during-disturbance)
and recovery-time-under-load into one diffable record.

Two properties are load-bearing:

  * every scenario must end bit-identical to its fault-free golden run
    (`scenarios.campaign` raises otherwise, and the gate re-checks the
    recorded flag structurally) — chaos may cost latency, never bytes;
  * the during-disturbance p99 gates as a wall cell (pathology
    tolerance only): a recovery that stalls traffic 10x longer than the
    baseline captured is a hang, not noise.
"""
from __future__ import annotations

from benchmarks import common


def _row(res: dict) -> dict:
    cm = res["commit_ms"]
    return {
        "scenario": res["scenario"],
        "steps": res["steps"],
        "events": res["events"],
        "r": res["r"],
        "window": res["window"],
        "clean_p50_ms": cm["clean"]["p50_ms"],
        "clean_p99_ms": cm["clean"]["p99_ms"],
        "during_p50_ms": cm["during"]["p50_ms"],
        "during_p99_ms": cm["during"]["p99_ms"],
        "recovery_p50_ms": res["recovery_ms"]["p50_ms"],
        "recovery_p99_ms": res["recovery_ms"]["p99_ms"],
        "recoveries": len(res["recoveries"]),
        "golden_exact": bool(res.get("golden_exact")),
    }


def run(quick: bool = False) -> dict:
    from repro.chaos import scenarios

    results = scenarios.campaign(quick=quick, storms=True)
    rows = [_row(r) for r in results]
    fmt = lambda v: None if v is None else round(v, 2)  # noqa: E731
    common.print_table(
        "chaos campaign: tail latency + recovery under load (ms)",
        [{**r, **{k: fmt(r[k]) for k in r if k.endswith("_ms")}}
         for r in rows],
        ["scenario", "r", "window", "clean_p50_ms", "clean_p99_ms",
         "during_p50_ms", "during_p99_ms", "recovery_p50_ms",
         "recovery_p99_ms", "recoveries", "golden_exact"])
    out = {"rows": rows}
    common.save_result("chaos", out)
    return out
