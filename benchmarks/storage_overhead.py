"""Paper §4.2 — storage overhead of each protection mode.

Pangolin: parity ~1% of an 8 GB pool (100 chunk rows) + ~8 MB replicated
metadata, vs libpmemobj-R's 100%.  Here: parity = 1/G of the zone (G = data
axis), checksums = 8 B per 4 KB page, replica = 100% — reported per
architecture from its real train-state layout, at G = 4 (bench mesh),
G = 16 (production pod) and G = 64 (multi-pod deployments).

Syndrome stack (redundancy=r, beyond paper): every extra GF(2^32)
Reed-Solomon syndrome is one more seg_words row per rank, so surviving
any r simultaneous rank losses costs exactly r x the parity fraction —
r=4 is still ~6% at G=64 where a full replica (which only survives ONE
loss) costs 100%.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, list_archs
from repro.core import layout as layout_mod
from repro.models import api
from repro.models.transformer import build_model
from repro.optim import build_optimizer


def run(quick: bool = False) -> dict:
    rows = []
    archs = list_archs() if not quick else ["qwen2-0.5b", "xlstm-1.3b"]
    for arch in archs:
        cfg = get_config(arch)
        model = build_model(cfg)
        optimizer = build_optimizer(TrainConfig(), cfg)
        abstract = api.abstract_train_state(model, optimizer)
        state_bytes = sum(
            l.size * jax.numpy.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(abstract))
        for g in (4, 16, 64):
            lo = layout_mod.build_layout(abstract, g)   # unsharded rows
            rep = lo.overhead_report()
            parity_pct = round(100 * rep["parity_fraction"], 2)
            rows.append({
                "arch": arch,
                "state_GiB": round(state_bytes / 2**30, 2),
                "G": g,
                "parity_pct": parity_pct,
                # each extra syndrome is one more seg_words row: the
                # stack tax is exactly r x P by construction
                "dual_parity_pct": round(2 * parity_pct, 2),
                "r3_pct": round(3 * parity_pct, 2),
                "r4_pct": round(4 * parity_pct, 2),
                "checksum_pct": round(100 * rep["checksum_fraction"], 3),
                "replica_pct": 100.0,
            })
    common.print_table(
        "storage overhead (percent of protected state)", rows,
        ["arch", "state_GiB", "G", "parity_pct", "dual_parity_pct",
         "r3_pct", "r4_pct", "checksum_pct", "replica_pct"])
    # the paper's headline: parity at deployment scale is ~1%, replica
    # 100% — and even FOUR-loss survival stays under 4x the parity tax
    # (a replica survives one loss at 100%)
    g64 = [r for r in rows if r["G"] == 64]
    assert all(r["parity_pct"] < 2.0 for r in g64), g64
    assert all(r["r4_pct"] <= 4 * r["parity_pct"] + 1e-9
               for r in rows), rows
    assert all(r["r4_pct"] < r["replica_pct"] for r in g64), g64
    common.save_result("storage_overhead", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
