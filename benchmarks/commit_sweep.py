"""Unfused vs fused commit engine — interleaved A/B on the same process.

This benchmark reconstructs the *seed* engine — re-flatten old and new
state, then separate verify / parity / checksum / digest sweeps — from
the same primitives, and compares it with the single-sweep engine
(core/txn.py) three ways:

  * wall time with interleaved repetitions, so ambient machine noise hits
    both sides equally (cross-run comparisons on a contended CPU box
    swing 3x; see EXPERIMENTS.md §Perf for the recorded numbers);
  * XLA's compiled "bytes accessed" — a deterministic, machine-state-free
    proxy for the HBM traffic the fusion targets;
  * bit-equality of the resulting protection (both engines must land the
    same parity / checksums / digest).

Three scenarios: `overwrite` (full-state commit, the train hot path),
`verify` (verify-at-open + commit), `decode` (dirty-page commit, the
serving hot path — the seed engine re-flattens the full state and, for
MLP, re-checksums the full row for its digest; the fused engine splices
the cached row and sweeps only the dirty pages).
"""
from __future__ import annotations

import sys

try:
    from benchmarks import _bootstrap  # noqa: F401  (run as a module)
except ImportError:
    import _bootstrap                  # noqa: F401  (run as a script)

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from benchmarks import common
from repro.configs.base import ProtectConfig
from repro.core import checksum as ck
from repro.core import layout as layout_mod
from repro.core import parity as parity_mod
from repro.core import redolog
from repro.core.txn import Mode, Protector, ProtectedState, tree_select
from repro.pool import Pool

U32 = jnp.uint32

SIZES = [256 * 1024, 1024 * 1024, 4 * 1024 * 1024]
MODES = [Mode.MLP, Mode.MLPC]


def make_unfused_commit(p: Protector, dirty_pages=None,
                        verify_old: bool = False):
    """The seed commit pipeline: independent sweeps, no row cache."""
    lo, ax, mode = p.layout, p.data_axis, p.mode
    # the seed engine predates the syndrome stack and maintains S_0 only
    assert p.redundancy == 1, \
        "the unfused baseline models the single-parity seed engine"

    def _protect(state_old, synd, cksums, state_new, canary_ok):
        # the seed engine predates the syndrome stack: it maintains the
        # single XOR parity, i.e. the stack's S_0 plane (r = 1 here)
        parity_l = p._unpack(synd)[0] if synd is not None else None
        cksums_l = p._unpack(cksums) if cksums is not None else None
        row_new = layout_mod.flatten_row(lo, state_new)
        ok = canary_ok
        row_old = None
        if mode.has_parity or verify_old:
            row_old = layout_mod.flatten_row(lo, state_old)
        if verify_old and cksums_l is not None:
            bad = ck.verify_blocks(row_old, cksums_l, lo.block_words)
            ok = jnp.logical_and(ok, jnp.logical_not(jnp.any(bad)))
            ok = lax.pmin(ok.astype(jnp.int32), ax) > 0
        outs = {"ok": ok}
        if mode.has_parity:
            new_parity = parity_mod.hybrid_update(
                row_old, row_new, parity_l, lo, ax,
                dirty_page_idx=dirty_pages,
                threshold_fraction=p.hybrid_threshold)
            outs["synd"] = p._pack(
                jnp.where(ok, new_parity, parity_l)[None])
        if mode.has_cksums:
            if dirty_pages is not None and len(dirty_pages) < lo.n_blocks:
                idx = jnp.asarray(np.asarray(dirty_pages), jnp.int32)
                pages = parity_mod.gather_pages(row_new, idx,
                                                lo.block_words)
                new_ck = ck.update_blocks(cksums_l, pages, idx,
                                          lo.block_words)
            else:
                new_ck = ck.block_checksums(row_new, lo.block_words)
            outs["cksums"] = p._pack(jnp.where(ok, new_ck, cksums_l))
            outs["digest"] = p._pack(ck.combine(new_ck, lo.block_words))
        elif mode.has_parity:
            outs["digest"] = p._pack(ck.digest(row_new, lo.block_words))
        return outs

    out_specs = {"ok": P()}
    if mode.has_parity:
        out_specs["synd"] = p._zone_spec
        out_specs["digest"] = p._zone_spec
    if mode.has_cksums:
        out_specs["cksums"] = p._zone_spec
        out_specs["digest"] = p._zone_spec
    protect = p._smap(
        _protect,
        in_specs=(p.state_specs, p._zone_spec, p._zone_spec,
                  p.state_specs, P()),
        out_specs=out_specs)

    def commit(prot: ProtectedState, state_new, *, rng_key=None,
               canary_ok=True):
        step = prot.step + U32(1)
        canary_ok = jnp.asarray(canary_ok, bool)
        outs = protect(prot.state, prot.synd, prot.cksums, state_new,
                       canary_ok)
        ok = outs["ok"]
        new_digest = outs.get("digest", prot.digest)
        log = prot.log
        if mode.has_log:
            if rng_key is None:
                rng_key = jax.random.PRNGKey(0)
            log = redolog.append(prot.log, step, 0, rng_key,
                                 new_digest.reshape(-1, 2)[0])
            log = tree_select(ok, redolog.commit_mark(log, step), log)
        new_state = tree_select(ok, state_new, prot.state)
        return ProtectedState(
            state=new_state, synd=outs.get("synd", prot.synd),
            cksums=outs.get("cksums", prot.cksums), digest=new_digest,
            replica=prot.replica, log=log,
            step=jnp.where(ok, step, prot.step), row=prot.row), ok

    return commit


def _interleaved(fns: dict, warmup: int = 2, reps: int = 10) -> dict:
    """Median wall time per engine, reps interleaved A/B/A/B."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    times = {name: [] for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts)) for name, ts in times.items()}


def _xla_bytes(fn, *args, **kw) -> float:
    """XLA 'bytes accessed' of the compiled program (deterministic).

    Already-jitted callables (which may carry static/donated argnums)
    are lowered as-is rather than re-wrapped.
    """
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    cost = jitted.lower(*args, **kw).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def _leafy_state(n_bytes: int, mesh, n_leaves: int = 16):
    """Multi-leaf state (params/moments/cache-like) for the decode case."""
    from jax.sharding import NamedSharding
    g = mesh.shape["data"]
    per = max(n_bytes // 4 // n_leaves, g)
    per = (per + g - 1) // g * g
    specs = {f"l{i:02d}": P("data") for i in range(n_leaves)}
    state = {f"l{i:02d}": (jnp.arange(per, dtype=jnp.uint32) % 997
                           + i).astype(jnp.float32)
             for i in range(n_leaves)}
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(jax.device_put, state, sh), specs


def _check_equal(pr_u, pr_f):
    np.testing.assert_array_equal(np.asarray(pr_u.parity),
                                  np.asarray(pr_f.parity))
    np.testing.assert_array_equal(np.asarray(pr_u.digest),
                                  np.asarray(pr_f.digest))
    if pr_u.cksums is not None:
        np.testing.assert_array_equal(np.asarray(pr_u.cksums),
                                      np.asarray(pr_f.cksums))


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    sizes = SIZES[:2] if quick else SIZES
    reps = 10 if quick else 25
    key = jax.random.PRNGKey(0)
    rows = []
    for size in sizes:
        for mode in MODES:
            scen = {}
            # -- overwrite / verify: full-state commit ----------------------
            state, specs = common.state_of_bytes(size, mesh)
            new_state = jax.tree.map(lambda x: x * 1.01, state)
            cfg = ProtectConfig(mode=mode.value, block_words=64)
            pool = Pool.open(state, specs, mesh=mesh, config=cfg,
                             donate=False)
            p = pool.protector
            prot = pool.prot
            for name, vo in (("overwrite", False), ("verify", True)):
                fused = jax.jit(p.make_commit(verify_old=vo))
                unfused = jax.jit(make_unfused_commit(p, verify_old=vo))
                scen[name] = (fused, unfused, prot, new_state)
            # -- decode: dirty-page commit on a leafy state -----------------
            lstate, lspecs = _leafy_state(size, mesh)
            lpool = Pool.open(lstate, lspecs, mesh=mesh, config=cfg,
                              donate=False)
            pl_ = lpool.protector
            lprot = lpool.prot
            dirty = layout_mod.leaf_pages(pl_.layout, 3).tolist()
            lnew = dict(lstate)
            lnew["l03"] = lstate["l03"] * 1.01
            scen["decode"] = (
                jax.jit(pl_.make_commit(dirty_pages=dirty)),
                jax.jit(make_unfused_commit(pl_, dirty_pages=dirty)),
                lprot, lnew)
            for name, (fused, unfused, pr, ns) in scen.items():
                med = _interleaved(
                    {"unfused": lambda: unfused(pr, ns, rng_key=key),
                     "fused": lambda: fused(pr, ns, rng_key=key)},
                    reps=reps)
                pr_u, ok_u = unfused(pr, ns, rng_key=key)
                pr_f, ok_f = fused(pr, ns, rng_key=key)
                assert bool(ok_u) and bool(ok_f), (name, mode)
                _check_equal(pr_u, pr_f)    # identical protection bits
                rows.append({
                    "size_B": size, "mode": mode.value, "scenario": name,
                    "unfused_us": round(med["unfused"] * 1e6, 1),
                    "fused_us": round(med["fused"] * 1e6, 1),
                    "speedup": round(med["unfused"] / med["fused"], 2),
                    "unfused_MB": round(_xla_bytes(
                        unfused, pr, ns, rng_key=key) / 2**20, 2),
                    "fused_MB": round(_xla_bytes(
                        fused, pr, ns, rng_key=key) / 2**20, 2),
                })
    common.print_table(
        "commit engine A/B (interleaved reps; MB = XLA bytes accessed)",
        rows, ["size_B", "mode", "scenario", "unfused_us", "fused_us",
               "speedup", "unfused_MB", "fused_MB"])
    out = {"rows": rows, "reps": reps}
    common.save_result("commit_sweep", out)
    return out


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
