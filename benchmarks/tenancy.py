"""Multi-tenant PoolGroup benchmark (BENCH_commit.json §tenancy).

Two records over one sync mlpc cohort (same shape x config tenants, so
they share one Protector and one compiled-program cache):

  * throughput — N in {1, 8, 64} tenants committing one wave through
    the batched stacked program (ONE dispatch) vs the looped per-pool
    baseline (N dispatches).  Both paths run inside the SAME PoolGroup
    (`batched=False` forces the loop), so protector state and compiled
    programs are shared and the A/B isolates dispatch count, not
    compile count; the two sides are interleaved rep-by-rep in one run
    so ambient load cancels.  The gate checks the structural direction
    (batched aggregate commits/s >= looped at N >= 8) — the batch is
    bit-identical to the loop by tests/test_tenancy.py, so this is
    pure dispatch-amortization accounting.

  * interference — 8 tenants; the SAME all-tenant batched commit wave
    is timed with and without a scrub storm on tenant 0 between waves
    (shared ScrubScheduler under a one-pool page budget, so the
    scheduler keeps serving the hot tenant).  A/B waves interleave;
    the storm-side p99 over the baseline p99 gates as a pathology
    bound — scheduler pressure may cost scrub time, never neighbor
    commit tails.

Quick mode keeps the full N in {1, 8, 64} column set (the N=64
ordering is the acceptance gate) and trims only per-tenant state size
and rep counts.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common


def _pct(ts, q):
    return float(np.percentile(np.asarray(ts, dtype=np.float64), q) * 1e3)


def _build_group(mesh, n, cfg, state_bytes, weights=None):
    import jax

    from repro.tenancy import PoolGroup

    grp = PoolGroup(mesh)
    base, specs = common.state_of_bytes(state_bytes, mesh)
    updates = {}
    for t in range(n):
        st = jax.tree.map(lambda x, t=t: x + np.float32(t + 1), base)
        grp.admit(f"t{t}", st, specs, config=cfg,
                  weight=(weights or {}).get(f"t{t}", 1))
        # a fixed candidate per tenant: committing it repeatedly is
        # idempotent on the protected bytes, so reps time pure dispatch
        updates[f"t{t}"] = jax.tree.map(
            lambda x, t=t: x * np.float32(1.5) + np.float32(t), st)
    return grp, updates


def _throughput(mesh, cfg, n, state_bytes, reps):
    import jax

    grp, updates = _build_group(mesh, n, cfg, state_bytes)
    # warm both programs (batched stack + per-pool loop)
    for _ in range(2):
        jax.block_until_ready(grp.commit(updates))
        jax.block_until_ready(grp.commit(updates, batched=False))
    tb, tl = [], []
    for _ in range(reps):                      # interleaved A/B
        t0 = time.perf_counter()
        jax.block_until_ready(grp.commit(updates))
        tb.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(grp.commit(updates, batched=False))
        tl.append(time.perf_counter() - t0)
    med_b = float(np.median(tb))
    med_l = float(np.median(tl))
    return {
        "n_tenants": n,
        "state_B": state_bytes,
        "batched_ms": med_b * 1e3,
        "looped_ms": med_l * 1e3,
        "batched_commits_per_s": n / med_b,
        "looped_commits_per_s": n / med_l,
        "speedup": med_l / med_b,
        "reps": reps,
    }


def _interference(mesh, cfg, state_bytes, waves):
    import jax

    n = 8
    # one-pool page budget: every tick the scheduler serves (about) one
    # tenant, and the weight skew keeps it coming back to tenant 0
    grp, updates = _build_group(mesh, n, cfg, state_bytes,
                                weights={"t0": 16})
    budget = grp["t0"].pool.scrubber.pool_pages
    for _ in range(2):
        jax.block_until_ready(grp.commit(updates))
    base_t, storm_t = [], []
    for _ in range(waves):                     # interleaved A/B waves
        t0 = time.perf_counter()
        jax.block_until_ready(grp.commit(updates))
        base_t.append(time.perf_counter() - t0)
        grp.scrub_tick(page_budget=budget)     # storm pressure on t0
        t0 = time.perf_counter()
        jax.block_until_ready(grp.commit(updates))
        storm_t.append(time.perf_counter() - t0)
    return {
        "n_tenants": n,
        "waves": waves,
        "scrub_pages_per_tick": budget,
        "base_p50_ms": _pct(base_t, 50),
        "base_p99_ms": _pct(base_t, 99),
        "storm_p50_ms": _pct(storm_t, 50),
        "storm_p99_ms": _pct(storm_t, 99),
        "p99_ratio": _pct(storm_t, 99) / _pct(base_t, 99),
    }


def run(quick: bool = False) -> dict:
    from repro.configs.base import ProtectConfig

    mesh = common.get_mesh(data=4, model=2)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=1,
                        block_words=256)
    state_bytes = 16 << 10 if quick else 64 << 10
    reps = 8 if quick else 15
    sizes = [1, 8, 64]        # the N=64 ordering is the acceptance gate,
    rows = [_throughput(mesh, cfg, n, state_bytes, reps) for n in sizes]
    interference = _interference(mesh, cfg, state_bytes,
                                 waves=24 if quick else 60)

    fmt = lambda v: round(v, 2) if isinstance(v, float) else v  # noqa: E731
    common.print_table(
        "PoolGroup throughput: batched stacked program vs per-pool loop",
        [{k: fmt(v) for k, v in r.items()} for r in rows],
        ["n_tenants", "state_B", "batched_ms", "looped_ms",
         "batched_commits_per_s", "looped_commits_per_s", "speedup"])
    common.print_table(
        "PoolGroup interference: neighbor commit wall under scrub storm",
        [{k: fmt(v) for k, v in interference.items()}],
        ["n_tenants", "waves", "scrub_pages_per_tick", "base_p50_ms",
         "base_p99_ms", "storm_p50_ms", "storm_p99_ms", "p99_ratio"])

    out = {"throughput": rows, "interference": interference}
    common.save_result("tenancy", out)
    return out
