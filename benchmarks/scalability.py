"""Paper Fig. 4 + §3.5 — scalability of concurrent parity updates and the
hybrid small/large threshold.

The paper's threads are SPMD ranks here: a G-rank zone commits G updates
concurrently in one SPMD program (every rank is a committer — the "multi-
threaded random overwrite" workload).  Two axes:

  * zone width G (1..8 ranks) x update size — throughput of concurrent
    commits (Fig. 4's thread axis),
  * dirty fraction sweep at fixed G — the patch path (incremental parity,
    'atomic XOR' analog) vs the bulk path (full rebuild, 'column lock'
    analog), locating the crossover the paper puts at 512 B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks import common
from repro.configs.base import ProtectConfig
from repro.core import layout as layout_mod
from repro.pool import Pool


def concurrent_commits(quick: bool) -> list:
    rows = []
    sizes = [4096, 64 * 1024] if quick else [4096, 64 * 1024, 1024 * 1024]
    for g in (2, 4, 8):
        mesh = jax.make_mesh((g, 1), ("data", "model"))
        for size in sizes:
            state, specs = common.state_of_bytes(size * g, mesh)
            pool = Pool.open(state, specs, mesh=mesh,
                             config=ProtectConfig(mode="mlpc",
                                                  block_words=64),
                             donate=False)
            prot = pool.prot
            commit = jax.jit(pool.protector.make_commit())
            new_state = jax.tree.map(lambda x: x * 1.01, state)
            t = common.timeit(commit, prot, new_state,
                              rng_key=jax.random.PRNGKey(0),
                              reps=(5 if quick else 12))
            rows.append({
                "G": g, "update_B_per_rank": size,
                "commit_us": round(t["median_s"] * 1e6, 1),
                "zone_MBps": round(size * g / t["median_s"] / 1e6, 1),
            })
    common.print_table("concurrent committers (G ranks, one zone)", rows,
                       ["G", "update_B_per_rank", "commit_us", "zone_MBps"])
    return rows


def hybrid_sweep(quick: bool) -> list:
    """Dirty-fraction sweep: patch path vs bulk path latency.

    Both paths pay the O(state) row flatten; the differential is in the
    parity traffic — k pages XOR-all-reduced vs a full-row reduce-scatter —
    so the state must be large enough for that traffic to show over
    dispatch noise.
    """
    mesh = common.get_mesh()
    size = 4 * 1024 * 1024 if quick else 32 * 1024 * 1024
    state, specs = common.state_of_bytes(size, mesh)
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=1024),
                     donate=False)
    p = pool.protector
    prot = pool.prot
    n_pages = p.layout.n_blocks
    rows = []
    fracs = [0.004, 0.02, 0.1, 0.5, 1.0]
    for frac in fracs:
        k = max(1, int(frac * n_pages))
        dirty = list(range(k))
        # force patch path
        p.hybrid_threshold = 1.1
        commit_patch = jax.jit(p.make_commit(dirty_pages=dirty))
        # force bulk path
        p.hybrid_threshold = 0.0
        commit_bulk = jax.jit(p.make_commit(dirty_pages=dirty))
        new_state = jax.tree.map(lambda x: x * 1.01, state)
        tp = common.timeit(commit_patch, prot, new_state,
                           rng_key=jax.random.PRNGKey(0),
                           reps=(8 if quick else 15))
        tb = common.timeit(commit_bulk, prot, new_state,
                           rng_key=jax.random.PRNGKey(0),
                           reps=(8 if quick else 15))
        rows.append({
            "dirty_frac": frac, "dirty_pages": k,
            "patch_us": round(tp["median_s"] * 1e6, 1),
            "bulk_us": round(tb["median_s"] * 1e6, 1),
            "patch_wins": bool(tp["median_s"] < tb["median_s"]),
        })
    common.print_table("hybrid parity: patch vs bulk by dirty fraction",
                       rows, ["dirty_frac", "dirty_pages", "patch_us",
                              "bulk_us", "patch_wins"])
    return rows


def run(quick: bool = False) -> dict:
    rows_c = concurrent_commits(quick)
    rows_h = hybrid_sweep(quick)
    # reproduction target: a crossover exists — the patch path wins at small
    # dirty fractions, the bulk path at (or near) full-state updates
    assert rows_h[0]["patch_wins"], "patch path must win for tiny updates"
    assert not rows_h[-1]["patch_wins"], \
        "bulk path must win for full-state updates"
    payload = {"concurrent": rows_c, "hybrid": rows_h}
    common.save_result("scalability", payload)
    return payload


if __name__ == "__main__":
    run()
