"""Paper Fig. 5 + Table 3 — application-level benchmarks.

The paper rewrites six PMDK key-value structures (ctree/rbtree/btree/
skiplist/rtree/hashmap) against Pangolin and measures insert/remove
throughput under each mode.  The application workload here is training:
six reduced architectures (one per family — the analog of six data
structures with diverse object sizes and access patterns) run protected
train steps under each mode; the metric is steps/s.

Reproduction target (DESIGN.md §6): MLP throughput within ~±30% of REPLICA
(the paper reports 98% on average) while using 1/G the protection storage,
and the full ladder ordering none >= ML >= MLP >= MLPC.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.configs.base import ProtectConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core.txn import Mode
from repro.runtime.trainer import Trainer

ARCHS = ["qwen2-0.5b", "glm4-9b", "moonshot-v1-16b-a3b", "chameleon-34b",
         "recurrentgemma-2b", "xlstm-1.3b"]
MODES = ["none", "ml", "mlp", "mlpc", "replica"]


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    archs = ARCHS[:2] if quick else ARCHS
    n_steps = 4 if quick else 8
    rows = []
    for arch in archs:
        cfg = get_config(arch, reduced=True)
        for mode in MODES:
            t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                         total_steps=1000),
                        ProtectConfig(mode=mode, block_words=64),
                        mesh, seq_len=32, global_batch=8)
            t.initialize()
            t.run(2)        # warmup / compile
            import time
            t0 = time.perf_counter()
            outs = t.run(n_steps)
            dt = time.perf_counter() - t0
            assert all(o["committed"] for o in outs)
            rows.append({
                "arch": arch, "mode": mode,
                "steps_per_s": round(n_steps / dt, 2),
                "state_KiB": round(
                    t.protector.layout.payload_words * 4 / 1024, 1),
                "loss": round(outs[-1]["loss"], 3),
            })
    common.print_table("protected training throughput (reduced archs)",
                       rows, ["arch", "mode", "steps_per_s", "state_KiB",
                              "loss"])
    summary = {}
    for arch in archs:
        by = {r["mode"]: r["steps_per_s"] for r in rows if r["arch"] == arch}
        summary[arch] = {
            "mlp_vs_replica": round(by["mlp"] / by["replica"], 2),
            "mlpc_vs_none": round(by["mlpc"] / by["none"], 2),
        }
    print("summary:", summary)
    common.save_result("app_kv", {"rows": rows, "summary": summary})
    return {"rows": rows, "summary": summary}


if __name__ == "__main__":
    run()
