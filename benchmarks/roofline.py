"""Beyond-paper — roofline table from the compiled dry-run artifacts,
plus the commit-sweep achieved-bytes/s measurement (ISSUE 6).

Part 1 reads the dry-run JSON (produced by `python -m
repro.launch.dryrun`) and emits the three-term roofline per (arch x
workload x mesh): compute / memory / collective seconds, the binding
term, and the useful-FLOP ratio (6ND / HLO FLOPs).  This is the
§Roofline table of EXPERIMENTS.md.

Part 2 measures the commit sweep itself against the memory roofline:
the streamed single-dispatch syndrome pipeline
(`ops.fused_commit_s_stream` — all r weighted planes, checksums and the
row digest from ONE pass over the dirty row) against the flat baseline
cadence it replaced (delta+checksum sweep, then the stacked weighting
pass re-reading the delta, then the digest combine — three dispatches,
two extra delta-row trips).  Both paths are checked bit-identical, then
compared on

  * XLA compiled bytes accessed (deterministic — the streamed program
    must touch strictly fewer bytes than the flat cadence), and the
    bandwidth-efficiency fraction `useful_frac` = useful bytes / bytes
    accessed (useful bytes = the roofline minimum: read old+new once,
    write the r syndrome planes once) — the deterministic form of
    "fraction of the streamed bytes/s that is useful", which is what
    the gate compares (the streamed path is strictly higher: it never
    re-reads the dirty delta, whatever the redundancy);
  * interleaved wall time -> achieved useful bytes/s as a fraction of
    the `launch.hlo_analysis.HBM_BW` peak (recorded for EXPERIMENTS.md
    §Roofline; wall cells gate pathology-only, per the standing rule —
    at the 1 MB point the identical GF(2^32) clmul work dominates both
    paths, so wall margins sit inside ambient noise on a shared box).

On CPU the ops dispatch routes to the jnp oracles, so the A/B measures
the dispatch/fusion structure the streaming refactor targets; on TPU
the identical harness routes to the Pallas kernels.  Recorded as
BENCH_commit.json §roofline and gated by scripts/bench_gate.py
(record-presence, streamed-bytes <= flat, streamed useful_frac above
flat at the 1 MB pool, wall pathology).
"""
from __future__ import annotations

import json
import os
import time

from benchmarks import common

# the commit-sweep A/B runs at the full stack height the streamed kernel
# amortizes (r=3: P, Q and one higher Vandermonde row from one row read)
SWEEP_R = 3
SWEEP_BLOCK_WORDS = 1024            # 4 KB pages (paper default)
SWEEP_CHUNK_BLOCKS = 16             # 64 KB double-buffered chunks
SWEEP_SIZES = [256 * 1024, 1024 * 1024]

DEFAULT_PATHS = [
    os.path.join(os.path.dirname(__file__), "..", "scratch",
                 "dryrun_v2.json"),
    os.path.join(os.path.dirname(__file__), "..", "scratch",
                 "dryrun_all.json"),
    "dryrun_results.json",
]


def load_records(path: str | None = None) -> list:
    paths = [path] if path else DEFAULT_PATHS
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                return json.load(f)
    return []


def _xla_bytes(jitted, *args) -> float:
    cost = jitted.lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


def commit_sweep_rows(quick: bool = False) -> list:
    """Streamed vs flat commit sweep against the HBM roofline."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import checksum as cksum
    from repro.core import gf
    from repro.kernels import ops
    from repro.launch.hlo_analysis import HBM_BW

    r, bw = SWEEP_R, SWEEP_BLOCK_WORDS
    reps = 30 if quick else 60
    coeffs = jnp.asarray([gf.pow_g_int(k * 3) for k in range(r)],
                         jnp.uint32)
    rows = []
    for size in SWEEP_SIZES:
        n = size // 4 // bw
        cb = max(1, min(SWEEP_CHUNK_BLOCKS, n))
        rng = np.random.default_rng(size)
        old = jnp.asarray(rng.integers(0, 2**32, (n, bw), dtype=np.uint32))
        new = jnp.asarray(rng.integers(0, 2**32, (n, bw), dtype=np.uint32))

        # flat baseline: the pre-streaming cadence — the delta+checksum
        # sweep materializes the delta, the stacked weighting pass
        # re-reads it, and the digest combines separately (three
        # dispatches, two extra delta-row trips)
        flat_commit = jax.jit(lambda o, nw: ops.fused_commit(o, nw))
        flat_scale = jax.jit(lambda d: ops.syndrome_scale(d, coeffs))
        flat_digest = jax.jit(lambda c: cksum.combine(c, bw))

        def run_flat():
            d, c = flat_commit(old, new)
            return flat_scale(d), c, flat_digest(c)

        # streamed pipeline: one dispatch emits every weighted plane,
        # the checksum terms AND the loop-carried digest from a single
        # pass over (old, new)
        stream = jax.jit(lambda o, nw: ops.fused_commit_s_stream(
            o, nw, coeffs, chunk_blocks=cb))

        def run_stream():
            return stream(old, new)

        # bit-identity before timing: both paths land the same planes,
        # checksums and digest
        sd_f, ck_f, dig_f = run_flat()
        sd_s, ck_s, dig_s = run_stream()
        np.testing.assert_array_equal(np.asarray(sd_f), np.asarray(sd_s))
        np.testing.assert_array_equal(np.asarray(ck_f), np.asarray(ck_s))
        np.testing.assert_array_equal(np.asarray(dig_f), np.asarray(dig_s))

        fns = {"flat": run_flat, "stream": run_stream}
        for fn in fns.values():
            for _ in range(3):
                jax.block_until_ready(fn())
        times = {name: [] for name in fns}
        for _ in range(reps):
            for name, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times[name].append(time.perf_counter() - t0)

        useful = (2 + r) * n * bw * 4     # read old+new, write r planes
        xla = {"flat": (_xla_bytes(flat_commit, old, new)
                        + _xla_bytes(flat_scale, sd_f[0])
                        + _xla_bytes(flat_digest, ck_f)),
               "stream": _xla_bytes(stream, old, new)}
        for name in fns:
            # min over interleaved reps: the noise-robust estimate of
            # the program's intrinsic time (ambient load only ever ADDS
            # time, so the minimum is the cleanest sample — medians on
            # this box still swing past the structural margin)
            wall = float(np.min(times[name]))
            achieved = useful / wall
            rows.append({
                "size_B": size, "path": name, "r": r,
                "wall_us": round(wall * 1e6, 1),
                "xla_MB": round(xla[name] / 2**20, 2),
                "useful_MB": round(useful / 2**20, 2),
                "useful_frac": round(useful / xla[name], 4),
                "achieved_GBps": round(achieved / 1e9, 2),
                "frac_of_peak": round(achieved / HBM_BW, 5),
            })
    return rows


def run(quick: bool = False, path: str | None = None) -> dict:
    sweep = commit_sweep_rows(quick=quick)
    common.print_table(
        "commit-sweep roofline (streamed vs flat; interleaved reps; "
        "frac_of_peak = useful bytes/s over HBM_BW)",
        sweep, ["size_B", "path", "r", "wall_us", "xla_MB", "useful_MB",
                "useful_frac", "achieved_GBps", "frac_of_peak"])
    recs = load_records(path)
    if not recs:
        print("roofline: no dry-run results found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        common.save_result("roofline", {"commit_sweep": sweep})
        return {"rows": [], "commit_sweep": sweep}
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append({
            "arch": r["arch"], "workload": r["workload"], "mesh": r["mesh"],
            "compute_ms": round(ro["compute_s"] * 1e3, 2),
            "memory_ms": round(ro["memory_s"] * 1e3, 2),
            "coll_ms": round(ro["collective_s"] * 1e3, 2),
            "bound": ro["bound"],
            "roofline_frac": round(ro["compute_s"] / dom, 3) if dom else 0.0,
            "useful_ratio": round(ro.get("useful_ratio", 0.0), 3),
            "GiB_per_dev": round(
                r["memory"]["total_bytes_per_device"] / 2**30, 2),
        })
    rows.sort(key=lambda x: (x["workload"], x["arch"], x["mesh"]))
    common.print_table("roofline terms per cell (from compiled dry-run)",
                       rows, ["arch", "workload", "mesh", "compute_ms",
                              "memory_ms", "coll_ms", "bound",
                              "roofline_frac", "useful_ratio",
                              "GiB_per_dev"])
    common.save_result("roofline", {"rows": rows, "commit_sweep": sweep})
    return {"rows": rows, "commit_sweep": sweep}


if __name__ == "__main__":
    try:
        from benchmarks import _bootstrap  # noqa: F401
    except ImportError:
        import _bootstrap                  # noqa: F401
    run()
