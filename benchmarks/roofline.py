"""Beyond-paper — roofline table from the compiled dry-run artifacts.

Reads the dry-run JSON (produced by `python -m repro.launch.dryrun`) and
emits the three-term roofline per (arch x workload x mesh): compute /
memory / collective seconds, the binding term, and the useful-FLOP ratio
(6ND / HLO FLOPs).  This is the §Roofline table of EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from benchmarks import common

DEFAULT_PATHS = [
    os.path.join(os.path.dirname(__file__), "..", "scratch",
                 "dryrun_v2.json"),
    os.path.join(os.path.dirname(__file__), "..", "scratch",
                 "dryrun_all.json"),
    "dryrun_results.json",
]


def load_records(path: str | None = None) -> list:
    paths = [path] if path else DEFAULT_PATHS
    for p in paths:
        if p and os.path.exists(p):
            with open(p) as f:
                return json.load(f)
    return []


def run(quick: bool = False, path: str | None = None) -> dict:
    recs = load_records(path)
    if not recs:
        print("roofline: no dry-run results found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return {"rows": []}
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        ro = r["roofline"]
        dom = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append({
            "arch": r["arch"], "workload": r["workload"], "mesh": r["mesh"],
            "compute_ms": round(ro["compute_s"] * 1e3, 2),
            "memory_ms": round(ro["memory_s"] * 1e3, 2),
            "coll_ms": round(ro["collective_s"] * 1e3, 2),
            "bound": ro["bound"],
            "roofline_frac": round(ro["compute_s"] / dom, 3) if dom else 0.0,
            "useful_ratio": round(ro.get("useful_ratio", 0.0), 3),
            "GiB_per_dev": round(
                r["memory"]["total_bytes_per_device"] / 2**30, 2),
        })
    rows.sort(key=lambda x: (x["workload"], x["arch"], x["mesh"]))
    common.print_table("roofline terms per cell (from compiled dry-run)",
                       rows, ["arch", "workload", "mesh", "compute_ms",
                              "memory_ms", "coll_ms", "bound",
                              "roofline_frac", "useful_ratio",
                              "GiB_per_dev"])
    common.save_result("roofline", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
