"""Async commit pipeline benchmark (BENCH_commit.json §async).

The depth sweep: one synchronous-engine mlpc r=2 pool per ring depth
d in {1, 2, 4, 8} (all sharing ONE Protector, so every depth runs the
very same compiled commit program — the A/B isolates resolution
policy, not compile luck).  Each rep times a burst of N chained
commits (state t+1 is computed from state t by a jitted update, so
the device chain is real) followed by a `drain()`:

  * depth 1 resolves every verdict before the next dispatch — the
    host blocks for the full commit program N times (the classic
    resolve-per-commit loop).
  * depth d > 1 dispatches up to d commits ahead of resolution; the
    host's dispatch work (program launch, ticket bookkeeping) overlaps
    the device's in-flight commit programs, and verdicts resolve as
    their scalars land.

Reps interleave across all depths (one rep = one burst per depth,
back to back) so ambient load cancels; the wall medians give
commits/s per depth, and each pool's `pool_commit_resolve_ms`
histogram gives the resolve-latency tail the ring introduces.  The
gate checks the structural direction — best depth >= 4 aggregate
commits/s at least depth=1's — plus a resolve-p99 pathology bound;
bit-identity of the drained pipeline against the synchronous engine
is tests/test_pipeline.py's job, so this file measures only wall.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common

DEPTHS = (1, 2, 4, 8)


def _build_pools(mesh, cfg_base, state_bytes, protector=None):
    import dataclasses

    import jax

    from repro.pool import Pool

    state, specs = common.state_of_bytes(state_bytes, mesh)
    pools = {}
    for d in DEPTHS:
        cfg = dataclasses.replace(cfg_base, pipeline_depth=d)
        # donate=False: the burst re-reads pool.state per commit
        pool = Pool.open(jax.tree.map(lambda x: x + np.float32(0), state),
                         specs, mesh=mesh, config=cfg, donate=False,
                         protector=protector)
        protector = pool.protector
        pools[d] = pool
    return pools


def _burst(pool, step_fn, n_commits) -> float:
    import jax
    import jax.numpy as jnp

    t0 = time.perf_counter()
    for i in range(n_commits):
        pool.commit_async(step_fn(pool.state, jnp.float32(i * 1e-6)))
    pool.drain()
    jax.block_until_ready(pool.prot.state)
    return time.perf_counter() - t0


def run(quick: bool = False):
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ProtectConfig

    mesh = common.get_mesh(4, 2)
    state_bytes = (1 << 15) if quick else (1 << 17)
    n_commits = 8 if quick else 16
    reps = 3 if quick else 6
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=1,
                        scrub_period=0)
    step_fn = jax.jit(
        lambda s, c: jax.tree.map(
            lambda x: x * jnp.float32(1.0000001) + c, s))

    # warm the shared commit program on a scratch pool FIRST, so no
    # measured pool's resolve histogram carries compile wall; the
    # measured pools (built after, sharing the warmed Protector) then
    # get one tiny burst each for their own ticket/drain plumbing
    from repro.pool import Pool
    state, specs = common.state_of_bytes(state_bytes, mesh)
    scratch = Pool.open(state, specs, mesh=mesh, config=cfg,
                        donate=False)
    for _ in range(3):
        _burst(scratch, step_fn, 2)
    pools = _build_pools(mesh, cfg, state_bytes,
                         protector=scratch.protector)
    for pool in pools.values():
        _burst(pool, step_fn, 2)

    walls = {d: [] for d in DEPTHS}
    for _ in range(reps):                      # interleaved A/B
        for d, pool in pools.items():
            walls[d].append(_burst(pool, step_fn, n_commits))

    rows = []
    for d, pool in pools.items():
        med = float(np.median(walls[d]))
        rs = pool.metrics.histogram("pool_commit_resolve_ms").summary()
        rows.append({
            "depth": d,
            "commits": n_commits,
            "state_B": state_bytes,
            "wall_ms": med * 1e3,
            "commits_per_s": n_commits / med,
            "resolve_p50_ms": rs["p50"],
            "resolve_p99_ms": rs["p99"],
            "reps": reps,
        })
    base = rows[0]["commits_per_s"]
    for r in rows:
        r["speedup_vs_depth1"] = r["commits_per_s"] / base

    common.print_table(
        "async commit pipeline: ring depth sweep (sync mlpc r=2)",
        rows, ["depth", "wall_ms", "commits_per_s",
               "speedup_vs_depth1", "resolve_p50_ms", "resolve_p99_ms"])
    out = {"depths": rows}
    common.save_result("async_pipeline", out)
    return out


if __name__ == "__main__":
    import os

    if "--xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8")
    run(quick=True)
