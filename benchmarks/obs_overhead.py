"""§obs — the telemetry plane's zero-overhead proof (A/B, both engines).

The observability tentpole's hard requirement: wiring the metrics
registry + tracer into a Pool must cost ZERO compiled bytes (publication
is host-side arithmetic, never a jit wrapper or a device fetch on the
commit path) and bounded host dispatch wall.  Two measurements:

  bytes — lower the commit program an *instrumented* pool routes
    through and the same program off a *bare* engine (constructed
    directly, no registry anywhere) and compare XLA "bytes accessed".
    Deterministic; the gate requires the delta to be exactly zero.
      * sync (W=1):  pool.commit_program()  vs  jax.jit(p.make_commit())
      * deferred:    the pool engine's jitted step program  vs  a
                     standalone DeferredProtector's, same args.

  wall — interleaved min-of-batches commit *dispatch* wall, publication
    enabled vs stubbed on an otherwise identical pool (the engine/
    scrubber registries detached, the cached commit handles no-op'd).
    The two perf_counter reads stay in both arms — they are the floor,
    not the plane.  Interleaving + min-of-batches squeezes scheduler
    noise; the gate treats the percentage as a pathology bound, not a
    microbenchmark (wall on a shared box swings; see bench_gate.py).

Record lands in BENCH_commit.json §obs via benchmarks/run.py and gates
in scripts/bench_gate.py: byte_delta == 0 structurally, overhead_pct
within the bound.
"""
from __future__ import annotations

try:
    from benchmarks import _bootstrap  # noqa: F401  (run as a module)
except ImportError:
    import _bootstrap                  # noqa: F401  (run as a script)

import gc
import time

import jax

from benchmarks import common
from repro.configs.base import ProtectConfig
from repro.core.epoch import DeferredProtector
from repro.pool import Pool

SIZE_B = 256 * 1024
DEFERRED_W = 4


class _NullMetric:
    """Publication stub for the bare wall arm (inc/observe no-ops)."""

    def inc(self, n=1):
        pass

    def observe(self, value):
        pass


def _pool(mesh, state, specs, *, window: int) -> Pool:
    return Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", window=window,
                                          block_words=64),
                     donate=False)


def _strip(pool: Pool) -> Pool:
    """Detach every obs publication point from `pool` (the bare arm)."""
    pool._m_commits = _NullMetric()
    pool._m_aborted = _NullMetric()
    pool._m_commit_ms = _NullMetric()
    pool.scrubber.metrics = None
    if pool.engine is not None:
        pool.engine.metrics = None
    return pool


def _bytes_rows(mesh, state, specs, new_state, key) -> list:
    from benchmarks.commit_sweep import _xla_bytes
    rows = []

    # sync engine (W=1): facade-routed program vs the direct protector's
    pool = _pool(mesh, state, specs, window=1)
    instr = _xla_bytes(pool.commit_program(), pool.prot, new_state,
                       rng_key=key)
    bare = _xla_bytes(jax.jit(pool.protector.make_commit()), pool.prot,
                      new_state, rng_key=key)
    rows.append({"engine": "sync", "mode": "mlpc", "window": 1,
                 "instrumented_MB": round(instr / 2**20, 3),
                 "bare_MB": round(bare / 2**20, 3),
                 "byte_delta": instr - bare})

    # deferred engine: the instrumented pool's jitted step program vs a
    # standalone DeferredProtector's (no pool, no registry, same layout)
    pool = _pool(mesh, state, specs, window=DEFERRED_W)
    eng = pool.engine
    est = pool._est
    step_args = (est.prot, est.dirty, est.pending, est.acc, new_state,
                 None, 0, key, True)
    instr = _xla_bytes(
        eng._jitted("step", eng.make_step_commit, n_donated=4,
                    static=(8,)), *step_args)
    bare_eng = DeferredProtector(pool.protector, window=DEFERRED_W,
                                 donate=False, replicate_meta=True)
    bare_est = bare_eng.wrap(est.prot)
    bare = _xla_bytes(
        bare_eng._jitted("step", bare_eng.make_step_commit, n_donated=4,
                         static=(8,)),
        bare_est.prot, bare_est.dirty, bare_est.pending, bare_est.acc,
        new_state, None, 0, key, True)
    rows.append({"engine": "deferred", "mode": "mlpc",
                 "window": DEFERRED_W,
                 "instrumented_MB": round(instr / 2**20, 3),
                 "bare_MB": round(bare / 2**20, 3),
                 "byte_delta": instr - bare})
    return rows


def _wall_ab(mesh, state, specs, new_state, key, *, batch: int,
             reps: int) -> dict:
    """Interleaved per-commit dispatch wall, publication on vs stubbed."""
    pools = {"instrumented": _pool(mesh, state, specs,
                                   window=DEFERRED_W),
             "bare": _strip(_pool(mesh, state, specs,
                                  window=DEFERRED_W))}
    # warm both compile caches (step AND the boundary flush) first
    for p in pools.values():
        for _i in range(DEFERRED_W + 1):
            p.commit(new_state, rng_key=key)
        jax.block_until_ready(p.state)
    best = {name: float("inf") for name in pools}
    # a long benchmark process accretes garbage, and a gen-2 collection
    # landing inside a 16-commit batch swamps a 3% bound — park the
    # collector for the timed region and alternate arm order per rep so
    # neither arm systematically pays first-of-pair costs
    gc.collect()
    gc.disable()
    try:
        order = list(pools.items())
        for rep in range(reps):
            if rep % 2:
                order = order[::-1]         # alternate: cancel pair order
            for name, p in order:           # interleaved: same ambient
                t0 = time.perf_counter()
                for _i in range(batch):
                    p.commit(new_state, rng_key=key)
                dt = time.perf_counter() - t0   # dispatch wall only
                jax.block_until_ready(p.state)  # drain outside the timer
                best[name] = min(best[name], dt)
    finally:
        gc.enable()
    instr_us = best["instrumented"] / batch * 1e6
    bare_us = best["bare"] / batch * 1e6
    return {"batch": batch, "reps": reps,
            "instrumented_us": round(instr_us, 2),
            "bare_us": round(bare_us, 2),
            "overhead_pct": round(
                max(0.0, (instr_us - bare_us) / bare_us * 100), 2)}


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    state, specs = common.state_of_bytes(SIZE_B, mesh)
    new_state = jax.tree.map(lambda x: x * 1.01, state)
    key = jax.random.PRNGKey(0)

    rows = _bytes_rows(mesh, state, specs, new_state, key)
    wall = _wall_ab(mesh, state, specs, new_state, key,
                    batch=16, reps=(12 if quick else 20))

    common.print_table("instrumented vs bare commit program (XLA MB)",
                       rows, ["engine", "mode", "window",
                              "instrumented_MB", "bare_MB", "byte_delta"])
    print(f"dispatch wall: instrumented {wall['instrumented_us']}us vs "
          f"bare {wall['bare_us']}us  (+{wall['overhead_pct']}%, "
          f"min of {wall['reps']}x{wall['batch']} interleaved)")

    for r in rows:
        assert r["byte_delta"] == 0, (
            f"telemetry added compiled bytes on the {r['engine']} "
            f"engine: delta {r['byte_delta']} — publication must stay "
            "host-side")
    out = {"size_B": SIZE_B, "bytes": rows, "wall": wall}
    common.save_result("obs_overhead", out)
    return out


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
