"""Pre-jax environment setup shared by directly-executable benchmarks.

Import this before anything that imports jax:

    try:
        from benchmarks import _bootstrap  # noqa: F401  (run as a module)
    except ImportError:
        import _bootstrap                  # noqa: F401  (run as a script)

Direct execution (`python benchmarks/foo.py`) puts only `benchmarks/` on
sys.path, so the fallback import resolves; this module then adds the repo
root (making `from benchmarks import common` work) and forces the 8-way
host-device mesh the zone collectives need — which must happen before
jax's first import locks the device count.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
