"""Paper Fig. 3 — transaction latency across object sizes and modes.

The paper times alloc / overwrite / free of one object per transaction at
sizes 64 B .. 4 KB.  The analogs here on a protected state of varying size:

  alloc     — init(): build protection for fresh state (checksums+parity),
  overwrite — commit(): full-state update through the protection pipeline,
  free      — commit with zero dirty pages (metadata-only transaction).

Modes ladder per Table 2: pgl(none) -> +ML -> +MLP -> +MLPC, vs REPLICA.
Reproduction targets (DESIGN.md §6): ladder ordering; MLP is the dominant
add-on; MLPC adds little for small states and ~10% at 4 KB-page scale;
MLP within ~±40% of REPLICA while protecting against strictly more.

Engines are reached through the `Pool` facade (the public API); the
low-level programs come off `pool.protector`.  A `facade` record pins
the facade's routed overwrite commit to the direct engine program's
compiled bytes (they must be the *same* program — scripts/bench_gate.py
fails if the facade ever adds bytes).
"""
from __future__ import annotations

try:
    from benchmarks import _bootstrap  # noqa: F401  (run as a module)
except ImportError:
    import _bootstrap                  # noqa: F401  (run as a script)

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs.base import ProtectConfig
from repro.core.txn import Mode
from repro.pool import Pool

# The paper's 64 B..4 KB objects are NVMM-scale; protected *state* here is
# MB-scale (params/moments/caches), so the size axis shifts accordingly —
# small enough that fixed costs show, large enough that CPU dispatch noise
# does not swamp the ladder.
SIZES = [64 * 1024, 256 * 1024, 1024 * 1024, 4 * 1024 * 1024,
         16 * 1024 * 1024]
MODES = [Mode.NONE, Mode.ML, Mode.MLP, Mode.MLPC, Mode.REPLICA]


def run(quick: bool = False) -> dict:
    from benchmarks.commit_sweep import _xla_bytes
    mesh = common.get_mesh()
    sizes = SIZES[:3] if quick else SIZES
    rows = []
    facade_rows = []
    for size in sizes:
        state, specs = common.state_of_bytes(size, mesh)
        new_state = jax.tree.map(lambda x: x * 1.01, state)
        for mode in MODES:
            pool = Pool.open(state, specs, mesh=mesh,
                             config=ProtectConfig(mode=mode.value,
                                                  block_words=64),
                             donate=False)
            p = pool.protector
            init_t = common.timeit(jax.jit(
                lambda s: p.init(s, jit=False)), state,
                reps=(5 if quick else 10))
            prot = pool.prot
            commit = jax.jit(p.make_commit())
            key = jax.random.PRNGKey(0)
            over_t = common.timeit(commit, prot, new_state, rng_key=key,
                                   reps=(5 if quick else 15))
            commit_meta = jax.jit(p.make_commit(dirty_pages=[]))
            free_t = common.timeit(commit_meta, prot, state, rng_key=key,
                                   reps=(5 if quick else 15))
            rows.append({
                "size_B": size, "mode": mode.value,
                "alloc_us": round(init_t["median_s"] * 1e6, 1),
                "overwrite_us": round(over_t["median_s"] * 1e6, 1),
                "free_us": round(free_t["median_s"] * 1e6, 1),
            })
            # the facade's routed commit vs the direct engine program:
            # compiled bytes must be identical (gated structurally)
            direct_mb = _xla_bytes(commit, prot, new_state, rng_key=key)
            facade_mb = _xla_bytes(pool.commit_program(), prot, new_state,
                                   rng_key=key)
            facade_rows.append({
                "size_B": size, "mode": mode.value,
                "direct_MB": round(direct_mb / 2**20, 3),
                "facade_MB": round(facade_mb / 2**20, 3),
            })
    common.print_table("transaction latency (us, CPU-relative)", rows,
                       ["size_B", "mode", "alloc_us", "overwrite_us",
                        "free_us"])
    common.print_table("facade vs direct commit (XLA bytes accessed, MB)",
                       facade_rows,
                       ["size_B", "mode", "direct_MB", "facade_MB"])

    # reproduction checks (relative claims only)
    summary = {}
    for size in sizes:
        by_mode = {r["mode"]: r for r in rows if r["size_B"] == size}
        over = {m: by_mode[m]["overwrite_us"] for m in by_mode}
        summary[size] = {
            "ladder_ratio_mlpc_over_none": round(
                over["mlpc"] / over["none"], 2),
            "mlp_vs_replica": round(over["mlp"] / over["replica"], 2),
            "cksum_addon_pct": round(
                100 * (over["mlpc"] - over["mlp"]) / over["mlp"], 1),
        }
    out = {"rows": rows, "summary": summary, "facade": facade_rows}
    common.save_result("txn_latency", out)
    print("summary (overwrite):", summary)
    return out


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
