"""Paper §4.6 — error injection, detection, and online correction.

Measures what the paper demonstrates qualitatively, plus latencies:

  * media error (rank loss): inject -> freeze -> rebuild row -> verify,
    across state sizes; reports recovery wall time and exactness,
  * scribble: inject targeted bit flips -> scrub detect -> page repair,
  * canary: a smashed staging buffer must abort the transaction,
  * detection completeness: every injected corruption is found (no false
    negatives) and clean pools scrub clean (no false positives),
  * double loss (beyond paper, redundancy=2): TWO simultaneous rank
    losses solved from P + the GF(2^32) Q syndrome — reconstruction wall
    time, exactness, and the Q storage tax (must stay <= 2x P; it is
    exactly 1x — gated by scripts/bench_gate.py via BENCH_commit.json),
  * r-sweep (generalized Reed-Solomon): for every stack height r in
    1..4, e = r simultaneous rank losses on a G=8 zone solve through the
    e x e Vandermonde inverse — reconstruction wall time, exactness, and
    the stack storage ratio syndrome_r_over_p (exactly r by
    construction; gated <= r in BENCH_commit.json §rs).

Everything routes through the public `Pool` facade: `pool.recover`
dispatches every fault kind (and flushes any open window first), and
`pool.scrub` is the detection path.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.configs.base import ProtectConfig
from repro.core import microbuffer
from repro.pool import Fault, Pool
from repro.runtime import failure


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    sizes = [64 * 1024, 1024 * 1024] if quick else \
        [64 * 1024, 1024 * 1024, 16 * 1024 * 1024]
    rows = []
    for size in sizes:
        state, specs = common.state_of_bytes(size, mesh)
        pool = Pool.open(state, specs, mesh=mesh,
                         config=ProtectConfig(mode="mlpc",
                                              block_words=1024),
                         donate=False)
        w0 = np.asarray(pool.state["w"]).copy()

        # media error: lose rank 2 entirely
        pool.prot, event = failure.inject_rank_loss(pool.protector,
                                                    pool.prot, rank=2)
        t0 = time.perf_counter()
        rep = pool.recover(Fault.from_event(event))
        jax.block_until_ready(jax.tree.leaves(pool.state)[0])
        t_rank = time.perf_counter() - t0
        exact = np.array_equal(np.asarray(pool.state["w"]), w0)

        # scribble: flip bits in 3 words, detect by scrub, repair pages
        pool.prot, ev2 = failure.inject_scribble(
            pool.protector, pool.prot, rank=1,
            word_offsets=[7, 2048, 100000])
        t0 = time.perf_counter()
        report = pool.scrub()
        jax.block_until_ready(jax.tree.leaves(pool.state)[0])
        t_scrub = time.perf_counter() - t0
        exact2 = np.array_equal(np.asarray(pool.state["w"]), w0)

        rows.append({
            "state_B": size,
            "rank_recover_ms": round(t_rank * 1e3, 2),
            "rank_exact": exact, "rank_verified": rep.verified,
            "scrub_repair_ms": round(t_scrub * 1e3, 2),
            "scribble_found": len(report.bad_locations),
            "scribble_exact": exact2,
            "repair_verified": bool(report.repair_ok),
        })

    common.print_table("error injection & online recovery", rows,
                       ["state_B", "rank_recover_ms", "rank_exact",
                        "scrub_repair_ms", "scribble_found",
                        "scribble_exact", "repair_verified"])
    assert all(r["rank_exact"] and r["scribble_exact"] for r in rows)

    # canary: overrun staging buffer must be caught before commit
    smashed = failure.smashed_canary_buffer(4096)
    caught = not bool(microbuffer.check(smashed))
    clean = bool(microbuffer.check(microbuffer.guard(
        jax.numpy.zeros((4096,), jax.numpy.uint32))))
    print(f"canary: overrun caught={caught}, clean buffer passes={clean}")
    assert caught and clean

    # false-positive check: a clean pool scrubs clean
    state, specs = common.state_of_bytes(256 * 1024, mesh)
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=1024),
                     donate=False)
    rep = pool.scrub()
    assert not rep.bad_locations and bool(rep.parity_ok)
    print("clean-pool scrub: no false positives")

    # dual parity: two simultaneous rank losses, P+Q Vandermonde solve
    double_rows = []
    for size in sizes:
        state, specs = common.state_of_bytes(size, mesh)
        pool2 = Pool.open(state, specs, mesh=mesh,
                          config=ProtectConfig(mode="mlpc", redundancy=2,
                                               block_words=1024),
                          donate=False)
        w0 = np.asarray(pool2.state["w"]).copy()
        pool2.prot, event = failure.inject_double_rank_loss(
            pool2.protector, pool2.prot, ranks=(1, 3))
        t0 = time.perf_counter()
        rep = pool2.recover(Fault.double_loss(*event.lost_ranks))
        jax.block_until_ready(jax.tree.leaves(pool2.state)[0])
        t_double = time.perf_counter() - t0
        over = pool2.overhead_report()
        double_rows.append({
            "state_B": size,
            "double_recover_ms": round(t_double * 1e3, 2),
            "double_exact": np.array_equal(np.asarray(pool2.state["w"]),
                                           w0),
            "double_verified": rep.verified,
            # syndrome bytes over ONE parity row = r; the legacy gate
            # key reads the extra (beyond-P) rows, historically <= 2
            "q_over_p": round(over["syndrome_bytes_per_rank"]
                              / max(over["parity_bytes_per_rank"], 1)
                              - 1.0, 4),
        })
    common.print_table("double loss (redundancy=2, P+Q)", double_rows,
                       ["state_B", "double_recover_ms", "double_exact",
                        "double_verified", "q_over_p"])
    assert all(r["double_exact"] and r["double_verified"]
               for r in double_rows)

    # generalized Reed-Solomon r-sweep: e = r losses at every stack
    # height on a pure 8-rank zone (r <= 4 needs G - 1 >= 4 survivable)
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    rs_rows = []
    rs_size = 256 * 1024
    for r in (1, 2, 3, 4):
        state, specs = common.state_of_bytes(rs_size, mesh8)
        pool_r = Pool.open(state, specs, mesh=mesh8,
                           config=ProtectConfig(mode="mlpc", redundancy=r,
                                                block_words=1024),
                           donate=False)
        w0 = np.asarray(pool_r.state["w"]).copy()
        dead = tuple(range(1, 1 + r))
        if r == 1:
            pool_r.prot, event = failure.inject_rank_loss(
                pool_r.protector, pool_r.prot, rank=dead[0])
        else:
            pool_r.prot, event = failure.inject_multi_rank_loss(
                pool_r.protector, pool_r.prot, dead)
        t0 = time.perf_counter()
        rep = pool_r.recover(Fault.from_event(event))
        jax.block_until_ready(jax.tree.leaves(pool_r.state)[0])
        t_rec = time.perf_counter() - t0
        over = pool_r.overhead_report()
        rs_rows.append({
            "r": r, "e": r, "state_B": rs_size,
            "recover_ms": round(t_rec * 1e3, 2),
            "exact": np.array_equal(np.asarray(pool_r.state["w"]), w0),
            "verified": rep.verified,
            "syndrome_r_over_p": round(
                over["syndrome_bytes_per_rank"]
                / max(over["parity_bytes_per_rank"], 1), 4),
            "storage_overhead_pct": round(
                100 * over["syndrome_fraction"], 3),
        })
    common.print_table("r-sweep: e = r losses per stack height (G=8)",
                       rs_rows,
                       ["r", "e", "state_B", "recover_ms", "exact",
                        "verified", "syndrome_r_over_p",
                        "storage_overhead_pct"])
    assert all(row["exact"] and row["verified"] for row in rs_rows)

    payload = {"rows": rows, "canary_caught": caught,
               "double_loss": double_rows, "rs": rs_rows}
    common.save_result("recovery", payload)
    return payload


if __name__ == "__main__":
    run()
