"""Benchmark harness: `PYTHONPATH=src python -m benchmarks.run [--quick]`.

One benchmark per paper table/figure (DESIGN.md §5):
  storage_overhead  §4.2          txn_latency  Fig. 3
  scalability       Fig. 4/§3.5   app_kv       Fig. 5 + Table 3
  scrub_freq        Fig. 6        recovery     §4.6
  roofline          (beyond paper: from the compiled dry-run)

Multi-device CPU meshes are required for the zone collectives, so the
device count is forced before jax's first import (8 hosts — not the
512-way production flag, which only launch/dryrun.py sets).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import importlib
import json
import time
import traceback

BENCHES = ["storage_overhead", "txn_latency", "commit_sweep", "deferred",
           "scalability", "app_kv", "scrub_freq", "recovery", "roofline",
           "chaos", "obs_overhead", "tenancy", "async_pipeline"]


def emit_commit_json(txn_result: dict, quick: bool, path: str,
                     ab_result: dict = None,
                     deferred_result: dict = None,
                     recovery_result: dict = None,
                     roofline_result: dict = None,
                     chaos_result: dict = None,
                     obs_result: dict = None,
                     tenancy_result: dict = None,
                     async_result: dict = None) -> None:
    """Write the per-PR commit-latency record (BENCH_commit.json).

    Distills txn_latency down to the commit hot path (overwrite latency
    per mode/size) plus the facade-vs-direct compiled-bytes record (the
    Pool facade must route to the very program direct engine use
    compiles — zero byte overhead, gated structurally), plus the
    interleaved unfused-vs-fused A/B when commit_sweep ran, the
    deferred-epoch W-sweep when `deferred` ran, and the dual-parity
    recovery record (double-loss reconstruction time + Q storage tax)
    when `recovery` ran, so perf regressions on the commit/recovery
    engines are visible as one small diffable file
    (scripts/bench_gate.py diffs it against the committed baseline);
    EXPERIMENTS.md §Perf records the history.
    """
    overwrite = {}
    for r in txn_result["rows"]:
        overwrite.setdefault(str(r["size_B"]), {})[r["mode"]] = \
            r["overwrite_us"]
    payload = {
        "bench": "txn_latency",
        "quick": quick,
        "commit_engine": "fused-single-sweep+deferred-epoch",
        "api": "pool-facade",
        "overwrite_us": overwrite,
        "summary": {str(k): v for k, v in txn_result["summary"].items()},
    }
    if txn_result.get("facade"):
        payload["facade"] = txn_result["facade"]
    if ab_result:
        payload["ab_interleaved"] = ab_result["rows"]
    if deferred_result:
        payload["deferred"] = deferred_result["rows"]
    if recovery_result and recovery_result.get("double_loss"):
        payload["recovery"] = {"double_loss": recovery_result["double_loss"]}
    if recovery_result and recovery_result.get("rs"):
        # §rs: the generalized Reed-Solomon sweep — e = r losses per
        # stack height, wall + exactness + storage ratio (gate:
        # record-presence, syndrome_r_over_p <= r, wall pathology)
        payload["rs"] = recovery_result["rs"]
    if roofline_result and roofline_result.get("commit_sweep"):
        # §roofline: streamed-vs-flat commit sweep achieved bytes/s
        # (gate: record-presence at 1 MB, streamed xla_MB <= flat,
        # streamed useful_frac above flat, wall pathology)
        payload["roofline"] = roofline_result["commit_sweep"]
    if chaos_result and chaos_result.get("rows"):
        # §chaos: tail latency + recovery-under-load per scripted fault
        # scenario (gate: record-presence of the four core scenarios,
        # golden_exact structural, during-p99 wall pathology)
        payload["chaos"] = chaos_result["rows"]
    if obs_result and obs_result.get("bytes"):
        # §obs: the telemetry plane's instrumented-vs-bare A/B (gate:
        # record-presence, byte_delta exactly 0 structurally, dispatch
        # overhead_pct within the bound)
        payload["obs"] = {"bytes": obs_result["bytes"],
                          "wall": obs_result["wall"]}
    if tenancy_result and tenancy_result.get("throughput"):
        # §tenancy: the multi-tenant PoolGroup A/B (gate: record-
        # presence, batched aggregate commits/s >= looped at N >= 8
        # structurally — same-run interleaved — and the scrub-storm
        # interference p99 ratio as a pathology bound)
        payload["tenancy"] = {
            "throughput": tenancy_result["throughput"],
            "interference": tenancy_result["interference"]}
    if async_result and async_result.get("depths"):
        # §async: the commit-ring depth sweep — commits/s + resolve
        # tail per depth over one shared compiled program (gate:
        # record-presence, best depth>=4 commits/s >= depth=1
        # structural, resolve-p99 wall pathology)
        payload["async"] = {"depths": async_result["depths"]}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"commit benchmark record -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/reps for CI")
    ap.add_argument("--commit-json", default="BENCH_commit.json",
                    help="where to write the commit-latency record "
                         "(written whenever txn_latency runs)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else BENCHES

    status = {}
    results = {}
    for name in names:
        print(f"\n{'=' * 70}\nBENCH {name}\n{'=' * 70}", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            results[name] = mod.run(quick=args.quick)
            status[name] = f"ok ({time.time() - t0:.1f}s)"
        except Exception as e:  # noqa: BLE001 — report all failures at the end
            traceback.print_exc()
            status[name] = f"FAILED: {type(e).__name__}: {e}"
    if isinstance(results.get("txn_latency"), dict):
        emit_commit_json(results["txn_latency"], args.quick,
                         args.commit_json,
                         ab_result=results.get("commit_sweep"),
                         deferred_result=results.get("deferred"),
                         recovery_result=results.get("recovery"),
                         roofline_result=results.get("roofline"),
                         chaos_result=results.get("chaos"),
                         obs_result=results.get("obs_overhead"),
                         tenancy_result=results.get("tenancy"),
                         async_result=results.get("async_pipeline"))
    print("\n" + "=" * 70)
    for name, s in status.items():
        print(f"{name:20s} {s}")
    if any(s.startswith("FAILED") for s in status.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
