"""Paper Fig. 6 — impact of checksum-verification (scrub) frequency.

The paper verifies the whole pool every N transactions and measures insert
throughput vs N.  Here: protected train steps with scrub_period in
{0 (off), 20, 10, 5, 2} plus the verify-at-open policy (the "default" bar:
checksums of to-be-modified objects verified per transaction).
"""
from __future__ import annotations

import time

from benchmarks import common
from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.runtime.trainer import Trainer

PERIODS = [0, 20, 10, 5, 2]


def run(quick: bool = False) -> dict:
    mesh = common.get_mesh()
    cfg = ModelConfig(
        name="b_scrub", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, d_ff=128, vocab=512, param_dtype="float32",
        compute_dtype="float32")
    n_steps = 10 if quick else 30
    rows = []
    for period in (PERIODS[:3] if quick else PERIODS):
        t = Trainer(cfg, TrainConfig(learning_rate=1e-3),
                    ProtectConfig(mode="mlpc", block_words=64,
                                  scrub_period=period),
                    mesh, seq_len=32, global_batch=8)
        t.initialize()
        t.run(2)
        t0 = time.perf_counter()
        outs = t.run(n_steps)
        dt = time.perf_counter() - t0
        n_scrubs = sum(1 for o in outs if "scrub" in o)
        rows.append({
            "scrub_period": period or "off",
            "steps_per_s": round(n_steps / dt, 2),
            "scrubs_run": n_scrubs,
        })

    # the "default" policy bar: verify-at-open (checksums of the old state
    # verified inside every commit), no periodic scrubbing
    t = Trainer(cfg, TrainConfig(learning_rate=1e-3),
                ProtectConfig(mode="mlpc", block_words=64, scrub_period=0),
                mesh, seq_len=32, global_batch=8)
    t.initialize()
    t.verify_old = True            # routed through the pool's commit
    t.run(2)
    t0 = time.perf_counter()
    t.run(n_steps)
    dt = time.perf_counter() - t0
    rows.append({"scrub_period": "verify-at-open",
                 "steps_per_s": round(n_steps / dt, 2), "scrubs_run": 0})

    common.print_table("scrub frequency vs training throughput", rows,
                       ["scrub_period", "steps_per_s", "scrubs_run"])
    # reproduction target: throughput decreases monotonically (within noise)
    # as scrubs become more frequent
    base = rows[0]["steps_per_s"]
    freq = [r for r in rows if r["scrub_period"] == 2]
    if freq:
        assert freq[0]["steps_per_s"] <= base * 1.1
    common.save_result("scrub_freq", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
