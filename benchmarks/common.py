"""Shared benchmark scaffolding: timing, mesh/state construction, reporting.

All benchmarks run on CPU host devices (8-way, set in benchmarks/run.py
before jax's first import).  Absolute times are CPU times — the paper's
*relative* claims (mode ladder ordering, parity-vs-replica ratio, hybrid
crossover) are the reproduction targets, as DESIGN.md §6 lays out.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

RESULTS_DIR = os.environ.get(
    "BENCH_RESULTS", os.path.join(os.path.dirname(__file__), "results"))


def get_mesh(data: int = 4, model: int = 2) -> Mesh:
    return jax.make_mesh((data, model), ("data", "model"))


def timeit(fn: Callable, *args, warmup: int = 2, reps: int = 10,
           **kw) -> dict:
    """Median wall time of fn(*args); blocks on all output leaves."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts = np.asarray(ts)
    return {"median_s": float(np.median(ts)), "p10_s": float(np.quantile(ts, .1)),
            "p90_s": float(np.quantile(ts, .9)), "reps": reps}


def state_of_bytes(n_bytes: int, mesh, dtype=jnp.float32) -> tuple:
    """A single-leaf state of ~n_bytes, FSDP-sharded over the data axis."""
    g = mesh.shape["data"]
    n = max(n_bytes // jnp.dtype(dtype).itemsize, g)
    n = (n + g - 1) // g * g
    specs = {"w": P("data")}
    state = {"w": (jnp.arange(n, dtype=jnp.uint32) % 1000).astype(dtype)}
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(jax.device_put, state, sh), specs


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def print_table(title: str, rows: list, cols: list):
    print(f"\n== {title} ==")
    widths = [max(len(str(c)), max((len(str(r.get(c, ''))) for r in rows),
                                   default=0)) for c in cols]
    print("  ".join(str(c).ljust(w) for c, w in zip(cols, widths)))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(w)
                        for c, w in zip(cols, widths)))
