"""Property tests: bit-exact uint32 word views (the byte substrate Pangolin's
parity/checksum math runs on) must round-trip every supported dtype."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import utils

DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16, jnp.int32, jnp.uint32,
          jnp.int16, jnp.uint16, jnp.int8, jnp.uint8]


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(1,), (7,), (3, 5), (2, 3, 4), (17,)])
def test_words_roundtrip_exact(dtype, shape):
    n = int(np.prod(shape))
    rng = np.random.default_rng(hash((str(dtype), shape)) % 2**32)
    raw = rng.integers(0, 256, size=n * jnp.dtype(dtype).itemsize,
                       dtype=np.uint8)
    x = jnp.asarray(raw).view(jnp.dtype(dtype).name).reshape(shape) \
        if jnp.dtype(dtype).itemsize == 1 else \
        jax.lax.bitcast_convert_type(
            jnp.asarray(raw.view(np.uint8)).reshape(
                n, jnp.dtype(dtype).itemsize),
            jnp.dtype(dtype)).reshape(shape)
    w = utils.to_words(x)
    assert w.dtype == jnp.uint32
    assert w.shape[0] == utils.num_words(shape, dtype)
    y = utils.from_words(w, shape, dtype)
    assert y.dtype == jnp.dtype(dtype) and y.shape == tuple(shape)
    # bit-exact (NaN bit patterns included)
    assert np.asarray(utils.to_words(y)).tobytes() == \
        np.asarray(w).tobytes()


@given(st.integers(1, 200), st.sampled_from(["float32", "bfloat16", "int8"]))
@settings(max_examples=30, deadline=None)
def test_num_words_matches_to_words(n, dtype):
    x = jnp.zeros((n,), jnp.dtype(dtype))
    assert utils.to_words(x).shape[0] == utils.num_words((n,), dtype)


def test_nan_bitpattern_preserved():
    x = jnp.asarray([np.nan, -np.nan, np.inf, -0.0], jnp.float32)
    w = utils.to_words(x)
    y = utils.from_words(w, (4,), jnp.float32)
    assert np.asarray(utils.to_words(y)).tobytes() == \
        np.asarray(w).tobytes()


def test_pad_to():
    x = jnp.arange(5, dtype=jnp.uint32)
    p = utils.pad_to(x, 8)
    assert p.shape == (8,)
    assert np.all(np.asarray(p[5:]) == 0)
    assert utils.pad_to(p, 8) is p


def test_round_up():
    assert utils.round_up(0, 4) == 0
    assert utils.round_up(1, 4) == 4
    assert utils.round_up(4, 4) == 4
    assert utils.round_up(5, 4) == 8


def test_tree_equal_bits():
    a = {"x": jnp.asarray([1.0, np.nan], jnp.float32)}
    b = {"x": jnp.asarray([1.0, np.nan], jnp.float32)}
    assert utils.tree_equal_bits(a, b)
    c = {"x": jnp.asarray([1.0, 2.0], jnp.float32)}
    assert not utils.tree_equal_bits(a, c)
    # shape mismatch
    d = {"x": jnp.zeros((3,), jnp.float32)}
    assert not utils.tree_equal_bits(a, d)


def test_tree_bytes():
    t = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros((2,),
                                                             jnp.bfloat16)}
    assert utils.tree_bytes(t) == 4 * 4 * 4 + 2 * 2
