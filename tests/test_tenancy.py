"""Multi-tenant PoolGroup (repro/tenancy): the batched commit programs
must be bit-identical to N independent Pools across engines and
redundancies (including canary aborts and the redo log), eviction
flushes the open window before handing the state back, recovery
quarantines only the faulted tenant, the shared scrub scheduler is
starvation-free under skewed weights and a page budget, QoS classes key
cohorts, and every pool metric rides a tenant= label in the group
registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtectConfig
from repro.pool import Fault, Pool
from repro.runtime import failure
from repro.tenancy import BRONZE, GOLD, SILVER, PoolGroup
from tests.conftest import small_state


@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs


def _evolve(cur, k=0):
    return jax.tree.map(
        lambda x: (x * (1.01 + 0.001 * k) + 0.003).astype(x.dtype), cur)


def _tstate(state, t):
    """Per-tenant distinct initial state (same shapes -> same cohort)."""
    return _evolve(state, 7 * t + 1)


def _assert_prot_equal(pa, pb, msg=""):
    for f in ("synd", "digest", "row", "cksums", "step"):
        a, b = getattr(pa, f), getattr(pb, f)
        if a is None or b is None:
            assert a is None and b is None, (msg, f)
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{msg}:{f}")
    for f in ("step", "data_cursor", "rng", "digest", "mark"):
        np.testing.assert_array_equal(
            np.asarray(getattr(pa.log, f)),
            np.asarray(getattr(pb.log, f)), err_msg=f"{msg}:log.{f}")


# -- batched == N independent pools, engines x redundancies -------------------

@pytest.mark.parametrize("window,red", [(1, 1), (1, 3), (4, 1), (4, 3)])
def test_group_commit_bit_identical(setup, window, red):
    """ISSUE acceptance: a PoolGroup commit wave over one cohort — ONE
    batched dispatch — must land the exact bytes N sequential
    `pool.commit` calls land: syndromes, checksums, digest, row cache,
    step counters and the redo log (records AND marks), through both
    engines, with a mid-run canary abort exercising the select paths."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=red, window=window,
                        block_words=64)
    grp = PoolGroup(mesh)
    n = 3
    for t in range(n):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
    refs = [Pool.open(_tstate(state, t), specs, mesh=mesh, config=cfg,
                      donate=False) for t in range(n)]
    assert len(grp.cohorts) == 1      # same shape x config: one cohort

    curs = [_tstate(state, t) for t in range(n)]
    for i in range(2 * window + 1):
        for t in range(n):
            curs[t] = _evolve(curs[t], i)
        ups = {f"t{t}": curs[t] for t in range(n)}
        # one tenant aborts mid-run: its state must not move while its
        # neighbors' commits land in the same batched dispatch
        can = {f"t{t}": not (i == 1 and t == 1) for t in range(n)}
        keys = {f"t{t}": jax.random.PRNGKey(100 * t + i)
                for t in range(n)}
        oks = grp.commit(ups, canary_ok=can, data_cursor=i,
                         rng_keys=keys)
        for t in range(n):
            ok_ref = refs[t].commit(
                curs[t], canary_ok=can[f"t{t}"], data_cursor=i,
                rng_key=jax.random.PRNGKey(100 * t + i))
            assert (bool(jax.device_get(oks[f"t{t}"]))
                    == bool(jax.device_get(ok_ref)))
    for t in range(n):
        _assert_prot_equal(grp[f"t{t}"].pool.prot, refs[t].prot,
                           msg=f"w{window} r{red} t{t}")
    # the wave really was batched: one group dispatch per commit wave
    assert grp.metrics.counter("group_commit_batches_total").value \
        == 2 * window + 1


def test_group_commit_verify_old_and_looped_fallback(setup):
    """verify_old rides the batched verify kernels bit-identically; and
    `batched=False` (the benchmark baseline) lands the same bytes
    through the per-tenant loop."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=2, block_words=64)
    grp = PoolGroup(mesh)
    grp_loop = PoolGroup(mesh)
    refs = []
    for t in range(2):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
        grp_loop.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
        refs.append(Pool.open(_tstate(state, t), specs, mesh=mesh,
                              config=cfg, donate=False))
    curs = [_tstate(state, t) for t in range(2)]
    for i in range(3):
        for t in range(2):
            curs[t] = _evolve(curs[t], i)
        ups = {f"t{t}": curs[t] for t in range(2)}
        grp.commit(ups, data_cursor=i, verify_old=True)
        grp_loop.commit(ups, data_cursor=i, verify_old=True,
                        batched=False)
        for t in range(2):
            refs[t].commit(curs[t], data_cursor=i, verify_old=True)
    for t in range(2):
        _assert_prot_equal(grp[f"t{t}"].pool.prot, refs[t].prot,
                           msg=f"batched t{t}")
        _assert_prot_equal(grp_loop[f"t{t}"].pool.prot, refs[t].prot,
                           msg=f"looped t{t}")
    assert grp_loop.metrics.counter(
        "group_commit_batches_total").value == 0


# -- scrub + recover bit-identity, quarantine isolation -----------------------

def test_group_scrub_and_recover_bit_identical(setup):
    """Scheduler-driven scrubs and quarantined recovery route through
    the tenant's own Pool (cohort-shared programs): the post-scrub and
    post-recovery protection must equal an independent pool's, and the
    faulted tenant's neighbors must come through recovery untouched."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=2, block_words=64)
    grp = PoolGroup(mesh, full_scrub_every=1)   # every serve = full
    n = 3
    for t in range(n):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
    ref = Pool.open(_tstate(state, 1), specs, mesh=mesh, config=cfg,
                    donate=False)
    curs = [_tstate(state, t) for t in range(n)]
    for i in range(2):
        for t in range(n):
            curs[t] = _evolve(curs[t], i)
        grp.commit({f"t{t}": curs[t] for t in range(n)}, data_cursor=i,
                   rng_keys={f"t{t}": jax.random.PRNGKey(100 * t + i)
                             for t in range(n)})
        ref.commit(curs[1], data_cursor=i,
                   rng_key=jax.random.PRNGKey(100 + i))

    served = grp.scrub_tick()
    assert {tid for tid, _, _ in served} == {f"t{t}" for t in range(n)}
    assert all(kind == "full" and not rep.suspect
               for _, kind, rep in served)
    _, ref_rep = ref.scrubber.run(ref.prot)
    assert not ref_rep.suspect
    _assert_prot_equal(grp["t1"].pool.prot, ref.prot, msg="post-scrub")

    # same rank loss injected into the group tenant and the reference
    grp["t1"].pool.inject(
        lambda p, pr: failure.inject_rank_loss(p, pr, 2))
    ref.inject(lambda p, pr: failure.inject_rank_loss(p, pr, 2))
    before = {t: np.asarray(grp[f"t{t}"].pool.prot.row)
              for t in (0, 2)}
    rep = grp.recover("t1", Fault.rank_loss(2))
    ref_rep = ref.recover(Fault.rank_loss(2))
    assert rep.verified and ref_rep.verified
    _assert_prot_equal(grp["t1"].pool.prot, ref.prot,
                       msg="post-recovery")
    assert grp.quarantined == ()      # lifted on success
    for t in (0, 2):                  # neighbors never touched
        np.testing.assert_array_equal(
            np.asarray(grp[f"t{t}"].pool.prot.row), before[t])


def test_quarantine_rejects_commits_until_release(setup):
    """A failed (budget-exhausted) recovery leaves the tenant
    quarantined: its commits are rejected host-side while neighbors
    keep committing in the same wave; `release` (after a re-arm)
    restores it."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=1, block_words=64)
    grp = PoolGroup(mesh)
    for t in range(2):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
    with pytest.raises(RuntimeError, match="budget exhausted"):
        grp.recover("t0", Fault.multi_loss(0, 1))   # e=2 > r=1
    assert grp.quarantined == ("t0",)
    assert grp.health()["status"] != "green"

    curs = {f"t{t}": _evolve(_tstate(state, t)) for t in range(2)}
    oks = grp.commit(curs)
    assert oks["t0"] is False                       # host rejection
    assert bool(jax.device_get(oks["t1"]))          # neighbor lands
    assert grp.metrics.counter(
        "group_commit_rejected_total").value == 1
    step0 = int(jax.device_get(grp["t0"].pool.prot.step))

    grp["t0"].pool.init(curs["t0"])                 # re-arm
    grp.release("t0")
    oks = grp.commit({"t0": _evolve(curs["t0"])})
    assert bool(jax.device_get(oks["t0"]))
    assert int(jax.device_get(grp["t0"].pool.prot.step)) == step0 + 1


# -- admission / eviction -----------------------------------------------------

def test_eviction_flushes_open_window_lru(setup):
    """At capacity the least-recently-committed tenant is evicted; the
    victim's open deferred window is flushed first (its returned state
    carries current redundancy — a clean precheck proves it)."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=1, window=4,
                        block_words=64)
    grp = PoolGroup(mesh, capacity=2)
    grp.admit("a", _tstate(state, 0), specs, config=cfg)
    grp.admit("b", _tstate(state, 1), specs, config=cfg)
    # one in-window commit each -> both windows open; then touch "a" so
    # "b" is the LRU victim
    grp.commit({"a": _evolve(_tstate(state, 0)),
                "b": _evolve(_tstate(state, 1))})
    grp.commit({"a": _evolve(_tstate(state, 0), 1)})
    hb = grp["b"]
    assert hb.pool.engine._since == 1               # window open
    grp.admit("c", _tstate(state, 2), specs, config=cfg)
    assert "b" not in grp and "a" in grp and "c" in grp
    assert hb.pool.engine._since == 0               # flushed on evict
    assert not hb.pool.precheck().suspect           # redundancy current
    assert grp.metrics.counter("group_evictions_total").value == 1

    strict = PoolGroup(mesh, capacity=1, evict_on_full=False)
    strict.admit("x", _tstate(state, 0), specs, config=cfg)
    with pytest.raises(RuntimeError, match="capacity"):
        strict.admit("y", _tstate(state, 1), specs, config=cfg)


# -- shared scrub scheduler ---------------------------------------------------

def test_scheduler_starvation_free_under_budget_and_weights(setup):
    """Under a one-pool-per-tick page budget and skewed QoS weights,
    every tenant is still served within a bounded number of ticks (the
    additive aging term), and the full-scrub cadence bounds every
    tenant's commits-since-full-scrub."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=1, block_words=64)
    pages = None
    grp = PoolGroup(mesh, full_scrub_every=2)
    n = 3
    for t in range(n):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg,
                  weight=(8 if t == 0 else 1))      # t0 hogs priority
        pages = grp[f"t{t}"].pool.scrubber.pool_pages
    served_kinds = {f"t{t}": set() for t in range(n)}
    max_age = 0
    for round_ in range(4 * n):
        # keep t0's commit pressure maximal every round
        grp.commit({f"t{t}": _evolve(_tstate(state, t), round_)
                    for t in range(n)})
        for tid, kind, rep in grp.scrub_tick(page_budget=pages):
            served_kinds[tid].add(kind)
            assert not rep.suspect
        max_age = max(max_age, grp.scheduler.max_check_age())
    # starvation-freedom: every tenant's wait is bounded despite t0's
    # x8 weight — everyone gets BOTH cadences and the check age never
    # exceeds the aging-term bound
    for t in range(n):
        assert served_kinds[f"t{t}"] == {"precheck", "full"}, \
            f"t{t} starved: {served_kinds}"
    assert max_age <= 2 * n + 1
    stats = grp.scheduler.stats()
    assert stats["pages_spent"] == stats["passes"] * pages
    # quarantined tenants drop out of scheduling entirely
    grp.scheduler.set_quarantined("t0", True)
    assert "t0" not in {tid for tid, _, _ in grp.scrub_tick()}


# -- QoS classes + cohort keying ---------------------------------------------

def test_qos_classes_key_cohorts(setup):
    """Same shape + same QoS class -> one cohort (one shared Protector,
    one batched program); a different class or config -> its own
    cohort.  QoS weight feeds the scheduler."""
    mesh, state, specs = setup
    grp = PoolGroup(mesh)
    a = grp.admit("a", _tstate(state, 0), specs, qos=GOLD)
    b = grp.admit("b", _tstate(state, 1), specs, qos=GOLD)
    c = grp.admit("c", _tstate(state, 2), specs, qos=BRONZE)
    assert a.cohort is b.cohort and a.cohort is not c.cohort
    assert a.pool.protector is b.pool.protector
    assert a.pool.redundancy == 3 and a.pool.engine is None  # gold: sync
    assert c.pool.engine is not None and c.pool.engine.window == 8
    assert grp.scheduler._tenants["a"].weight == GOLD.weight
    # derived class stays in-tier but re-keys the cohort
    d = grp.admit("d", _tstate(state, 3), specs,
                  qos=SILVER.configure(block_words=64))
    assert d.cohort not in (a.cohort, c.cohort)
    assert len(grp.cohorts) == 3


def test_tenant_metric_labels(setup):
    """Every pool metric in the group registry rides a tenant= label,
    and a tenant's labeled view filters to its own slice."""
    mesh, state, specs = setup
    cfg = ProtectConfig(mode="mlpc", redundancy=1, block_words=64)
    grp = PoolGroup(mesh)
    for t in range(2):
        grp.admit(f"t{t}", _tstate(state, t), specs, config=cfg)
    grp.commit({f"t{t}": _evolve(_tstate(state, t)) for t in range(2)})
    for t in range(2):
        assert grp.metrics.counter(
            "pool_commits_total", tenant=f"t{t}").value == 1
        view = grp[f"t{t}"].pool.metrics
        names = {name for name, _, _ in view.collect()}
        assert "pool_commits_total" in names
    snap = grp.metrics.snapshot()
    assert any("tenant=t0" in lkey
               for lkey in snap.get("pool_commits_total", {}))
    st = grp.stats()
    assert st["tenants"] == 2 and st["per_tenant"]["t0"]["commits"] == 1
    assert grp.health()["status"] == "green"
