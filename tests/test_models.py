"""Model family coverage: every block family must train (finite loss/grads)
and its decode path must agree with the full-sequence forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoESpec
from repro.models.transformer import build_model

COMMON = dict(n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
              param_dtype="float32", compute_dtype="float32")

FAMILIES = {
    "dense": ModelConfig(name="t_dense", family="dense", **COMMON),
    "qknorm_bias": ModelConfig(name="t_qn", family="dense", qk_norm=True,
                               qkv_bias=True, **COMMON),
    "rope_large_theta": ModelConfig(name="t_rope", family="dense",
                                    rope_theta=1e6, **COMMON),
    "moe_top1": ModelConfig(
        name="t_moe1", family="moe",
        moe=MoESpec(num_experts=4, top_k=1, d_expert=128, interleave=2,
                    shared_expert=True, capacity_factor=4.0), **COMMON),
    "moe_top2": ModelConfig(
        name="t_moe2", family="moe",
        moe=MoESpec(num_experts=4, top_k=2, d_expert=128,
                    capacity_factor=4.0), **COMMON),
    "hybrid_rglru": ModelConfig(
        name="t_rg", family="hybrid", block_pattern=("rglru", "rglru", "attn"),
        window=8, subquadratic=True, n_layers=5, d_model=64, n_heads=4,
        n_kv=1, d_ff=128, vocab=256, param_dtype="float32",
        compute_dtype="float32"),
    "ssm_xlstm": ModelConfig(
        name="t_xl", family="ssm",
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"), subquadratic=True,
        n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=0, vocab=256,
        param_dtype="float32", compute_dtype="float32"),
    "vlm_stub": ModelConfig(name="t_vlm", family="vlm", mm_positions=4,
                            **COMMON),
    "encdec": ModelConfig(name="t_ed", family="audio", enc_layers=2,
                          n_layers=2, d_model=64, n_heads=4, n_kv=2,
                          d_ff=128, vocab=256, param_dtype="float32",
                          compute_dtype="float32"),
}


def make_batch(cfg, B=2, S=32, seed=1):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.mm_positions:
        batch["mm_embeds"] = jnp.ones((B, cfg.mm_positions, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype)) * 0.01
    if cfg.enc_layers:
        batch["src_embeds"] = jnp.ones((B, S, cfg.d_model),
                                       jnp.dtype(cfg.compute_dtype)) * 0.01
    return batch


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_loss_and_grads_finite(fam):
    cfg = FAMILIES[fam]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), (fam, float(loss))
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    for path, g in jax.tree.leaves_with_path(grads):
        assert np.all(np.isfinite(np.asarray(g, np.float32))), (fam, path)


@pytest.mark.parametrize("fam", sorted(FAMILIES))
def test_decode_matches_forward(fam):
    """Greedy decode logits at position t must equal forward logits at t."""
    cfg = FAMILIES[fam]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T, n_check = 2, 16, 8
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    cache = model.init_cache(B, T)
    if cfg.enc_layers:
        batch = make_batch(cfg, B=B, S=T)
        enc_out = model.encode(params, batch["src_embeds"])
        cache["cross"] = model.build_cross_cache(params, enc_out)
    dec_step = jax.jit(model.decode_step)
    logits_seq = []
    for t in range(n_check):
        lg, cache = dec_step(params, tok[:, t], cache,
                             jnp.asarray(t, jnp.int32))
        logits_seq.append(lg)
    dec_logits = jnp.stack(logits_seq, axis=1)

    fwd_batch = {"tokens": tok[:, :n_check]}
    if cfg.enc_layers:
        fwd_batch["src_embeds"] = batch["src_embeds"]
    if cfg.mm_positions:
        cfg2 = dataclasses.replace(cfg, mm_positions=0)
        fwd_logits, _ = jax.jit(build_model(cfg2).forward)(params, fwd_batch)
    else:
        fwd_logits, _ = jax.jit(model.forward)(params, fwd_batch)
    err = np.max(np.abs(np.asarray(dec_logits, np.float32)
                        - np.asarray(fwd_logits, np.float32)))
    rel = err / (np.max(np.abs(np.asarray(fwd_logits, np.float32))) + 1e-9)
    assert rel < 1e-4, (fam, rel)


def test_forward_shapes():
    cfg = FAMILIES["dense"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B=3, S=16)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (3, 16, cfg.vocab)


def test_sliding_window_masks_old_tokens():
    """With window w and L layers, token 0's receptive field reaches at most
    L*(w-1) positions; beyond that, logits must be unaffected.  Windowing
    applies to 'attn' blocks (the hybrid families' local attention) —
    'dense' blocks are always full attention."""
    cfg = dataclasses.replace(FAMILIES["dense"], window=4, n_layers=1,
                              block_pattern=("attn",), name="t_win")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0, cfg.vocab)
    lg1, _ = jax.jit(model.forward)(params, {"tokens": tok})
    tok2 = tok.at[0, 0].set((tok[0, 0] + 1) % cfg.vocab)
    lg2, _ = jax.jit(model.forward)(params, {"tokens": tok2})
    d = np.abs(np.asarray(lg1) - np.asarray(lg2))[0]
    # 1 layer: positions >= window cannot see token 0 at all
    assert d[4:].max() < 1e-5, "token 0 leaked past the window"
    assert d[0].max() > 0, "sanity: position 0 must differ"


def test_causality():
    """Changing a future token must not change past logits."""
    cfg = FAMILIES["dense"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(4), (1, 12), 0, cfg.vocab)
    lg1, _ = jax.jit(model.forward)(params, {"tokens": tok})
    tok2 = tok.at[0, 8].set((tok[0, 8] + 1) % cfg.vocab)
    lg2, _ = jax.jit(model.forward)(params, {"tokens": tok2})
    d = np.abs(np.asarray(lg1) - np.asarray(lg2))[0]
    assert d[:8].max() < 1e-5, "future token leaked into the past"


def test_param_count_consistency():
    from repro.models import api
    cfg = FAMILIES["moe_top2"]
    n_total = api.count_params(cfg)
    n_active = api.count_params(cfg, active_only=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_real = sum(x.size for x in jax.tree.leaves(params))
    assert n_total == n_real
    assert n_active < n_total      # top-2 of 4 experts
