"""Substrate layers: optimizers, schedules, data pipeline determinism,
sharding rules, gradient compression, straggler policy, elastic reshard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.synthetic import SyntheticStream, batch_for
from repro.dist import sharding as shd
from repro.dist.elastic import reshard_state
from repro.dist.straggler import StragglerPolicy
from repro.optim import (adafactor, adamw, build_optimizer,
                         clip_by_global_norm, cosine_schedule)
from repro.optim.compress import (init_error_feedback,
                                  make_crosspod_compressed_mean)


# -- optimizers ----------------------------------------------------------------

def test_adamw_matches_reference_update():
    """One AdamW step vs a hand-computed reference."""
    lr = 0.1
    opt = adamw(lambda s: lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, 0.25], jnp.float32)}
    state = opt.init(params)
    new_p, new_s = opt.update(grads, state, params, 0)
    g = np.asarray([0.5, 0.25])
    m = 0.1 * g
    v = 0.001 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray([1.0, -2.0]) - lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_s["m"]["w"]), m, rtol=1e-6)


def test_adamw_bf16_moments_dtype():
    opt = adamw(lambda s: 0.1, moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.float32)}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.bfloat16
    new_p, new_s = opt.update({"w": jnp.ones((4,))}, st, params, 0)
    assert new_s["v"]["w"].dtype == jnp.bfloat16
    assert new_p["w"].dtype == jnp.float32


def test_adafactor_factored_state_shapes():
    opt = adafactor(lambda s: 0.01)
    params = {"w": jnp.ones((8, 16), jnp.float32),
              "b": jnp.ones((16,), jnp.float32)}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (8,)
    assert st["w"]["vc"].shape == (16,)
    assert st["b"]["v"].shape == (16,)
    new_p, _ = opt.update(jax.tree.map(jnp.ones_like, params), st, params, 0)
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(new_p))


def test_adafactor_state_specs():
    opt = adafactor(lambda s: 0.01)
    specs = {"w": P("data", "model"), "b": P()}
    s = opt.state_specs(specs)
    assert s["w"]["vr"] == P("data")
    assert s["w"]["vc"] == P("model")


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.0, abs=1e-6)
    assert float(lr(60)) == pytest.approx(0.5, abs=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}           # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-6)
    not_clipped, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(not_clipped["a"]), [3.0, 4.0])


def test_build_optimizer_dispatch():
    cfg = ModelConfig(name="x", family="dense", n_layers=1, d_model=8,
                      n_heads=1, n_kv=1, d_ff=8, vocab=8)
    assert build_optimizer(TrainConfig(optimizer="adamw"), cfg)
    assert build_optimizer(TrainConfig(optimizer="adafactor"), cfg)


# -- data ----------------------------------------------------------------------

def test_synthetic_stream_deterministic():
    s = SyntheticStream(vocab=128, seq_len=16, global_batch=4, seed=7)
    a = s.batch_at(3)
    b = s.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 128


def test_batch_for_modal_stubs():
    cfg = ModelConfig(name="x", family="vlm", n_layers=1, d_model=8,
                      n_heads=1, n_kv=1, d_ff=8, vocab=64, mm_positions=4)
    s = batch_for(cfg, seq_len=16, global_batch=2)
    b = s.batch_at(0)
    assert b["tokens"].shape == (2, 12)
    assert b["mm_embeds"].shape == (2, 4, 8)


# -- sharding rules --------------------------------------------------------------

def test_spec_for_divisibility_fallback(mesh42):
    # 14 heads don't divide model=2? 14 % 2 == 0 -> sharded
    assert shd.spec_for(mesh42, ("heads",), (14,)) == P("model")
    # 7 doesn't divide 2 -> replicated
    assert shd.spec_for(mesh42, ("heads",), (7,)) == P()
    # batch tries (pod,data) -> absent -> (data,)
    assert shd.spec_for(mesh42, ("batch",), (8,)) == P("data")
    # no double-booking of a mesh axis within one spec
    spec = shd.spec_for(mesh42, ("vocab", "ffn"), (64, 64))
    assert tuple(spec) in ((("model",), None), ("model",)) or \
        spec == P("model")   # second dim must NOT also take "model"
    assert len([a for a in tuple(spec) if a == "model"]) <= 1


def test_spec_for_multipod(mesh_pod):
    assert shd.spec_for(mesh_pod, ("batch",), (8,)) == P(("pod", "data"))
    assert shd.spec_for(mesh_pod, ("embed",), (8,)) == P("data")


# -- gradient compression ----------------------------------------------------------

def test_crosspod_compressed_mean(mesh_pod):
    grads = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(
        (8, 16)).astype(np.float32))}
    specs = {"w": P()}
    ef = init_error_feedback(grads)
    f = make_crosspod_compressed_mean(mesh_pod, specs)
    out, new_ef = f(grads, ef)
    # pods hold identical replicas here, so the mean == the input, up to
    # int8 quantization error bounded by scale = max|g|/127
    scale = float(np.max(np.abs(np.asarray(grads["w"])))) / 127.0
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=scale + 1e-7)
    # error feedback captures exactly the quantization residual
    assert float(np.max(np.abs(np.asarray(new_ef["w"])))) <= scale + 1e-7


def test_error_feedback_reduces_bias(mesh_pod):
    """Accumulated EF keeps the long-run mean unbiased: sum of dequantized
    outputs + final residual == sum of raw gradients (telescoping)."""
    rng = np.random.default_rng(1)
    specs = {"w": P()}
    f = make_crosspod_compressed_mean(mesh_pod, specs)
    g = {"w": jnp.asarray(rng.standard_normal((4, 8)).astype(np.float32))}
    ef = init_error_feedback(g)
    total_out = np.zeros((4, 8), np.float32)
    total_in = np.zeros((4, 8), np.float32)
    for _ in range(5):
        out, ef = f(g, ef)
        total_out += np.asarray(out["w"])
        total_in += np.asarray(g["w"])
    resid = np.asarray(ef["w"])
    np.testing.assert_allclose(total_out + resid, total_in, atol=1e-4)


# -- straggler policy ---------------------------------------------------------------

def test_straggler_policy_drops_slow_replica():
    pol = StragglerPolicy(n_replicas=8, threshold=3.0,
                          max_drop_fraction=0.25)
    for step in range(10):
        for r in range(8):
            pol.observe(r, 1.0 if r != 5 else 10.0)
    mask = pol.replica_mask()
    assert not mask[5]
    assert mask.sum() == 7
    lm = pol.loss_mask(32)
    assert lm.shape == (32,)
    assert lm[5 * 4:6 * 4].sum() == 0
    assert lm.sum() == 28


def test_straggler_policy_respects_max_drop():
    pol = StragglerPolicy(n_replicas=8, threshold=1.5,
                          max_drop_fraction=0.125)
    for step in range(10):
        for r in range(8):
            pol.observe(r, 1.0 if r < 4 else 100.0)
    mask = pol.replica_mask()
    assert (~mask).sum() == 1           # only 12.5% may drop


# -- elastic -------------------------------------------------------------------------

def test_reshard_state_between_meshes(mesh42, mesh81):
    state = {"w": jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)}
    specs42 = {"w": P("data", "model")}
    specs81 = {"w": P("data", None)}
    s1 = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh42, sp)),
        state, specs42)
    s2 = reshard_state(s1, mesh81, specs81)
    np.testing.assert_array_equal(np.asarray(s2["w"]), np.asarray(state["w"]))
    assert s2["w"].sharding.mesh.shape["data"] == 8


def test_elastic_rescale_rebuilds_protection(mesh42, mesh81):
    """Zone geometry changes with G; parity must be rebuilt and still recover."""
    from repro.core.txn import Mode, Protector
    from repro.dist.elastic import rescale
    state = {"w": jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)}
    specs = {"w": P("data", None)}
    st = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh42, sp)),
        state, specs)
    p4 = Protector(mesh42, jax.eval_shape(lambda: st), specs,
                   mode=Mode.MLPC, block_words=16)
    prot4 = p4.init(st)

    def make_protector(new_mesh):
        return Protector(new_mesh, jax.eval_shape(lambda: st), specs,
                         mode=Mode.MLPC, block_words=16)

    p8, prot8 = rescale(p4, prot4, make_protector, mesh81)
    assert p8.group_size == 8
    np.testing.assert_array_equal(np.asarray(prot8.state["w"]),
                                  np.asarray(state["w"]))
    prot_rec, ok = p8.recover_rank(prot8, 3)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(prot_rec.state["w"]),
                                  np.asarray(state["w"]))
