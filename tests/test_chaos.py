"""Chaos campaign (repro/chaos): scripted faults under live traffic.

The load-bearing invariant everywhere: a fault recovered mid-traffic
must leave the state bit-identical to the fault-free golden run — the
deferred engine's flush reads only its own accumulator, never the live
row, so corruption landing inside an open window leaves the refreshed
redundancy describing *intended* values and reconstruction is exact.

Also covered here: the pool-level chaos plumbing the runner rides on —
the fault-arrival hook's firing points, async-safe recovery re-entry,
the actionable budget-exhausted error, post-recovery re-verification,
seeded-injector determinism, Fault.from_event's full taxonomy, and the
straggler policy wired through Pool and Trainer.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.chaos.runner import ScenarioRunner, attach_schedule
from repro.chaos.schedule import ChaosEvent, FaultSchedule
from repro.chaos.workload import PoolWorkload
from repro.configs.base import ProtectConfig
from repro.dist.straggler import StragglerPolicy
from repro.pool import Fault, Pool
from repro.runtime import failure
from tests.conftest import small_state

E = ChaosEvent.make


def _wl(mesh, *, window=4, redundancy=2, seed=3, **cfg_kw):
    cfg = ProtectConfig(mode="mlpc", window=window,
                        redundancy=redundancy, block_words=64, **cfg_kw)
    return PoolWorkload(mesh, cfg, n_bytes=1 << 14, seed=seed)


# -- mid-window fault arrival x engines x stack heights -----------------------

@pytest.mark.parametrize("window", [1, 4])
@pytest.mark.parametrize("red", [1, 2, 3])
def test_midwindow_loss_recovers_to_golden(mesh42, window, red):
    """A rank loss at the in-window arrival point, recovered online,
    must end bit-identical to the fault-free run — for the synchronous
    engine and mid-window in the deferred engine, at every r."""
    wl = _wl(mesh42, window=window, redundancy=red)
    sched = FaultSchedule(
        [E(2, "rank_loss", mid_window=True, rank=1)], seed=7)
    out = ScenarioRunner(wl, sched).run(6)
    assert out["golden_exact"], out
    (rec,) = out["recoveries"]
    assert rec["kind"] == "rank_loss" and rec["verified"]
    assert rec["reverified"] is True


def test_midwindow_scribble_plus_loss_escape_hatch(mesh42):
    """Scribble on rank 0 concurrent with rank 2's loss inside one
    window: the runner folds both into a multi_loss through the r=2
    stack (single parity cannot untangle the overlap)."""
    wl = _wl(mesh42, window=8, redundancy=2)
    sched = FaultSchedule([
        E(3, "scribble", mid_window=True, rank=0, n_words=5),
        E(3, "rank_loss", mid_window=True, rank=2),
    ], seed=11)
    out = ScenarioRunner(wl, sched).run(8)
    assert out["golden_exact"], out
    (rec,) = out["recoveries"]
    assert rec["kind"] == "multi_loss" and rec["verified"]


def test_budget_exhaust_then_rearm(mesh42):
    """e=2 on an r=1 pool trips the budget error; the runner restores
    the snapshot + replays deterministically; a later single loss
    recovers online again — and the whole run still ends golden."""
    wl = _wl(mesh42, window=2, redundancy=1)
    sched = FaultSchedule([
        E(1, "snapshot"),
        E(3, "multi_loss", e=2),
        E(6, "rank_loss"),
    ], seed=5)
    out = ScenarioRunner(wl, sched).run(9)
    assert out["golden_exact"], out
    kinds = [r["kind"] for r in out["recoveries"]]
    assert kinds == ["restore_replay", "rank_loss"]
    assert "syndrome budget exhausted" in out["recoveries"][0]["error"]


def test_rescale_under_traffic_stays_golden(mesh42):
    wl = _wl(mesh42, window=4, redundancy=2)
    sched = FaultSchedule([
        E(2, "rescale", shape=(8, 1)),
        E(4, "rank_loss"),
        E(6, "rescale", shape=(4, 2)),
    ], seed=13)
    out = ScenarioRunner(wl, sched).run(9)
    assert out["golden_exact"], out
    kinds = [r["kind"] for r in out["recoveries"]]
    assert kinds == ["rescale", "rank_loss", "rescale"]


# -- pool plumbing: arrival hook, re-entry, budget error, re-verify -----------

def _pool(mesh, **cfg_kw):
    state, specs, _ = small_state(mesh)
    base = dict(mode="mlpc", block_words=64)
    base.update(cfg_kw)
    return Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(**base), donate=False)


def _evolve(cur):
    return jax.tree.map(lambda x: (x * 1.01 + 0.003).astype(x.dtype), cur)


def test_arrival_hook_fires_between_commit_and_flush(mesh42):
    pool = _pool(mesh42, window=4)
    seen = []
    pool.set_arrival_hook(
        lambda prot, since, at_boundary:
            (seen.append((since, at_boundary)), None)[1])
    for _ in range(4):
        pool.commit(_evolve(pool.state))
    assert seen == [(1, False), (2, False), (3, False), (4, True)]
    pool.set_arrival_hook(None)
    pool.commit(_evolve(pool.state))
    assert len(seen) == 4


def test_arrival_hook_sync_engine_every_commit(mesh42):
    pool = _pool(mesh42, window=1)
    seen = []
    pool.set_arrival_hook(
        lambda prot, since, at_boundary:
            (seen.append((since, at_boundary)), None)[1])
    pool.commit(_evolve(pool.state))
    pool.commit(_evolve(pool.state))
    assert seen == [(1, True), (1, True)]


def test_recover_reentry_queues_and_drains(mesh42):
    """A fault arriving during recovery (via the freeze callback — the
    async path) is queued, drained after the running reconstruction,
    and counted in the outer report's followups."""
    box = {}

    def freeze():
        pool = box["pool"]
        if not box.get("fired"):
            box["fired"] = True
            # second fault lands while the first recovery is in flight
            assert pool.recover(Fault.scribble(0, [0])) is None

    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", block_words=64),
                     donate=False, on_freeze=freeze)
    box["pool"] = pool
    before = jax.device_get(pool.state)
    pool.prot, ev = failure.inject_rank_loss(pool.protector, pool.prot, 1)
    rep = pool.recover(Fault.from_event(ev))
    assert rep.followups == 1
    assert rep.verified and rep.reverified
    after = jax.device_get(pool.state)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_budget_exhausted_error_is_actionable(mesh42):
    pool = _pool(mesh42, redundancy=1)
    pool.prot, ev = failure.inject_multi_rank_loss(
        pool.protector, pool.prot, (0, 2))
    with pytest.raises(RuntimeError) as err:
        pool.recover(Fault.from_event(ev))
    msg = str(err.value)
    assert "syndrome budget exhausted" in msg
    assert "[0, 2]" in msg                   # names the dead ranks
    assert "redundancy=1" in msg             # names the available budget
    assert "pool.init" in msg                # names the re-arm path


def test_post_recovery_reverify_flags_residual_corruption(mesh42):
    """r=1: a scribble outstanding on rank 0 while rank 2 is being
    rebuilt poisons the reconstruction (parity XOR runs through the
    scribbled row); the post-recovery re-verify must surface it."""
    pool = _pool(mesh42, redundancy=1)
    pool.prot, _ = failure.inject_scribble(pool.protector, pool.prot,
                                           rank=0, word_offsets=[5])
    pool.prot, ev = failure.inject_rank_loss(pool.protector, pool.prot, 2)
    rep = pool.recover(Fault.from_event(ev))
    assert rep.reverified is False
    assert rep.verified is False             # folded into the verdict


def test_pool_inject_preserves_open_window(mesh42):
    """Pool.inject must not reset the deferred window's accumulator:
    corrupt mid-window, recover, and the flushed state still matches a
    clean run of the same commits."""
    pool = _pool(mesh42, window=4, redundancy=2)
    ref = _pool(mesh42, window=4, redundancy=2)
    for _ in range(2):                        # window half-open
        pool.commit(_evolve(pool.state))
        ref.commit(_evolve(ref.state))
    ev = pool.inject(
        lambda p, prot: failure.inject_rank_loss(p, prot, 3))
    rep = pool.recover(Fault.from_event(ev))
    assert rep.verified and rep.reverified
    for a, b in zip(jax.tree.leaves(jax.device_get(pool.state)),
                    jax.tree.leaves(jax.device_get(ref.state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- seeded injectors + Fault.from_event taxonomy -----------------------------

def test_seeded_injectors_are_deterministic(mesh42):
    pool_a = _pool(mesh42)
    pool_b = _pool(mesh42)
    plan = failure.scribble_plan(pool_a.protector, seed=42, n_words=4)
    assert plan == failure.scribble_plan(pool_b.protector, seed=42,
                                         n_words=4)
    assert plan != failure.scribble_plan(pool_a.protector, seed=43,
                                         n_words=4)
    pa, ev_a = failure.seeded_scribble(pool_a.protector, pool_a.prot,
                                       seed=42)
    pb, ev_b = failure.seeded_scribble(pool_b.protector, pool_b.prot,
                                       seed=42)
    assert ev_a.locations == ev_b.locations
    for a, b in zip(jax.tree.leaves(jax.device_get(pa.state)),
                    jax.tree.leaves(jax.device_get(pb.state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    _, ev_r = failure.seeded_rank_loss(pool_a.protector, pa, seed=9)
    _, ev_r2 = failure.seeded_rank_loss(pool_b.protector, pb, seed=9)
    assert ev_r.lost_rank == ev_r2.lost_rank
    _, ev_m = failure.seeded_multi_rank_loss(pool_a.protector, pa,
                                             seed=9, e=2)
    _, ev_m2 = failure.seeded_multi_rank_loss(pool_b.protector, pb,
                                              seed=9, e=2)
    assert ev_m.lost_ranks == ev_m2.lost_ranks


def test_fault_from_event_covers_every_kind():
    ev = failure.FailureEvent("rank_loss", lost_rank=2)
    assert Fault.from_event(ev) == Fault.rank_loss(2)
    ev = failure.FailureEvent("multi_loss", lost_ranks=[3, 1])
    assert Fault.from_event(ev) == Fault.multi_loss(1, 3)
    ev = failure.FailureEvent("double_loss", lost_ranks=[0, 2])
    assert Fault.from_event(ev) == Fault.double_loss(0, 2)
    ev = failure.FailureEvent("scribble", locations=[(1, 4), (1, 7)])
    assert Fault.from_event(ev) == Fault.scribble(1, [4, 7])
    with pytest.raises(ValueError, match="canary"):
        Fault.from_event(failure.FailureEvent("canary"))


# -- straggler wiring ---------------------------------------------------------

def test_straggler_collapses_window_then_regrows(mesh42):
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", block_words=64, window=8,
                        straggler_threshold=2.0,
                        window_growth_commits=2)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg,
                     donate=False,
                     straggler_policy=StragglerPolicy(
                         4, threshold=2.0, window=2))
    assert pool.engine.window == 8
    slow = np.asarray([0.01, 0.08, 0.01, 0.01])
    for _ in range(2):
        pool.commit(_evolve(pool.state))
        pool.observe_commit_times(slow)
    assert pool.dropped_replicas == [1]
    assert pool.engine.window == 1            # degraded: collapsed
    healthy = np.full(4, 0.01)
    for _ in range(2):                        # slide the slow samples out
        pool.observe_commit_times(healthy)
    assert pool.dropped_replicas == []
    for _ in range(8):                        # clean commits regrow
        pool.commit(_evolve(pool.state))
    assert pool.engine.window > 1


def test_straggler_threshold_validation():
    with pytest.raises(ValueError, match="straggler_threshold"):
        ProtectConfig(straggler_threshold=-1.0)


def test_trainer_straggler_drops_and_continues(mesh42):
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.runtime.trainer import Trainer
    cfg = ModelConfig(
        name="t_chaos", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
    t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                 total_steps=100),
                ProtectConfig(mode="mlpc", block_words=64,
                              straggler_threshold=2.0),
                mesh42, seq_len=16, global_batch=8)
    t.pool.straggler = StragglerPolicy(4, threshold=2.0, window=2)
    t.initialize()
    t.replica_slowdown[1] = 10.0
    outs = t.run(4)
    assert all(o["committed"] for o in outs)
    assert t.pool.dropped_replicas == [1]
    assert outs[-1].get("dropped_replicas") == [1]
    out = t.step()                    # loss-masked step still commits
    assert out["committed"] and np.isfinite(out["loss"])
    t.replica_slowdown[1] = 1.0
    t.run(2)                          # heals once the window slides
    assert t.pool.dropped_replicas == []


def test_trainer_schedule_attachment(mesh42):
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.runtime.trainer import Trainer
    cfg = ModelConfig(
        name="t_sched", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
    t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                 total_steps=100),
                ProtectConfig(mode="mlpc", block_words=64),
                mesh42, seq_len=16, global_batch=8)
    t.initialize()
    log = attach_schedule(t, FaultSchedule(
        [E(1, "rank_loss", rank=2)], seed=0))
    outs = t.run(3)
    assert all(o["committed"] for o in outs)
    # the log record is the full RecoveryReport.to_event() payload:
    # identity fields plus the timing breakdown the telemetry plane adds
    assert len(log) == 1
    rec = log[0]
    assert rec["step"] == 1 and rec["kind"] == "rank_loss"
    assert rec["verified"] is True and rec["reverified"] is True
    assert rec["lost_rank"] == 2
    assert rec["solve_ms"] >= 0 and rec["total_ms"] >= rec["solve_ms"]
