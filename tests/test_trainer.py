"""End-to-end fault-tolerant training: the Trainer must survive rank loss,
scribbles, and crashes (checkpoint + redo-log replay) without losing a step.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.runtime import failure
from repro.runtime.trainer import Trainer


@pytest.fixture(scope="module")
def trainer_factory(mesh42):
    cfg = ModelConfig(
        name="t_train", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")

    def make(protect_mode="mlpc", scrub_period=0, checkpoint_dir=None,
             seed=0):
        t = Trainer(cfg, TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                     total_steps=100),
                    ProtectConfig(mode=protect_mode, block_words=64,
                                  scrub_period=scrub_period),
                    mesh42, seq_len=32, global_batch=8,
                    checkpoint_dir=checkpoint_dir, seed=seed)
        t.initialize()
        return t

    return make


def test_training_loss_decreases(trainer_factory):
    t = trainer_factory()
    outs = t.run(12)
    assert all(o["committed"] for o in outs)
    assert outs[-1]["step"] == 12
    first = np.mean([o["loss"] for o in outs[:4]])
    last = np.mean([o["loss"] for o in outs[-4:]])
    assert last < first, (first, last)


def test_training_survives_rank_loss(trainer_factory):
    t = trainer_factory()
    t.run(3)
    w_before = np.asarray(jax.tree.leaves(t.prot.state["params"])[0]).copy()
    bad_prot, event = failure.inject_rank_loss(t.protector, t.prot, rank=1)
    t.prot = bad_prot
    report = t.on_failure(event)
    assert report["verified"]
    w_after = np.asarray(jax.tree.leaves(t.prot.state["params"])[0])
    np.testing.assert_array_equal(w_after, w_before)
    out = t.step()                    # training continues
    assert out["committed"]


def test_training_survives_scribble(trainer_factory):
    t = trainer_factory()
    t.run(2)
    w_before = np.asarray(jax.tree.leaves(t.prot.state["params"])[0]).copy()
    bad_prot, event = failure.inject_scribble(t.protector, t.prot, rank=0,
                                              word_offsets=[3, 70])
    t.prot = bad_prot
    report = t.on_failure(event)
    assert report["verified"]
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(t.prot.state["params"])[0]), w_before)


def test_canary_abort_blocks_commit(trainer_factory):
    t = trainer_factory()
    t.run(2)
    before = int(jax.device_get(t.prot.step))
    out = t.step(canary_ok=False)
    assert not out["committed"]
    assert int(jax.device_get(t.prot.step)) == before


def test_periodic_scrub_runs(trainer_factory):
    t = trainer_factory(scrub_period=3)
    outs = t.run(3)
    assert "scrub" in outs[-1], "scrub must fire on the period boundary"
    assert outs[-1]["scrub"]["checked"]
    assert not outs[-1]["scrub"]["bad_locations"]


def test_checkpoint_restore_and_replay(trainer_factory, tmp_path):
    ck = str(tmp_path / "ckpt")
    t = trainer_factory(checkpoint_dir=ck, seed=3)
    t.run(4)
    t.save_checkpoint(wait=True)
    t.run(3)                               # steps 5..7 live only in the log
    digest_before = np.asarray(jax.device_get(t.prot.digest)).copy()
    step_before = int(jax.device_get(t.prot.step))

    # "crash": new trainer, same config/seed, restore + replay
    t2 = trainer_factory(checkpoint_dir=ck, seed=3)
    # replaying needs the redo log from the crashed run (in production the
    # log is replicated in peer HBM / host RAM; here we hand it over)
    t2._ckpt_mgr = t._ckpt_mgr
    info = t2.restore_from_checkpoint(replay=False)
    assert info["restored_step"] == 4
    # manual replay: run the same number of steps; determinism must hold
    for _ in range(step_before - 4):
        t2.step()
    digest_after = np.asarray(jax.device_get(t2.prot.digest))
    np.testing.assert_array_equal(digest_after, digest_before)


def test_replica_mode_trains(trainer_factory):
    t = trainer_factory(protect_mode="replica")
    outs = t.run(3)
    assert outs[-1]["step"] == 3
    # replica mirrors the state
    a = jax.tree.leaves(t.prot.state["params"])[0]
    b = jax.tree.leaves(t.prot.replica["params"])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_none_mode_trains(trainer_factory):
    t = trainer_factory(protect_mode="none")
    outs = t.run(3)
    assert outs[-1]["step"] == 3
    assert t.prot.parity is None and t.prot.cksums is None


def test_restore_replay_from_serialized_log(trainer_factory, tmp_path):
    """Crash recovery must work from the DISK round-trip of the redo log
    (manifest serializes RedoLog as a jsonable dict), not only from a live
    in-memory handover — regression test for the dict-form restore path."""
    ck = str(tmp_path / "ckpt2")
    t = trainer_factory(checkpoint_dir=ck, seed=5)
    t.run(3)
    t.save_checkpoint(wait=True)
    t.run(2)                         # steps 4..5 live only in the log
    t.save_checkpoint(wait=True)     # persists log alongside step 5
    digest_before = np.asarray(jax.device_get(t.prot.digest)).copy()

    t2 = trainer_factory(checkpoint_dir=ck, seed=5)
    info = t2.restore_from_checkpoint(replay=True)
    assert info["restored_step"] == 5
    # same protected digest after restore+replay path
    t2.run(1)
    t.run(1)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(t2.prot.digest)),
        np.asarray(jax.device_get(t.prot.digest)))
