"""Shared fixtures.

The protection core runs inside shard_map over a ("data", "model") mesh, so
the test process forces EIGHT host devices (not 512 — the production-mesh
dry-run owns that flag and runs as its own process; keeping the test count
small keeps CPU smoke tests fast).  This must happen before jax's first
import anywhere in the pytest process, which conftest guarantees.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

try:                             # the container image may not ship hypothesis
    import hypothesis            # noqa: F401
except ImportError:
    from tests import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub
    _hypothesis_stub.strategies = _hypothesis_stub

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@pytest.fixture(scope="session")
def mesh42() -> Mesh:
    """4-way data (zone) axis x 2-way model axis."""
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh81() -> Mesh:
    """8-way data axis (pure zone; power of two for tree reduce)."""
    return jax.make_mesh((8, 1), ("data", "model"))


@pytest.fixture(scope="session")
def mesh_pod() -> Mesh:
    """Tiny multi-pod mesh (2 pods x 2 data x 2 model)."""
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


def small_state(mesh):
    """Heterogeneous protected state: f32 FSDP+TP, bf16 TP, replicated scalar."""
    specs = {
        "w1": P("data", "model"),
        "w2": P(None, "model"),
        "scale": P(),
    }
    state = {
        "w1": jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) * 0.1,
        "w2": (jnp.arange(16 * 32, dtype=jnp.float32) * 0.01
               ).astype(jnp.bfloat16).reshape(16, 32),
        "scale": jnp.float32(3.25),
    }
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(jax.device_put, state, shardings)
    return state, specs, shardings


@pytest.fixture()
def tiny_dense_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="t_dense", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
