"""Minimal deterministic stand-in for `hypothesis` (not installed here).

The container image does not ship hypothesis and installing packages is
off-limits, so conftest registers this module under ``sys.modules
["hypothesis"]`` when the real library is missing.  It implements exactly
the surface the test-suite uses — ``given``, ``settings`` and the
``integers`` / ``sampled_from`` / ``sets`` / ``data`` strategies — as a
deterministic example sweep: each ``@given`` test runs ``max_examples``
times with examples drawn from per-iteration seeded numpy generators, so
failures reproduce exactly.  No shrinking, no database; if the real
hypothesis is present it is always preferred.
"""
from __future__ import annotations

import numpy as np


class Strategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example(self, rng) -> object:
        return self._draw_fn(rng)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def sets(elements: Strategy, min_size: int = 0, max_size: int = 10
         ) -> Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        out = set()
        attempts = 0
        while len(out) < size and attempts < 1000:
            out.add(elements.example(rng))
            attempts += 1
        return out
    return Strategy(draw)


class _Data:
    """Interactive draw object handed to tests that request st.data()."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy: Strategy):
        return strategy.example(self._rng)


def data() -> Strategy:
    return Strategy(lambda rng: _Data(rng))


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            # read lazily so @settings composes in either decorator order
            n_examples = getattr(wrapper, "_max_examples",
                                 getattr(fn, "_max_examples", 20))
            for i in range(n_examples):
                rng = np.random.default_rng(7919 * i + 13)
                drawn = tuple(s.example(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)
        # deliberately NOT functools.wraps: pytest must see the zero-arg
        # signature, not the wrapped test's strategy parameters (it would
        # try to resolve them as fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
