"""Deferred-epoch engine (core/epoch.py): bit-identity with the synchronous
engine at every epoch boundary, per-step digest maintenance for replay,
crash recovery across a window, donation (allocation-free steady state),
and the serving patch-path wiring the engine subsumes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import layout as layout_mod
from repro.core import redolog
from repro.core.epoch import DeferredProtector
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector
from tests.conftest import small_state


def make_protector(mesh, state, specs, mode, **kw):
    kw.setdefault("block_words", 64)
    return Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                     **kw)


@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs, shardings


def _assert_protection_equal(pa, pb, mode):
    # the whole syndrome stack (every S_k plane) must match bit-for-bit
    np.testing.assert_array_equal(np.asarray(pa.synd), np.asarray(pb.synd))
    np.testing.assert_array_equal(np.asarray(pa.digest), np.asarray(pb.digest))
    np.testing.assert_array_equal(np.asarray(pa.row), np.asarray(pb.row))
    if mode.has_cksums:
        np.testing.assert_array_equal(np.asarray(pa.cksums),
                                      np.asarray(pb.cksums))


@pytest.mark.parametrize("mode,red", [(Mode.MLPC, 1), (Mode.MLP, 1),
                                      (Mode.MLPC, 2), (Mode.MLPC, 3)])
def test_bulk_engine_matches_sync_at_boundaries(setup, mode, red):
    """W full-state commits + one flush must land exactly where W
    synchronous commits land: syndromes, cksums, digest, row AND the
    redo log's per-step digests (the engine keeps the digest current
    inside the window, so every record stays replay-verifiable)."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, mode, redundancy=red)
    prot_sync = p.init(state)
    eng = DeferredProtector(p, window=4, donate=False)
    est = eng.init(state)
    cur = state
    for i in range(8):
        cur = jax.tree.map(lambda x: (x * 1.01 + 0.003).astype(x.dtype), cur)
        key = jax.random.PRNGKey(i)
        prot_sync, ok_s = p.commit(prot_sync, cur, rng_key=key,
                                   data_cursor=i)
        est, ok_d = eng.commit(est, cur, rng_key=key, data_cursor=i)
        assert bool(ok_s) and bool(ok_d)
        # digest bit-identical at EVERY step, not only at boundaries
        np.testing.assert_array_equal(np.asarray(prot_sync.digest),
                                      np.asarray(est.prot.digest))
        if (i + 1) % 4 == 0:
            _assert_protection_equal(prot_sync, est.prot, mode)
    np.testing.assert_array_equal(np.asarray(prot_sync.log.digest),
                                  np.asarray(est.prot.log.digest))
    np.testing.assert_array_equal(np.asarray(prot_sync.log.mark),
                                  np.asarray(est.prot.log.mark))
    # flushed parity supports online recovery
    rec, okr = p.recover_rank(est.prot, 2)
    assert bool(okr) or not mode.has_cksums
    np.testing.assert_array_equal(np.asarray(rec.state["w1"]),
                                  np.asarray(cur["w1"]))


@pytest.mark.parametrize("mode,red", [(Mode.MLPC, 1), (Mode.MLP, 1),
                                      (Mode.MLPC, 2), (Mode.MLPC, 3)])
@pytest.mark.parametrize("words", ["full", "dynamic"])
def test_patch_engine_matches_sync(setup, mode, red, words):
    """The decode-style engine commits against a static dirty-leaf set —
    either wholly-dirty leaves or a dynamic word-index array (one
    compiled program for every position) — and must match the
    static-dirty-set synchronous commit bit-for-bit, including at epoch
    boundaries where the flush lands the syndrome stack and checksums."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, mode, redundancy=red)
    prot_sync = p.init(state)
    lo = p.layout
    pages = layout_mod.leaf_pages(lo, 1).tolist()      # w1's page columns
    eng = DeferredProtector(p, window=3, dirty_leaf_idx=[1], donate=False)
    est = eng.init(state)
    n_words = lo.slots[1].n_words
    dirty_words = (None if words == "full"
                   else (np.arange(n_words, dtype=np.int32),))
    cur = state
    for i in range(6):
        cur = dict(cur)
        cur["w1"] = cur["w1"] * 1.02 + 0.5
        key = jax.random.PRNGKey(10 + i)
        prot_sync, ok_s = p.commit(prot_sync, cur, dirty_pages=pages,
                                   rng_key=key)
        est, ok_d = eng.commit(est, cur, dirty_words=dirty_words,
                               rng_key=key)
        assert bool(ok_s) and bool(ok_d)
        np.testing.assert_array_equal(np.asarray(prot_sync.digest),
                                      np.asarray(est.prot.digest))
        if (i + 1) % 3 == 0:
            _assert_protection_equal(prot_sync, est.prot, mode)
    rec, okr = p.recover_rank(est.prot, 1)
    assert bool(okr) or not mode.has_cksums
    np.testing.assert_array_equal(np.asarray(rec.state["w1"]),
                                  np.asarray(cur["w1"]))


def test_patch_engine_partial_word_updates(setup):
    """Word-granular commits: only the words named in `dirty_words`
    changed; digest and flush must stay bit-identical to sync even when
    the dirty region is a slice of a leaf and OOB overhang entries are
    gathered with fill semantics."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot_sync = p.init(state)
    lo = p.layout
    eng = DeferredProtector(p, window=2, dirty_leaf_idx=[1], donate=False)
    est = eng.init(state)
    n_words = lo.slots[1].n_words
    cur = state
    for i in range(4):
        cur = dict(cur)
        w1 = np.asarray(cur["w1"]).copy()
        w1[i % w1.shape[0], :5] += 3.25          # one row of w1 per step
        cur["w1"] = jax.device_put(jnp.asarray(w1), cur["w1"].sharding)
        # local words of the modified row (w1 is (8,64) f32 over a 4x2
        # mesh -> local (2,32); every rank runs the same index program)
        lrows, lcols = 2, 32
        lr = (i % 8) % lrows
        widx = np.arange(lr * lcols, (lr + 1) * lcols,
                         dtype=np.int32)          # conservative: full row
        widx = np.concatenate([widx,
                               np.full(4, n_words + 1, np.int32)])  # OOB
        pages = layout_mod.leaf_pages(lo, 1).tolist()
        key = jax.random.PRNGKey(30 + i)
        prot_sync, ok_s = p.commit(prot_sync, cur, dirty_pages=pages,
                                   rng_key=key)
        est, ok_d = eng.commit(est, cur, dirty_words=(widx,), rng_key=key)
        assert bool(ok_s) and bool(ok_d)
        np.testing.assert_array_equal(np.asarray(prot_sync.digest),
                                      np.asarray(est.prot.digest))
        if (i + 1) % 2 == 0:
            _assert_protection_equal(prot_sync, est.prot, Mode.MLPC)


def test_flush_patches_last_page_despite_fill_slots(setup):
    """Regression: the flush's nonzero fill slots must route to the
    out-of-range sentinel, not clamp onto page n_blocks-1 — a clamped
    fill's zero-delta scatter entry could overwrite the real parity
    patch for a genuinely-dirty last page (duplicate-index .at[].set
    keeps only one value)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = setup[0]
    # leaf "z" is one word in the row's FINAL page column; a high hybrid
    # threshold keeps the flush on the patch path, and the window bound
    # leaves fill slots alongside the one real dirty page
    specs = {"a": P("data"), "z": P()}
    state = {"a": jnp.arange(4 * 192, dtype=jnp.float32),
             "z": jnp.float32(1.5)}
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(jax.device_put, state, sh)
    p = make_protector(mesh, state, specs, Mode.MLPC,
                       hybrid_threshold=0.95)
    prot_sync = p.init(state)
    lo = p.layout
    last_pages = layout_mod.leaf_pages(lo, 1).tolist()
    assert last_pages == [lo.n_blocks - 1], (last_pages, lo.n_blocks)
    eng = DeferredProtector(p, window=2, dirty_leaf_idx=[1], donate=False)
    assert eng.flush_patch and eng.flush_capacity > len(last_pages), \
        "setup must exercise patch flush with fill slots"
    est = eng.init(state)
    cur = state
    for i in range(2):
        cur = dict(cur)
        cur["z"] = cur["z"] * 2 + 1
        key = jax.random.PRNGKey(40 + i)
        prot_sync, ok_s = p.commit(prot_sync, cur, dirty_pages=last_pages,
                                   rng_key=key)
        est, ok_d = eng.commit(est, cur, rng_key=key)
        assert bool(ok_s) and bool(ok_d)
    _assert_protection_equal(prot_sync, est.prot, Mode.MLPC)


def test_abort_mid_window_leaves_window_intact(setup):
    """A canary abort inside a window must leave row, digest, accumulator
    and dirty mask untouched, and the eventual flush must still match the
    synchronous engine over the committed steps only."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot_sync = p.init(state)
    eng = DeferredProtector(p, window=3, donate=False)
    est = eng.init(state)
    cur = state
    # step 1 commits on both engines
    cur = jax.tree.map(lambda x: (x * 1.5).astype(x.dtype), cur)
    prot_sync, _ = p.commit(prot_sync, cur, rng_key=jax.random.PRNGKey(0))
    est, _ = eng.commit(est, cur, rng_key=jax.random.PRNGKey(0))
    # step 2 aborts on both
    row_before = np.asarray(est.prot.row).copy()
    digest_before = np.asarray(est.prot.digest).copy()
    bad = jax.tree.map(jnp.zeros_like, cur)
    prot_sync, ok_s = p.commit(prot_sync, bad, canary_ok=False)
    est, ok_d = eng.commit(est, bad, canary_ok=False)
    assert not bool(ok_s) and not bool(ok_d)
    np.testing.assert_array_equal(np.asarray(est.prot.row), row_before)
    np.testing.assert_array_equal(np.asarray(est.prot.digest),
                                  digest_before)
    assert int(est.pending) == 1
    # step 3 commits; window closes (3 attempts)
    cur = jax.tree.map(lambda x: (x + 1).astype(x.dtype), cur)
    prot_sync, _ = p.commit(prot_sync, cur, rng_key=jax.random.PRNGKey(2))
    est, _ = eng.commit(est, cur, rng_key=jax.random.PRNGKey(2))
    assert not eng.needs_flush
    _assert_protection_equal(prot_sync, est.prot, Mode.MLPC)


def test_deferred_commit_is_allocation_free(setup):
    """Steady-state patch commits donate the old EpochState: the pinned
    row rides along untouched, the donated digest/log/dirty buffers are
    consumed, and the compiled step program's outputs alias its inputs
    instead of allocating fresh row-sized buffers.  (The bulk engine
    necessarily rewrites its row from the flatten each step; the
    allocation-free guarantee targets the serving hot path.)"""
    mesh, state, specs, _ = setup
    # the donating engine consumes its inputs — keep the shared fixture's
    # arrays out of the donated pytree
    state = jax.tree.map(jnp.copy, state)
    p = make_protector(mesh, state, specs, Mode.MLPC)
    eng = DeferredProtector(p, window=8, dirty_leaf_idx=[1], donate=True)
    est = eng.init(state)
    cur = state
    for i in range(3):
        cur = dict(cur)
        cur["w1"] = cur["w1"] * 1.01
        prev = est
        est, ok = eng.commit(est, cur, rng_key=jax.random.PRNGKey(i))
        assert bool(ok)
        assert prev.prot.digest.is_deleted(), "old digest must donate forward"
        assert prev.prot.log.mark.is_deleted(), "old log must donate forward"
        assert prev.dirty.is_deleted(), "old dirty mask must donate forward"
    stepfn = eng._jit["step"]
    ma = stepfn.lower(est.prot, est.dirty, est.pending, est.acc, cur,
                      None, 0, jax.random.PRNGKey(9), True).compile(
                      ).memory_analysis()  # (prot, dirty, pending, acc,
                                           #  state_new, dirty_words, ...)
    if ma is not None:                      # backend-dependent availability
        per_dev_row = est.prot.row.nbytes // len(jax.devices())
        unaliased = ma.output_size_in_bytes - ma.alias_size_in_bytes
        assert unaliased < per_dev_row, (
            f"{unaliased}B of un-aliased output — a row-sized buffer is "
            "being reallocated per commit")


def test_mid_window_scribble_detected_after_flush(setup):
    """The flush refreshes checksums from the *cached row*, which a state
    scribble never touched — so corruption that lands inside a window is
    still detected (and repaired to the intended values) by the first
    post-flush scrub, only with window latency."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    eng = DeferredProtector(p, window=4, donate=False)
    est = eng.init(state)
    cur = dict(state)
    for i in range(2):
        cur = dict(cur)
        cur["w1"] = cur["w1"] * 1.1 + 0.25
        est, ok = eng.commit(est, cur, rng_key=jax.random.PRNGKey(i))
        assert bool(ok)
    intended = np.asarray(est.prot.state["w1"]).copy()
    # scribble the live state mid-window (rank 1 holds rows 2:4 of w1)
    scr = intended.copy()
    scr[2, 3] = -77.5
    bad = dict(est.prot.state)
    bad["w1"] = jax.device_put(scr, shardings["w1"])
    est = dataclasses.replace(est,
                              prot=dataclasses.replace(est.prot, state=bad))
    est = eng.flush(est)
    scrubber = Scrubber(p, period=1)
    prot, report = scrubber.run(est.prot)
    assert report.bad_locations, "post-flush scrub must detect the scribble"
    assert report.repair_ok
    assert not report.row_cache_ok, "cache-vs-state divergence must be seen"
    np.testing.assert_array_equal(np.asarray(prot.state["w1"]), intended)


def test_crash_replay_across_deferred_window(trainer_cfg, mesh42, tmp_path):
    """ISSUE acceptance: kill mid-epoch, restore the checkpoint, replay
    the marked redo records, and land bit-identically to the synchronous
    engine — row, parity, cksums and digest."""
    from repro.configs.base import ProtectConfig, TrainConfig
    from repro.runtime.trainer import Trainer

    def make(window, ckpt=None):
        t = Trainer(trainer_cfg,
                    TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                total_steps=100),
                    ProtectConfig(mode="mlpc", block_words=64,
                                  window=window),
                    mesh42, seq_len=32, global_batch=8,
                    checkpoint_dir=ckpt, seed=7)
        t.initialize()
        return t

    # synchronous reference: 5 steps
    t_sync = make(window=1)
    t_sync.run(5)

    # deferred run: checkpoint at step 2, "crash" at step 5 (mid-epoch:
    # window=4 flushed after step 4, step 5 pending in the accumulator)
    ck = str(tmp_path / "ckpt")
    t = make(window=4, ckpt=ck)
    t.run(2)
    t.save_checkpoint(wait=True)
    t.run(3)
    assert t._engine.needs_flush, "crash point must be strictly mid-epoch"
    crash_log = jax.device_get(t.prot.log)   # replicated in peer HBM

    # restore + replay the marked records on a fresh deferred trainer
    t2 = make(window=4, ckpt=ck)
    t2._ckpt_mgr = t._ckpt_mgr
    info = t2.restore_from_checkpoint(replay=False)
    assert info["restored_step"] == 2
    log = redolog.RedoLog(*[jnp.asarray(x) for x in (
        crash_log.step, crash_log.data_cursor, crash_log.rng,
        crash_log.digest, crash_log.mark)])
    for s in redolog.replayable_steps(log, 2):
        rec = redolog.lookup(log, s)
        t2.cursor = int(jax.device_get(rec["data_cursor"]))
        t2.step()
        # every replayed step must reproduce the logged digest — the
        # deferred engine maintains the digest per step so even the
        # mid-window record (step 5) is verifiable
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(t2.prot.digest)).reshape(-1, 2)[0],
            np.asarray(jax.device_get(rec["digest"])))
    assert int(jax.device_get(t2.prot.step)) == 5
    t2.flush()
    t_sync.flush()                           # no-op (window=1)
    _assert_protection_equal(t_sync.prot, t2.prot, Mode.MLPC)


def test_trainer_overlap_commit_matches_sync(trainer_cfg, mesh42):
    """overlap_commit only changes *when* commits are awaited, never what
    they compute: losses, step ids and protection must be bit-identical
    to the non-overlapped run."""
    from repro.configs.base import ProtectConfig, TrainConfig
    from repro.runtime.trainer import Trainer

    def make(overlap):
        t = Trainer(trainer_cfg,
                    TrainConfig(learning_rate=1e-3, warmup_steps=2,
                                total_steps=100),
                    ProtectConfig(mode="mlpc", block_words=64, window=4,
                                  overlap_commit=overlap),
                    mesh42, seq_len=32, global_batch=8, seed=11)
        t.initialize()
        return t

    t_a, t_b = make(False), make(True)
    outs_a, outs_b = t_a.run(6), t_b.run(6)
    assert [o["step"] for o in outs_a] == [o["step"] for o in outs_b]
    assert all(o["committed"] for o in outs_b)
    np.testing.assert_array_equal(
        np.asarray([o["loss"] for o in outs_a]),
        np.asarray([o["loss"] for o in outs_b]))
    t_a.flush(), t_b.flush()
    _assert_protection_equal(t_a.prot, t_b.prot, Mode.MLPC)


@pytest.fixture(scope="module")
def trainer_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="t_epoch", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")


def test_elastic_rescale_windowed_rebuilds_all_syndromes(setup, mesh81):
    """ISSUE satellite: elastic rescale under W>1 must flush-before-
    rescale, then rebuild EVERY syndrome bit-exactly on the new mesh
    geometry (G changes 4 -> 8: new segment lengths, new page->owner
    map, new Vandermonde coefficients g^(k·i) for all r rows)."""
    from repro.dist import elastic
    mesh, state, specs, _ = setup
    state = jax.tree.map(jnp.copy, state)
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=3)
    eng = DeferredProtector(p, window=3, donate=False)
    est = eng.init(state)
    cur = state
    for i in range(2):          # strictly mid-window: 2 of 3 commits
        cur = jax.tree.map(lambda x: (x * 1.01 + 0.02).astype(x.dtype), cur)
        est, ok = eng.commit(est, cur, rng_key=jax.random.PRNGKey(i))
        assert bool(ok)
    assert eng.needs_flush

    def make_protector_new(new_mesh):
        return make_protector(new_mesh, state, specs, Mode.MLPC,
                              redundancy=3)

    p_new, prot_new = elastic.rescale_windowed(eng, est,
                                               make_protector_new, mesh81)
    assert not eng.needs_flush, "rescale must have flushed the window"
    assert p_new.group_size == 8 and p.group_size == 4
    # the moved state is bit-exact...
    for k, v in cur.items():
        np.testing.assert_array_equal(np.asarray(prot_new.state[k]),
                                      np.asarray(v))
    # ...every syndrome verifies on the new geometry, bit-identical to a
    # fresh rebuild of the same state there
    rep = p_new.scrub(prot_new)
    assert np.asarray(rep["synd_ok"]).shape == (3,)
    assert np.asarray(rep["synd_ok"]).all()
    assert not np.asarray(rep["bad_pages"]).any()
    fresh = p_new.init(prot_new.state)
    _assert_protection_equal(fresh, prot_new, Mode.MLPC)
    # and the new zone still solves a triple loss
    from repro.runtime import failure
    snap = np.asarray(prot_new.state["w1"]).copy()
    bad, ev = failure.inject_multi_rank_loss(p_new, prot_new, (2, 5, 7))
    rec, ok = p_new.recover_e(bad, ev.lost_ranks)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(rec.state["w1"]), snap)


# -- serving wiring -----------------------------------------------------------

def _xla_bytes(jitted, *args, **kw) -> float:
    cost = jitted.lower(*args, **kw).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("bytes accessed", 0.0))


@pytest.fixture(scope="module")
def served(mesh42, trainer_cfg):
    from repro.models.transformer import build_model
    model = build_model(trainer_cfg, mesh42)
    params = model.init(jax.random.PRNGKey(0))
    return trainer_cfg, params


def test_server_decode_commit_takes_patch_path(served, mesh42):
    """Regression gate for the bulk-commit bypass: the Server's decode
    commit must compile to a dirty-page program whose bytes-accessed are
    strictly below the bulk (whole cache) commit's."""
    from repro.configs.base import ProtectConfig
    from repro.runtime.server import Server
    cfg, params = served
    srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=64), mesh42,
                 batch=4, max_len=32, window=1)
    srv.start(params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 3), 0, cfg.vocab)
    srv.prefill(prompt)
    keys = [k for k in srv.protector._jit_cache if k[0] == "commit"]
    assert keys and all(k[1] is not None and len(k[1]) > 0 for k in keys), (
        "decode commits must be keyed by a non-empty dirty-page set "
        f"(got {keys})")
    p = srv.protector
    pages = srv._dirty_pages(0).tolist()
    prot = p.init(srv.prot.state)
    new_cache = srv.prot.state
    patch = _xla_bytes(jax.jit(p.make_commit(dirty_pages=pages)),
                       prot, new_cache)
    bulk = _xla_bytes(jax.jit(p.make_commit()), prot, new_cache)
    assert patch < bulk, (patch, bulk)


def test_server_deferred_window_matches_sync(served, mesh42):
    """Windowed serving must decode identically to W=1 and leave
    protection bit-identical to a fresh rebuild of the final cache."""
    from repro.configs.base import ProtectConfig
    from repro.runtime.server import Server
    cfg, params = served
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 5), 0, cfg.vocab)
    outs = {}
    for window in (1, 4):
        srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=64),
                     mesh42, batch=4, max_len=32, window=window)
        srv.start(params)
        outs[window] = srv.generate(prompt, n_new=4)
        srv.flush()
        fresh = srv.protector.init(srv.prot.state)
        _assert_protection_equal(fresh, srv.prot, Mode.MLPC)
    np.testing.assert_array_equal(outs[1], outs[4])


def test_server_deferred_amortized_bytes_below_sync(served, mesh42):
    """The Vilamb claim on this stack, deterministically: amortized
    compiled bytes per decode step with W=16 must be strictly below the
    synchronous per-step program's — and the in-window step itself must
    be far below it (its protection work is proportional to the words a
    decode step writes, not to the row)."""
    from repro.configs.base import ProtectConfig
    from repro.runtime.server import Server
    cfg, params = served
    W = 16
    srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=64), mesh42,
                 batch=4, max_len=32, window=W)
    srv.start(params)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (4, 2), 0, cfg.vocab)
    srv.prefill(prompt)                      # compiles step program
    eng = srv._engine
    est = srv._est
    cache = est.prot.state
    step_b = _xla_bytes(eng._jit["step"], est.prot, est.dirty, est.pending,
                        est.acc, cache, srv._dirty_words(0), 0, None, True)
    flush_b = _xla_bytes(eng._jitted("flush", eng.make_flush), est)
    p = srv.protector
    pages = srv._dirty_pages(0).tolist()
    sync_b = _xla_bytes(jax.jit(p.make_commit(dirty_pages=pages)),
                        p.init(cache), cache)
    amortized = (step_b * W + flush_b) / W
    assert amortized < sync_b, (amortized, sync_b, step_b, flush_b)
    assert step_b < 0.75 * sync_b, (step_b, sync_b)
