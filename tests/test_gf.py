"""Dual-parity erasure subsystem: GF(2^32) arithmetic, the gf_parity
Pallas kernel family vs its oracles, P+Q commit threading (P path must
stay bit-identical to single-parity modes), two-rank reconstruction
(including mid-window at W=16 and rank-loss-with-outstanding-scribble),
adaptive window feedback, window-metadata replication, and ProtectConfig
validation."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gf
from repro.core import layout as layout_mod
from repro.core.epoch import DeferredProtector
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector, resolve_mode
from repro.kernels import gf_parity as gfk
from repro.kernels import ref
from repro.runtime import failure
from tests.conftest import small_state

U32 = jnp.uint32


def make_protector(mesh, state, specs, mode, **kw):
    kw.setdefault("block_words", 64)
    return Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                     **kw)


# -- field arithmetic ---------------------------------------------------------

def test_gf_field_properties_host():
    """GF(2^32) under POLY is a field with primitive g=2: spot-check the
    group axioms, inverses, and distributivity on random samples."""
    rng = random.Random(0)
    for _ in range(50):
        a, b, c = (rng.getrandbits(32) for _ in range(3))
        assert gf.mul_int(a, b) == gf.mul_int(b, a)
        assert gf.mul_int(a, gf.mul_int(b, c)) == \
            gf.mul_int(gf.mul_int(a, b), c)
        assert gf.mul_int(a, b ^ c) == gf.mul_int(a, b) ^ gf.mul_int(a, c)
        assert gf.mul_int(a, 1) == a
        if a:
            assert gf.mul_int(a, gf.inv_int(a)) == 1
    # the per-rank coefficients are distinct and nonzero (primitivity)
    table = gf.pow_g_table(64)
    assert len(set(table)) == 64 and 0 not in table
    with pytest.raises(ZeroDivisionError):
        gf.inv_int(0)


def test_gf_device_matches_host():
    """jnp mul_const / mul_pow_g lanes agree with exact host integers."""
    rng = random.Random(1)
    words = np.asarray([rng.getrandbits(32) for _ in range(256)], np.uint32)
    x = jnp.asarray(words)
    for coeff in [1, 2, 3, 0x80000000, 0xDEADBEEF, gf.pow_g_int(7)]:
        want = np.asarray([gf.mul_int(int(w), coeff) for w in words],
                          np.uint32)
        np.testing.assert_array_equal(
            np.asarray(gf.mul_const(x, coeff)), want)
    for k in [0, 1, 5, 31, 40]:
        np.testing.assert_array_equal(
            np.asarray(gf.mul_pow_g(x, k)),
            np.asarray(gf.mul_const(x, gf.pow_g_int(k))))


def test_gf_solve_two_roundtrip():
    """The 2x2 Vandermonde solve recovers both lost rows exactly."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 1 << 32, 512, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 1 << 32, 512, dtype=np.uint32))
    for ra, rb in [(0, 1), (1, 3), (2, 7), (0, 63)]:
        p = a ^ b
        q = gf.mul_pow_g(a, ra) ^ gf.mul_pow_g(b, rb)
        got_a, got_b = gf.solve_two(p, q, ra, rb)
        np.testing.assert_array_equal(np.asarray(got_a), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(b))


# -- kernels vs oracles -------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 64), (5, 128), (1, 256)])
def test_gf_kernels_match_oracles(shape):
    """The gf_parity Pallas kernels (interpret mode) are bit-identical to
    the jnp oracles on every output."""
    rng = np.random.default_rng(3)
    old = jnp.asarray(rng.integers(0, 1 << 32, shape, dtype=np.uint32))
    new = jnp.asarray(rng.integers(0, 1 << 32, shape, dtype=np.uint32))
    stored = jnp.asarray(
        rng.integers(0, 1 << 32, (shape[0], 2), dtype=np.uint32))
    coeff = jnp.asarray(0xC0FFEE42, U32)

    np.testing.assert_array_equal(
        np.asarray(gfk.gf_scale(old, coeff, interpret=True)),
        np.asarray(ref.gf_scale_ref(old, coeff)))

    got = gfk.fused_commit_pq(old, new, coeff, interpret=True)
    want = ref.fused_commit_pq_ref(old, new, coeff)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    got = gfk.fused_verify_commit_pq(old, new, stored, coeff,
                                     interpret=True)
    want = ref.fused_verify_commit_pq_ref(old, new, stored, coeff)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    got = gfk.fused_commit_old_terms_pq(old, new, coeff, interpret=True)
    want = ref.fused_commit_old_terms_pq_ref(old, new, coeff)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_gf_scale_1d_and_verify_flags():
    """1-D dispatch path, and a corrupted old block flips the verify bit."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(0, 1 << 32, 2048, dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(gfk.gf_scale(x, 7, interpret=True)),
        np.asarray(gf.mul_const(x, 7)))
    old = jnp.asarray(rng.integers(0, 1 << 32, (4, 64), dtype=np.uint32))
    new = old ^ U32(1)
    stored = ref.fletcher_blocks_ref(old)
    _, _, _, bad = gfk.fused_verify_commit_pq(old, new, stored, 3,
                                              interpret=True)
    assert not np.asarray(bad).any()
    smashed = old.at[2, 5].set(old[2, 5] ^ U32(0x40))
    _, _, _, bad = gfk.fused_verify_commit_pq(smashed, new, stored, 3,
                                              interpret=True)
    np.testing.assert_array_equal(np.asarray(bad),
                                  [False, False, True, False])


# -- P+Q commit threading -----------------------------------------------------

@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs, shardings


def _q_verifies(p, prot) -> bool:
    return bool(jax.device_get(p.scrub(prot)["qparity_ok"]))


@pytest.mark.parametrize("base,dual", [(Mode.MLPC, Mode.MLPC2),
                                       (Mode.MLP, Mode.MLP2)])
def test_dual_parity_p_path_bit_identical(setup, base, dual):
    """redundancy=2 must not perturb the single-parity engine: P, cksums,
    digest and row stay bit-identical to the base mode across bulk,
    patch, and verify_old commits — with Q verifying at every step."""
    mesh, state, specs, _ = setup
    p1 = make_protector(mesh, state, specs, base)
    p2 = make_protector(mesh, state, specs, dual)
    a, b = p1.init(state), p2.init(state)
    lo = p2.layout
    pages = layout_mod.leaf_pages(lo, 1).tolist()
    cur = state
    plans = [dict(), dict(dirty_pages=pages),
             dict(verify_old=True), dict(dirty_pages=pages,
                                         verify_old=base.has_cksums)]
    for i, kw in enumerate(plans):
        cur = dict(cur)
        cur["w1"] = cur["w1"] * 1.01 + 0.25
        key = jax.random.PRNGKey(i)
        a, ok_a = p1.commit(a, cur, rng_key=key, **kw)
        b, ok_b = p2.commit(b, cur, rng_key=key, **kw)
        assert bool(ok_a) and bool(ok_b), (i, kw)
        np.testing.assert_array_equal(np.asarray(a.parity),
                                      np.asarray(b.parity))
        np.testing.assert_array_equal(np.asarray(a.digest),
                                      np.asarray(b.digest))
        np.testing.assert_array_equal(np.asarray(a.row), np.asarray(b.row))
        if base.has_cksums:
            np.testing.assert_array_equal(np.asarray(a.cksums),
                                          np.asarray(b.cksums))
        assert _q_verifies(p2, b), (i, kw)
    assert a.qparity is None and b.qparity is not None


def test_resolve_mode_ladder():
    assert resolve_mode("mlpc", 1) is Mode.MLPC
    assert resolve_mode("mlpc", 2) is Mode.MLPC2
    assert resolve_mode("mlp", 2) is Mode.MLP2
    assert resolve_mode(Mode.MLPC2, 2) is Mode.MLPC2
    assert Mode.MLPC2.redundancy == 2 and Mode.MLPC.redundancy == 1
    with pytest.raises(ValueError, match="redundancy=2"):
        resolve_mode("ml", 2)
    with pytest.raises(ValueError, match="redundancy"):
        resolve_mode("mlpc", 3)


# -- two-rank reconstruction --------------------------------------------------

@pytest.mark.parametrize("mode", [Mode.MLPC2, Mode.MLP2])
@pytest.mark.parametrize("ranks", [(0, 1), (1, 3), (0, 3)])
def test_double_rank_loss_reconstructs(setup, mode, ranks):
    """ISSUE acceptance: any two simultaneous rank losses reconstruct
    bit-exactly against a pre-loss snapshot."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, mode)
    prot = p.init(state)
    cur = state
    for i in range(2):
        cur = jax.tree.map(lambda x: (x * 1.02 + 0.01).astype(x.dtype), cur)
        prot, ok = p.commit(prot, cur, rng_key=jax.random.PRNGKey(i))
        assert bool(ok)
    snap = {k: np.asarray(v).copy() for k, v in prot.state.items()}
    bad, event = failure.inject_double_rank_loss(p, prot, ranks)
    assert event.kind == "double_loss"
    rec, ok = p.recover_two(bad, *event.lost_ranks)
    assert bool(ok) or not mode.has_cksums
    for k in snap:
        np.testing.assert_array_equal(np.asarray(rec.state[k]), snap[k])
    assert _q_verifies(p, rec)


def test_double_loss_unrecoverable_without_q(setup):
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    from repro.core import recovery as recovery_mod
    with pytest.raises(RuntimeError, match="no Q syndrome"):
        recovery_mod.recover_from_double_loss(p, p.init(state), (0, 1))


def test_rank_loss_with_outstanding_scribble(setup):
    """A rank loss while another rank's scribble is still unrepaired is a
    double erasure: naming the scribbled rank as the second loss brings
    both back to intended values (single parity cannot untangle this)."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC2)
    prot = p.init(state)
    snap = {k: np.asarray(v).copy() for k, v in prot.state.items()}
    # scribble rank 1 (undetected — no scrub ran), then lose rank 3
    bad, _ = failure.inject_scribble(p, prot, rank=1,
                                     word_offsets=[3, 70])
    bad, _ = failure.inject_rank_loss(p, bad, rank=3)
    rec, ok = p.recover_two(bad, 1, 3)
    assert bool(ok)
    for k in snap:
        np.testing.assert_array_equal(np.asarray(rec.state[k]), snap[k])


def test_mid_window_double_loss_w16(trainer_cfg, mesh42):
    """ISSUE acceptance: a double loss landing mid-window at W=16 in
    redundancy=2 mode reconstructs bit-exactly — the flush brings P and Q
    current from the cached row, then the Vandermonde solve rebuilds both
    lost rows; the replicated window metadata bounds the window with no
    checkpoint + log replay."""
    from repro.configs.base import ProtectConfig, TrainConfig
    from repro.runtime.trainer import Trainer
    t = Trainer(trainer_cfg,
                TrainConfig(learning_rate=1e-3, warmup_steps=2,
                            total_steps=100),
                ProtectConfig(mode="mlpc", block_words=64, window=16,
                              redundancy=2),
                mesh42, seq_len=32, global_batch=8, seed=3)
    t.initialize()
    assert t.protector.mode is Mode.MLPC2
    t.run(3)
    assert t._engine.needs_flush, "loss must land strictly mid-window"
    snap = jax.tree.map(lambda x: np.asarray(x).copy(), t.prot.state)
    bad, event = failure.inject_double_rank_loss(t.protector, t.prot,
                                                 ranks=(0, 2))
    t._est = dataclasses.replace(t._est, prot=bad)
    rep = t.on_failure(event)
    assert rep["kind"] == "double_loss" and rep["verified"]
    assert rep["lost_ranks"] == [0, 2]
    # survivors' replicated metadata bounded the lost window exactly
    assert rep["window_bound"]["digest_verified"]
    assert rep["window_bound"]["pending"] == 3
    # failure suspicion collapsed the adaptive window
    assert t._engine.window == 1
    got = jax.tree.map(np.asarray, t.prot.state)
    for k in jax.tree.leaves(jax.tree.map(
            lambda a, b: np.array_equal(a, b), snap, got)):
        assert k
    assert _q_verifies(t.protector, t.prot)


# -- adaptive window ----------------------------------------------------------

def test_adaptive_window_shrinks_and_regrows(setup):
    """Scrub pressure collapses W to 1; consecutive clean scrubs double
    it back up to the configured ceiling."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC2)
    eng = DeferredProtector(p, window=8, donate=False)
    scrubber = Scrubber(p, period=1, engine=eng)
    est = eng.init(state)
    cur = jax.tree.map(lambda x: (x * 1.1).astype(x.dtype), state)
    est, ok = eng.commit(est, cur, rng_key=jax.random.PRNGKey(0))
    assert bool(ok)
    est = eng.flush_if_pending(est)
    # scribble -> suspect scrub -> W collapses to 1
    bad, _ = failure.inject_scribble(p, est.prot, rank=1,
                                     word_offsets=[5])
    est = dataclasses.replace(est, prot=bad)
    prot, report = scrubber.run(est.prot)
    assert report.suspect and report.bad_locations
    assert eng.window == 1
    est = dataclasses.replace(est, prot=prot)
    # clean scrubs regrow toward the ceiling: 2, 4, 8, capped at 8
    widths = []
    for _ in range(4):
        prot, report = scrubber.run(est.prot)
        assert not report.suspect
        assert report.qparity_ok
        est = dataclasses.replace(est, prot=prot)
        widths.append(eng.window)
    assert widths == [2, 4, 8, 8]
    assert eng.max_window == 8


# -- ProtectConfig validation -------------------------------------------------

def test_protect_config_validation():
    from repro.configs.base import ProtectConfig
    ProtectConfig(mode="mlpc", window=16, redundancy=2)     # valid
    with pytest.raises(ValueError, match="not a protection level"):
        ProtectConfig(mode="mlqc")
    with pytest.raises(ValueError, match="window"):
        ProtectConfig(window=0)
    with pytest.raises(ValueError, match="scrub_period"):
        ProtectConfig(scrub_period=-5)
    with pytest.raises(ValueError, match="at most two syndromes"):
        ProtectConfig(redundancy=3)
    with pytest.raises(ValueError, match="requires.*parity mode"):
        ProtectConfig(mode="ml", redundancy=2)
    with pytest.raises(ValueError, match="block_words"):
        ProtectConfig(block_words=0)
    with pytest.raises(ValueError, match="hybrid_threshold"):
        ProtectConfig(hybrid_threshold=1.5)
    with pytest.raises(ValueError, match="log_capacity"):
        ProtectConfig(log_capacity=0)


# -- storage accounting -------------------------------------------------------

def test_overhead_report_dual_parity(setup):
    mesh, state, specs, _ = setup
    r1 = make_protector(mesh, state, specs, Mode.MLPC).overhead_report()
    r2 = make_protector(mesh, state, specs, Mode.MLPC2).overhead_report()
    assert r1["qparity_bytes_per_rank"] == 0
    assert r2["qparity_bytes_per_rank"] == r2["parity_bytes_per_rank"]
    assert r2["redundancy"] == 2
    # the dual-parity tax is exactly one extra parity fraction
    assert r2["protection_fraction"] == pytest.approx(
        r1["protection_fraction"] + r1["parity_fraction"])


@pytest.fixture(scope="module")
def trainer_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="t_gf", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
