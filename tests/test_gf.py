"""Generalized Reed-Solomon syndrome subsystem: GF(2^32) arithmetic and
the e x e Vandermonde solve, the gf_parity syndrome-kernel family vs its
oracles, stack threading through the commit engines (the S_0 prefix must
stay bit-identical across stack heights, and r=1/r=2 must match the
host-computed P/Q golden values — the PR 4 semantics), the e-of-r
reconstruction matrix (r in 1..4, every e <= r, including
loss-plus-scribble), adaptive window feedback, and ProtectConfig /
Protector validation."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gf
from repro.core import layout as layout_mod
from repro.core.epoch import DeferredProtector
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector, resolved_mode
from repro.kernels import gf_parity as gfk
from repro.kernels import ref
from repro.runtime import failure
from tests.conftest import small_state

U32 = jnp.uint32


def make_protector(mesh, state, specs, mode, **kw):
    kw.setdefault("block_words", 64)
    return Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                     **kw)


# -- field arithmetic ---------------------------------------------------------

def test_gf_field_properties_host():
    """GF(2^32) under POLY is a field with primitive g=2: spot-check the
    group axioms, inverses, and distributivity on random samples."""
    rng = random.Random(0)
    for _ in range(50):
        a, b, c = (rng.getrandbits(32) for _ in range(3))
        assert gf.mul_int(a, b) == gf.mul_int(b, a)
        assert gf.mul_int(a, gf.mul_int(b, c)) == \
            gf.mul_int(gf.mul_int(a, b), c)
        assert gf.mul_int(a, b ^ c) == gf.mul_int(a, b) ^ gf.mul_int(a, c)
        assert gf.mul_int(a, 1) == a
        if a:
            assert gf.mul_int(a, gf.inv_int(a)) == 1
    # the per-rank coefficients are distinct and nonzero (primitivity)
    table = gf.pow_g_table(64)
    assert len(set(table)) == 64 and 0 not in table
    with pytest.raises(ZeroDivisionError):
        gf.inv_int(0)


def test_gf_device_matches_host():
    """jnp mul_const / mul_pow_g lanes agree with exact host integers."""
    rng = random.Random(1)
    words = np.asarray([rng.getrandbits(32) for _ in range(256)], np.uint32)
    x = jnp.asarray(words)
    for coeff in [1, 2, 3, 0x80000000, 0xDEADBEEF, gf.pow_g_int(7)]:
        want = np.asarray([gf.mul_int(int(w), coeff) for w in words],
                          np.uint32)
        np.testing.assert_array_equal(
            np.asarray(gf.mul_const(x, coeff)), want)
    for k in [0, 1, 5, 31, 40]:
        np.testing.assert_array_equal(
            np.asarray(gf.mul_pow_g(x, k)),
            np.asarray(gf.mul_const(x, gf.pow_g_int(k))))


def test_syndrome_table_shape_and_rows():
    """Entry [i][k] = g^(k·i): column 0 all-ones (S_0 = XOR parity),
    column 1 the classic per-rank Q coefficients."""
    t = gf.syndrome_array(8, 4)
    assert t.shape == (8, 4)
    np.testing.assert_array_equal(t[:, 0], np.ones(8, np.uint32))
    np.testing.assert_array_equal(t[:, 1], gf.pow_g_array(8))
    for i in range(8):
        for k in range(4):
            assert int(t[i, k]) == gf.pow_g_int(k * i)


def test_inv_vandermonde_is_exact_inverse():
    """V · V^-1 == I over GF(2^32) for every erasure-set size 1..4."""
    rng = random.Random(2)
    for e in range(1, 5):
        ranks = tuple(sorted(rng.sample(range(64), e)))
        v = gf.vandermonde_int(ranks)
        inv = gf.inv_vandermonde_int(ranks)
        for i in range(e):
            for j in range(e):
                acc = 0
                for k in range(e):
                    acc ^= gf.mul_int(v[i][k], inv[k][j])
                assert acc == (1 if i == j else 0), (ranks, i, j)


@pytest.mark.parametrize("e", [1, 2, 3, 4])
def test_gf_solve_e_roundtrip(e):
    """The e x e Vandermonde solve recovers all e lost rows exactly."""
    rng = np.random.default_rng(2)
    rows = [jnp.asarray(rng.integers(0, 1 << 32, 256, dtype=np.uint32))
            for _ in range(e)]
    for ranks in [tuple(range(e)), tuple(range(1, 2 * e, 2)),
                  tuple(sorted(np.random.default_rng(e).choice(
                      63, e, replace=False).tolist()))]:
        deficits = []
        for k in range(e):
            acc = jnp.zeros_like(rows[0])
            for j, a in enumerate(ranks):
                acc = acc ^ gf.mul_const(rows[j], gf.pow_g_int(k * a))
            deficits.append(acc)
        got = gf.solve_e(jnp.stack(deficits), ranks)
        for g, w in zip(got, rows):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_gf_solve_two_roundtrip():
    """The e=2 alias recovers both lost rows exactly."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 1 << 32, 512, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 1 << 32, 512, dtype=np.uint32))
    for ra, rb in [(0, 1), (1, 3), (2, 7), (0, 63)]:
        p = a ^ b
        q = gf.mul_pow_g(a, ra) ^ gf.mul_pow_g(b, rb)
        got_a, got_b = gf.solve_two(p, q, ra, rb)
        np.testing.assert_array_equal(np.asarray(got_a), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(b))


# -- kernels vs oracles -------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 64), (5, 128), (1, 256)])
@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_syndrome_kernels_match_oracles(shape, r):
    """The gf_parity syndrome kernels (interpret mode) are bit-identical
    to the jnp oracles on every output and every stack height."""
    rng = np.random.default_rng(3)
    old = jnp.asarray(rng.integers(0, 1 << 32, shape, dtype=np.uint32))
    new = jnp.asarray(rng.integers(0, 1 << 32, shape, dtype=np.uint32))
    stored = jnp.asarray(
        rng.integers(0, 1 << 32, (shape[0], 2), dtype=np.uint32))
    coeffs = jnp.asarray([gf.pow_g_int(k * 5) for k in range(r)], U32)

    got = gfk.fused_commit_s(old, new, coeffs, interpret=True)
    want = ref.fused_commit_s_ref(old, new, coeffs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    got = gfk.fused_verify_commit_s(old, new, stored, coeffs,
                                    interpret=True)
    want = ref.fused_verify_commit_s_ref(old, new, stored, coeffs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    got = gfk.fused_commit_old_terms_s(old, new, coeffs, interpret=True)
    want = ref.fused_commit_old_terms_s_ref(old, new, coeffs)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_gf_scale_1d_and_verify_flags():
    """1-D dispatch path, and a corrupted old block flips the verify bit."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(0, 1 << 32, 2048, dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(gfk.gf_scale(x, 7, interpret=True)),
        np.asarray(gf.mul_const(x, 7)))
    old = jnp.asarray(rng.integers(0, 1 << 32, (4, 64), dtype=np.uint32))
    new = old ^ U32(1)
    stored = ref.fletcher_blocks_ref(old)
    coeffs = jnp.asarray([1, 3, 9], U32)
    _, _, bad = gfk.fused_verify_commit_s(old, new, stored, coeffs,
                                          interpret=True)
    assert not np.asarray(bad).any()
    smashed = old.at[2, 5].set(old[2, 5] ^ U32(0x40))
    _, _, bad = gfk.fused_verify_commit_s(smashed, new, stored, coeffs,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(bad),
                                  [False, False, True, False])


def test_sdelta_plane_zero_is_raw_delta():
    """The k=0 plane must be the raw delta (g^0 = 1, no clmul) — the
    property that keeps r=1 at single-parity kernel cost."""
    rng = np.random.default_rng(5)
    old = jnp.asarray(rng.integers(0, 1 << 32, (4, 64), dtype=np.uint32))
    new = jnp.asarray(rng.integers(0, 1 << 32, (4, 64), dtype=np.uint32))
    coeffs = jnp.asarray([1, 2], U32)
    sdelta, _ = gfk.fused_commit_s(old, new, coeffs, interpret=True)
    np.testing.assert_array_equal(np.asarray(sdelta[0]),
                                  np.asarray(old ^ new))
    from repro.kernels import ops as kops
    sd1, ck1 = kops.fused_commit_s(old, new, None)
    d, ck = kops.fused_commit(old, new)
    np.testing.assert_array_equal(np.asarray(sd1[0]), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(ck1), np.asarray(ck))


# -- stack threading through the commit engines -------------------------------

@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs, shardings


def _synd_verifies(p, prot) -> bool:
    return bool(np.asarray(jax.device_get(
        p.scrub(prot)["synd_ok"])).all())


@pytest.mark.parametrize("base", [Mode.MLPC, Mode.MLP])
@pytest.mark.parametrize("red", [2, 3])
def test_stack_prefix_bit_identical(setup, base, red):
    """redundancy=r must not perturb the lower-r engine: S_0 (and every
    shared plane), cksums, digest and row stay bit-identical to the
    r=1 protector across bulk, patch, and verify_old commits — with the
    whole stack verifying at every step."""
    mesh, state, specs, _ = setup
    p1 = make_protector(mesh, state, specs, base)
    p2 = make_protector(mesh, state, specs, base, redundancy=red)
    a, b = p1.init(state), p2.init(state)
    lo = p2.layout
    pages = layout_mod.leaf_pages(lo, 1).tolist()
    cur = state
    plans = [dict(), dict(dirty_pages=pages),
             dict(verify_old=True), dict(dirty_pages=pages,
                                         verify_old=base.has_cksums)]
    for i, kw in enumerate(plans):
        cur = dict(cur)
        cur["w1"] = cur["w1"] * 1.01 + 0.25
        key = jax.random.PRNGKey(i)
        a, ok_a = p1.commit(a, cur, rng_key=key, **kw)
        b, ok_b = p2.commit(b, cur, rng_key=key, **kw)
        assert bool(ok_a) and bool(ok_b), (i, kw)
        np.testing.assert_array_equal(np.asarray(a.parity),
                                      np.asarray(b.parity))
        np.testing.assert_array_equal(np.asarray(a.digest),
                                      np.asarray(b.digest))
        np.testing.assert_array_equal(np.asarray(a.row), np.asarray(b.row))
        if base.has_cksums:
            np.testing.assert_array_equal(np.asarray(a.cksums),
                                          np.asarray(b.cksums))
        assert _synd_verifies(p2, b), (i, kw)
    assert a.synd.shape[-2] == 1 and b.synd.shape[-2] == red


def test_r1_r2_golden_p_q_regression(setup):
    """ISSUE acceptance: the r=1 and r=2 stacks must equal the
    host-computed XOR parity P and GF(2^32) Q — the exact PR 4
    dual-parity semantics, recomputed independently with exact host
    integers from the committed row."""
    mesh, state, specs, _ = setup
    g = mesh.shape["data"]
    p2 = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    prot = p2.init(state)
    cur = jax.tree.map(lambda x: (x * 1.5 + 0.125).astype(x.dtype), state)
    prot, ok = p2.commit(prot, cur, rng_key=jax.random.PRNGKey(0))
    assert bool(ok)
    # rank i's full row, (G, row_words) — row is replicated over the
    # model axis, so take model-coordinate 0
    rows = np.asarray(prot.row)[:, 0, :]
    seg = rows.shape[1] // g
    p_want = np.bitwise_xor.reduce(rows, axis=0)
    q_want = np.zeros_like(p_want)
    for i in range(g):
        ci = gf.pow_g_int(i)
        q_want ^= np.asarray([gf.mul_int(int(w), ci) for w in rows[i]],
                             np.uint32)
    synd = np.asarray(prot.synd)[:, 0]                    # (G, 2, seg)
    for i in range(g):
        np.testing.assert_array_equal(synd[i, 0],
                                      p_want[i * seg:(i + 1) * seg])
        np.testing.assert_array_equal(synd[i, 1],
                                      q_want[i * seg:(i + 1) * seg])
    # and the r=1 stack is exactly the P plane
    p1 = make_protector(mesh, state, specs, Mode.MLPC)
    prot1 = p1.init(state)
    prot1, _ = p1.commit(prot1, cur, rng_key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(prot1.synd)[:, :, 0],
                                  np.asarray(prot.synd)[:, :, 0])


def test_resolved_mode_ladder():
    assert resolved_mode("mlpc", 1) == (Mode.MLPC, 1)
    assert resolved_mode("mlpc", 3) == (Mode.MLPC, 3)
    assert resolved_mode("mlp", 2) == (Mode.MLP, 2)
    # legacy dual-parity aliases keep working
    assert resolved_mode("mlpc2") == (Mode.MLPC, 2)
    assert resolved_mode("mlp2", 1) == (Mode.MLP, 2)
    assert resolved_mode("mlp2", 3) == (Mode.MLP, 3)   # explicit r wins
    assert resolved_mode(Mode.MLPC, 4) == (Mode.MLPC, 4)
    with pytest.raises(ValueError, match="redundancy"):
        resolved_mode("ml", 2)
    with pytest.raises(ValueError, match="redundancy"):
        resolved_mode("mlpc", 5)
    with pytest.raises(ValueError, match="redundancy"):
        resolved_mode("mlpc", 0)


# -- e-of-r reconstruction matrix ---------------------------------------------

@pytest.fixture(scope="module")
def setup8(mesh81):
    state, specs, shardings = small_state(mesh81)
    return mesh81, state, specs, shardings


@pytest.mark.parametrize("r,e", [(r, e) for r in (1, 2, 3, 4)
                                 for e in range(1, r + 1)])
def test_e_of_r_loss_reconstructs(setup8, r, e):
    """ISSUE acceptance: any e <= r simultaneous rank losses reconstruct
    bit-exactly against a pre-loss snapshot, for every stack height."""
    mesh, state, specs, _ = setup8
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=r)
    prot = p.init(state)
    cur = state
    for i in range(2):
        cur = jax.tree.map(lambda x: (x * 1.02 + 0.01).astype(x.dtype), cur)
        prot, ok = p.commit(prot, cur, rng_key=jax.random.PRNGKey(i))
        assert bool(ok)
    snap = {k: np.asarray(v).copy() for k, v in prot.state.items()}
    ranks = tuple(range(0, 2 * e, 2))[:e]          # spread over the zone
    if e == 1:
        bad, event = failure.inject_rank_loss(p, prot, ranks[0])
        rec, ok = p.recover_rank(bad, ranks[0])
    else:
        bad, event = failure.inject_multi_rank_loss(p, prot, ranks)
        assert event.kind == "multi_loss"
        rec, ok = p.recover_e(bad, event.lost_ranks)
    assert bool(ok)
    for k in snap:
        np.testing.assert_array_equal(np.asarray(rec.state[k]), snap[k])
    assert _synd_verifies(p, rec)


def test_loss_exceeding_redundancy_raises(setup8):
    mesh, state, specs, _ = setup8
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    from repro.core import recovery as recovery_mod
    with pytest.raises(RuntimeError, match="redundancy"):
        recovery_mod.recover_from_e_loss(p, p.init(state), (0, 1, 2))
    p1 = make_protector(mesh, state, specs, Mode.MLPC)
    with pytest.raises(RuntimeError, match="redundancy"):
        recovery_mod.recover_from_double_loss(p1, p1.init(state), (0, 1))


@pytest.mark.parametrize("r,e", [(2, 1), (3, 2), (4, 3)])
def test_loss_with_outstanding_scribble(setup8, r, e):
    """e rank losses while another rank's scribble is still unrepaired is
    an (e+1)-erasure: naming the scribbled rank as the extra loss brings
    everything back to intended values (an e-syndrome stack cannot
    untangle this)."""
    mesh, state, specs, _ = setup8
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=r)
    prot = p.init(state)
    snap = {k: np.asarray(v).copy() for k, v in prot.state.items()}
    # scribble rank 1 (undetected — no scrub ran), then lose e more ranks
    bad, _ = failure.inject_scribble(p, prot, rank=1,
                                     word_offsets=[3, 70])
    dead = tuple(range(3, 3 + e))
    for a in dead:
        bad, _ = failure.inject_rank_loss(p, bad, rank=a)
    rec, ok = p.recover_e(bad, (1,) + dead)
    assert bool(ok)
    for k in snap:
        np.testing.assert_array_equal(np.asarray(rec.state[k]), snap[k])


def test_mid_window_triple_loss_w16(trainer_cfg, mesh42):
    """A triple loss landing mid-window at W=16 with redundancy=3
    reconstructs bit-exactly — the flush brings the whole stack current
    from the cached row, then the Vandermonde solve rebuilds all lost
    rows; the replicated window metadata bounds the window with no
    checkpoint + log replay."""
    from repro.configs.base import ProtectConfig, TrainConfig
    from repro.runtime.trainer import Trainer
    t = Trainer(trainer_cfg,
                TrainConfig(learning_rate=1e-3, warmup_steps=2,
                            total_steps=100),
                ProtectConfig(mode="mlpc", block_words=64, window=16,
                              redundancy=3),
                mesh42, seq_len=32, global_batch=8, seed=3)
    t.initialize()
    assert t.protector.mode is Mode.MLPC and t.protector.redundancy == 3
    t.run(3)
    assert t._engine.needs_flush, "loss must land strictly mid-window"
    snap = jax.tree.map(lambda x: np.asarray(x).copy(), t.prot.state)
    bad, event = failure.inject_multi_rank_loss(t.protector, t.prot,
                                                ranks=(0, 2, 3))
    t._est = dataclasses.replace(t._est, prot=bad)
    rep = t.on_failure(event)
    assert rep["kind"] == "multi_loss" and rep["verified"]
    assert rep["lost_ranks"] == [0, 2, 3]
    # survivors' replicated metadata bounded the lost window exactly
    assert rep["window_bound"]["digest_verified"]
    assert rep["window_bound"]["pending"] == 3
    # failure suspicion collapsed the adaptive window
    assert t._engine.window == 1
    got = jax.tree.map(np.asarray, t.prot.state)
    for k in jax.tree.leaves(jax.tree.map(
            lambda a, b: np.array_equal(a, b), snap, got)):
        assert k
    assert _synd_verifies(t.protector, t.prot)


# -- adaptive window ----------------------------------------------------------

def test_adaptive_window_shrinks_and_regrows(setup):
    """Scrub pressure collapses W to 1; consecutive clean scrubs double
    it back up to the configured ceiling."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    eng = DeferredProtector(p, window=8, donate=False)
    scrubber = Scrubber(p, period=1, engine=eng)
    est = eng.init(state)
    cur = jax.tree.map(lambda x: (x * 1.1).astype(x.dtype), state)
    est, ok = eng.commit(est, cur, rng_key=jax.random.PRNGKey(0))
    assert bool(ok)
    est = eng.flush_if_pending(est)
    # scribble -> suspect scrub -> W collapses to 1
    bad, _ = failure.inject_scribble(p, est.prot, rank=1,
                                     word_offsets=[5])
    est = dataclasses.replace(est, prot=bad)
    prot, report = scrubber.run(est.prot)
    assert report.suspect and report.bad_locations
    assert eng.window == 1
    est = dataclasses.replace(est, prot=prot)
    # clean scrubs regrow toward the ceiling: 2, 4, 8, capped at 8
    widths = []
    for _ in range(4):
        prot, report = scrubber.run(est.prot)
        assert not report.suspect
        assert report.synd_ok == [True, True]
        est = dataclasses.replace(est, prot=prot)
        widths.append(eng.window)
    assert widths == [2, 4, 8, 8]
    assert eng.max_window == 8


def test_precheck_feeds_adaptive_window(setup):
    """A clean rank-local pre-check standing in for a scrub must regrow
    a shrunken window exactly like a clean global scrub — otherwise
    full_scrub_every=N would slow regrowth by N."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    eng = DeferredProtector(p, window=8, donate=False)
    scrubber = Scrubber(p, period=1, engine=eng)
    est = eng.init(state)
    eng.report_pressure(True)                  # suspicion: W -> 1
    assert eng.window == 1
    widths = []
    for _ in range(4):
        rep = scrubber.precheck(est.prot)
        assert rep.local_only and not rep.suspect
        widths.append(eng.window)
    assert widths == [2, 4, 8, 8]
    # and a suspect pre-check collapses it right back
    bad, _ = failure.inject_scribble(p, est.prot, rank=1,
                                     word_offsets=[5])
    rep = scrubber.precheck(dataclasses.replace(est, prot=bad).prot)
    assert rep.suspect and eng.window == 1


# -- rank-local syndrome scrub ------------------------------------------------

def test_local_scrub_clean_pool(setup):
    """The rank-local pre-check agrees with the global scrub on a clean
    pool: no bad pages, every syndrome fold matches, cache coherent."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=3)
    prot = p.init(state)
    out = p.local_scrub(prot)
    assert np.asarray(out["synd_ok"]).shape == (3,)
    assert np.asarray(out["synd_ok"]).all()
    assert bool(out["row_cache_ok"])
    assert int(out["bad_count"]) == 0


def test_local_scrub_detects_syndrome_rot(setup):
    """Bit-rot in a stored syndrome segment — invisible to the checksum
    table, which covers only the state — is caught by the folded
    syndrome compare without any full-row collective."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    prot = p.init(state)
    synd = np.asarray(prot.synd).copy()
    synd[2, 0, 1, 7] ^= 0x10000          # rot rank 2's S_1 segment
    bad = dataclasses.replace(prot, synd=jax.device_put(
        jnp.asarray(synd), prot.synd.sharding))
    out = p.local_scrub(bad)
    ok = np.asarray(out["synd_ok"])
    assert bool(ok[0]) and not bool(ok[1]), ok
    assert int(out["bad_count"]) == 0
    # the global scrub agrees plane-for-plane
    gout = p.scrub(bad)
    np.testing.assert_array_equal(np.asarray(gout["synd_ok"]), ok)


def test_local_scrub_detects_state_scribble(setup):
    """A state scribble shows up in the local checksum check AND flips
    the affected syndrome folds (the weighted row changed)."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC, redundancy=2)
    prot = p.init(state)
    bad, _ = failure.inject_scribble(p, prot, rank=1, word_offsets=[9])
    out = p.local_scrub(bad)
    assert int(out["bad_count"]) > 0
    assert not np.asarray(out["synd_ok"]).all()


# -- ProtectConfig / Protector validation -------------------------------------

def test_protect_config_validation():
    from repro.configs.base import ProtectConfig
    ProtectConfig(mode="mlpc", window=16, redundancy=2)     # valid
    ProtectConfig(mode="mlpc", redundancy=4)                # valid now
    with pytest.raises(ValueError, match="not a protection level"):
        ProtectConfig(mode="mlqc")
    with pytest.raises(ValueError, match="window"):
        ProtectConfig(window=0)
    with pytest.raises(ValueError, match="scrub_period"):
        ProtectConfig(scrub_period=-5)
    with pytest.raises(ValueError, match="1 to 4"):
        ProtectConfig(redundancy=5)
    with pytest.raises(ValueError, match="1 to 4"):
        ProtectConfig(redundancy=0)
    with pytest.raises(ValueError, match="requires a parity mode"):
        ProtectConfig(mode="ml", redundancy=2)
    with pytest.raises(ValueError, match="full_scrub_every"):
        ProtectConfig(full_scrub_every=0)
    with pytest.raises(ValueError, match="block_words"):
        ProtectConfig(block_words=0)
    with pytest.raises(ValueError, match="hybrid_threshold"):
        ProtectConfig(hybrid_threshold=1.5)
    with pytest.raises(ValueError, match="log_capacity"):
        ProtectConfig(log_capacity=0)


def test_protector_rejects_redundancy_beyond_zone(setup):
    """r > num_ranks - 1 leaves no survivor: rejected with an actionable
    error naming the zone size."""
    mesh, state, specs, _ = setup                 # G = 4
    with pytest.raises(ValueError, match="num_ranks - 1"):
        make_protector(mesh, state, specs, Mode.MLPC, redundancy=4)
    make_protector(mesh, state, specs, Mode.MLPC, redundancy=3)  # fits


# -- storage accounting -------------------------------------------------------

def test_overhead_report_syndrome_stack(setup):
    mesh, state, specs, _ = setup
    r1 = make_protector(mesh, state, specs, Mode.MLPC).overhead_report()
    assert r1["syndrome_rows"] == 1
    assert r1["syndrome_bytes_per_rank"] == r1["parity_bytes_per_rank"]
    for r in (2, 3):
        rep = make_protector(mesh, state, specs, Mode.MLPC,
                             redundancy=r).overhead_report()
        assert rep["redundancy"] == r and rep["syndrome_rows"] == r
        # the stack tax is exactly r parity fractions
        assert rep["syndrome_bytes_per_rank"] == \
            r * rep["parity_bytes_per_rank"]
        assert rep["syndrome_r_over_p"] == float(r)
        assert rep["protection_fraction"] == pytest.approx(
            r1["protection_fraction"] + (r - 1) * r1["parity_fraction"])


@pytest.fixture(scope="module")
def trainer_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(
        name="t_gf", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
