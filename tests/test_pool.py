"""Pool facade (repro/pool.py): the pgl-style front door must be a pure
router — facade-routed commit / scrub / recover bit-identical to direct
`Protector` / `DeferredProtector` use across the mode ladder
(MLP/MLPC/MLP2/MLPC2) and window sizes, transactions abort cleanly on a
smashed canary, recovery flushes any open window first, `ProtectConfig`
rejects nonsense combos with actionable errors, and the adaptive window
regrows under sustained clean-commit load."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtectConfig
from repro.core.epoch import DeferredProtector
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector
from repro.pool import Fault, Pool
from repro.runtime import failure
from tests.conftest import small_state


@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs, shardings


def _assert_protection_equal(pa, pb, mode):
    # the whole syndrome stack (every S_k plane) must match bit-for-bit
    np.testing.assert_array_equal(np.asarray(pa.synd),
                                  np.asarray(pb.synd))
    np.testing.assert_array_equal(np.asarray(pa.digest),
                                  np.asarray(pb.digest))
    np.testing.assert_array_equal(np.asarray(pa.row), np.asarray(pb.row))
    if mode.has_cksums:
        np.testing.assert_array_equal(np.asarray(pa.cksums),
                                      np.asarray(pb.cksums))


def _evolve(cur):
    return jax.tree.map(lambda x: (x * 1.01 + 0.003).astype(x.dtype), cur)


# -- facade == direct engines, whole ladder x window sizes --------------------

@pytest.mark.parametrize("base,red", [("mlp", 1), ("mlpc", 1),
                                      ("mlp", 2), ("mlpc", 2),
                                      ("mlpc", 3)])
@pytest.mark.parametrize("window", [1, 4])
def test_pool_routes_bit_identical(setup, base, red, window):
    """ISSUE acceptance: commits, scrubs and recoveries routed through
    `Pool` must land the exact protection bits direct engine use lands —
    digest at every step, full protection at epoch boundaries, and
    bit-exact reconstruction (single loss via S_0; e losses via the
    syndrome stack when redundancy >= e)."""
    mesh, state, specs, _ = setup
    cfg = ProtectConfig(mode=base, redundancy=red, window=window,
                        block_words=64)
    mode = cfg.resolved_mode
    pool = Pool.open(state, specs, mesh=mesh, config=cfg, donate=False)
    assert pool.mode is mode and pool.redundancy == red

    # the direct engines, hand-wired exactly as the runtimes used to
    p = Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                  redundancy=red, block_words=64)
    if window == 1:
        direct = p.init(state)
        commit = jax.jit(p.make_commit(), static_argnames=("canary_ok",))
        eng = None
    else:
        eng = DeferredProtector(p, window=window, donate=False)
        est = eng.init(state)

    cur = state
    for i in range(2 * window):
        cur = _evolve(cur)
        key = jax.random.PRNGKey(i)
        ok_f = pool.commit(cur, rng_key=key, data_cursor=i)
        if eng is None:
            direct, ok_d = commit(direct, cur, rng_key=key, data_cursor=i)
        else:
            est, ok_d = eng.commit(est, cur, rng_key=key, data_cursor=i)
            direct = est.prot
        assert bool(ok_f) and bool(ok_d)
        np.testing.assert_array_equal(np.asarray(pool.prot.digest),
                                      np.asarray(direct.digest))
        if (i + 1) % window == 0:
            _assert_protection_equal(pool.prot, direct, mode)
    np.testing.assert_array_equal(np.asarray(pool.prot.log.digest),
                                  np.asarray(direct.log.digest))

    # scrub: facade flushes + scrubs + repairs; direct does it by hand
    rep_f = pool.scrub()
    if eng is not None:
        est = eng.flush_if_pending(est)
        direct = est.prot
    direct, rep_d = Scrubber(p, period=1).run(direct)
    assert rep_f.checked and rep_d.checked
    assert rep_f.bad_locations == rep_d.bad_locations == []
    assert rep_f.parity_ok is rep_d.parity_ok is True
    _assert_protection_equal(pool.prot, direct, mode)

    # recovery: the same loss injected into both, reconstructed both ways
    want = np.asarray(pool.state["w1"]).copy()
    if red >= 2:
        dead = tuple(range(1, red + 1))       # e = r simultaneous losses
        fault = Fault.multi_loss(*dead)
        bad_f, _ = failure.inject_multi_rank_loss(p, pool.prot, dead)
        bad_d, _ = failure.inject_multi_rank_loss(p, direct, dead)
    else:
        fault = Fault.rank_loss(2)
        bad_f, _ = failure.inject_rank_loss(p, pool.prot, 2)
        bad_d, _ = failure.inject_rank_loss(p, direct, 2)
    if pool.engine is not None:
        pool._est = dataclasses.replace(pool._est, prot=bad_f)
    else:
        pool._prot = bad_f
    rep = pool.recover(fault)
    if red >= 2:
        direct, ok_d = p.recover_e(bad_d, dead)
    else:
        direct, ok_d = p.recover_rank(bad_d, 2)
    assert rep.verified == bool(jax.device_get(ok_d))
    assert rep.verified or not mode.has_cksums
    np.testing.assert_array_equal(np.asarray(pool.state["w1"]), want)
    np.testing.assert_array_equal(np.asarray(pool.state["w1"]),
                                  np.asarray(direct.state["w1"]))
    np.testing.assert_array_equal(np.asarray(pool.prot.row),
                                  np.asarray(direct.row))


def test_pool_commit_is_the_direct_program(setup):
    """The facade adds zero compiled bytes: `pool.commit` routes through
    the Protector's cached jit, whose lowered cost equals a hand-built
    `jax.jit(p.make_commit())` exactly."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64),
                     donate=False)
    new = _evolve(state)
    key = jax.random.PRNGKey(0)

    def bytes_of(fn):
        cost = fn.lower(pool.prot, new, rng_key=key).compile() \
                 .cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost.get("bytes accessed", 0.0))

    direct = jax.jit(pool.protector.make_commit(),
                     static_argnames=("canary_ok",))
    assert bytes_of(pool.commit_program()) == bytes_of(direct)
    # and the facade's cached program IS the protector's cached program
    assert pool.commit_program() is pool.protector.commit_program()


# -- transactions --------------------------------------------------------------

@pytest.mark.parametrize("window", [1, 4])
def test_transaction_abort_on_canary(setup, window):
    """A staged buffer whose guard page was overrun must abort the
    transaction: no state movement, no step advance, for both engines
    (the deferred engine's abort is the compiled no-op variant)."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64,
                                          window=window),
                     donate=False)
    cur = _evolve(state)
    with pool.transaction(rng_key=jax.random.PRNGKey(0)) as tx:
        tx.stage(cur)
    assert tx.committed and tx.ok and pool.step == 1

    before = np.asarray(pool.state["w1"]).copy()
    with pool.transaction() as tx:
        tx.watch(failure.smashed_canary_buffer(1024))
        tx.stage(jax.tree.map(jnp.zeros_like, cur))
    assert tx.aborted and not tx.ok and not tx.committed
    assert pool.step == 1
    np.testing.assert_array_equal(np.asarray(pool.state["w1"]), before)

    # a clean guarded buffer commits
    with pool.transaction(rng_key=jax.random.PRNGKey(1)) as tx:
        tx.guard(jnp.zeros((256,), jnp.uint32))
        tx.stage(_evolve(cur))
    assert tx.committed and pool.step == 2


def test_transaction_exception_aborts(setup):
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64),
                     donate=False)
    with pytest.raises(RuntimeError, match="kernel exploded"):
        with pool.transaction() as tx:
            tx.stage(_evolve(state))
            raise RuntimeError("kernel exploded")
    assert tx.aborted and not tx.committed and pool.step == 0


# -- recovery flushes the open window ------------------------------------------

def test_recover_flushes_open_window(setup):
    """A rank loss strictly mid-window: `pool.recover` must flush first
    (the cached row never saw the corruption), reconstruct bit-exactly,
    and collapse the adaptive window to 1."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64,
                                          window=4),
                     donate=False)
    cur = state
    for i in range(2):                     # 2 of 4: strictly mid-window
        cur = _evolve(cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(i))
    assert pool.engine.needs_flush
    want = np.asarray(pool.state["w1"]).copy()
    bad, event = failure.inject_rank_loss(pool.protector, pool.prot,
                                          rank=2)
    pool._est = dataclasses.replace(pool._est, prot=bad)
    rep = pool.recover(Fault.from_event(event))
    assert not pool.engine.needs_flush, "recover must have flushed"
    assert rep.verified
    assert pool.engine.window == 1, "failure suspicion collapses W"
    np.testing.assert_array_equal(np.asarray(pool.state["w1"]), want)
    # the refreshed redundancy is current: a fresh rebuild matches
    fresh = pool.protector.init(pool.state)
    _assert_protection_equal(fresh, pool.prot, Mode.MLPC)


# -- config validation ---------------------------------------------------------

def test_protect_config_rejects_nonsense_combos():
    with pytest.raises(ValueError, match="redundancy=2"):
        ProtectConfig(mode="replica", redundancy=2)
    with pytest.raises(ValueError, match="window"):
        ProtectConfig(mode="replica", window=4)
    with pytest.raises(ValueError, match="window"):
        ProtectConfig(mode="none", window=16)
    with pytest.raises(ValueError, match="window"):
        ProtectConfig(mode="ml", window=2)
    with pytest.raises(ValueError, match="redundancy"):
        ProtectConfig(mode="mlpc", redundancy=5)
    with pytest.raises(ValueError, match="window_growth_commits"):
        ProtectConfig(mode="mlpc", window_growth_commits=-1)
    with pytest.raises(ValueError, match="not a protection"):
        ProtectConfig(mode="mlcp")


def test_protect_config_resolves_modes():
    assert ProtectConfig(mode="mlpc").resolved_mode is Mode.MLPC
    assert ProtectConfig(mode="mlpc").resolved_redundancy == 1
    cfg = ProtectConfig(mode="mlp", redundancy=2)
    assert cfg.resolved_mode is Mode.MLP and cfg.resolved_redundancy == 2
    cfg = ProtectConfig(mode="mlpc", redundancy=3)
    assert cfg.resolved_mode is Mode.MLPC and cfg.resolved_redundancy == 3
    # legacy dual-parity aliases fold onto (base mode, redundancy 2)
    cfg = ProtectConfig(mode="mlpc2")
    assert cfg.resolved_mode is Mode.MLPC and cfg.resolved_redundancy == 2
    cfg = ProtectConfig(mode="mlp2", redundancy=2)
    assert cfg.resolved_mode is Mode.MLP and cfg.resolved_redundancy == 2
    cfg = ProtectConfig(mode="mlpc2", redundancy=3)  # explicit r wins
    assert cfg.resolved_mode is Mode.MLPC and cfg.resolved_redundancy == 3


# -- adaptive window: growth under sustained clean-commit load -----------------

def test_window_regrows_under_clean_commit_load(setup):
    """ISSUE satellite: after suspicion collapses W to 1, N consecutive
    clean commits (not only a clean scrub) must double it back toward
    the ceiling."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64,
                                          window=8,
                                          window_growth_commits=3),
                     donate=False)
    eng = pool.engine
    eng.report_pressure(True)              # failure suspicion: W -> 1
    assert eng.window == 1
    cur = state
    seen = [1]
    for i in range(12):
        cur = _evolve(cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(i))
        # growth may only land at an epoch boundary — never stretch an
        # epoch that opened under a smaller window
        if seen[-1] != eng.window:
            assert not eng.needs_flush, (i, seen, eng.window)
        seen.append(eng.window)
    assert eng.window == 8, seen           # 1 -> 2 -> 4 -> 8 under load
    assert seen == sorted(seen) and set(seen) == {1, 2, 4, 8}, seen

    # a dirty commit resets the streak: no growth past the ceiling reset
    eng.report_pressure(True)          # suspicion collapses W...
    pool.scrubber.note_suspect()       # ...and resets the clean streak
    for i in range(2):
        cur = _evolve(cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(20 + i))
    pool.commit(_evolve(cur), canary_ok=False)     # aborted commit
    assert eng.window == 1, "streak must reset on a dirty commit"


# -- rank-local scrub cadence --------------------------------------------------

def test_maybe_scrub_local_precheck_cadence(setup):
    """ISSUE satellite: with full_scrub_every=N, due scrubs run the
    rank-local syndrome pre-check and only every Nth pays for the global
    collectives — unless the pre-check flags the pool suspect, which
    escalates to a global scrub (with repair) immediately."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", redundancy=2,
                                          block_words=64, scrub_period=1,
                                          full_scrub_every=3),
                     donate=False)
    cur = state
    kinds = []
    for i in range(6):
        cur = _evolve(cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(i))
        rep = pool.maybe_scrub()
        assert rep is not None and rep.checked and not rep.suspect
        kinds.append(rep.local_only)
    # two local pre-checks between every global scrub
    assert kinds == [True, True, False, True, True, False], kinds

    # a scribble lands mid-cadence: the next due pre-check flags it and
    # ESCALATES — the returned report is the global scrub's, with the
    # page repaired in place
    cur = _evolve(cur)
    pool.commit(cur, rng_key=jax.random.PRNGKey(99))   # makes a scrub due
    want = np.asarray(pool.state["w1"]).copy()
    bad, _ = failure.inject_scribble(pool.protector, pool.prot, rank=1,
                                     word_offsets=[9])
    pool.prot = bad
    rep = pool.maybe_scrub()
    assert rep is not None and not rep.local_only, \
        "a suspect pre-check must escalate to the global scrub"
    assert rep.repaired and rep.repair_ok
    np.testing.assert_array_equal(np.asarray(pool.state["w1"]), want)


def test_pool_precheck_is_collective_light(setup):
    """The pre-check's program must not contain the full-row all-to-all:
    its compiled bytes stay well below the global scrub's."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", redundancy=3,
                                          block_words=64),
                     donate=False)
    p = pool.protector

    def bytes_of(make):
        jitted = jax.jit(make())
        cost = jitted.lower(pool.prot).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        return float(cost.get("bytes accessed", 0.0))

    local_b = bytes_of(p.make_local_scrub)
    global_b = bytes_of(p.make_scrub)
    assert local_b < global_b, (local_b, global_b)
    rep = pool.precheck()
    assert rep.local_only and not rep.suspect


# -- rescale -------------------------------------------------------------------

def test_pool_rescale_mid_window(setup, mesh81):
    """ISSUE satellite: `pool.rescale` must flush the open window, move
    the state bit-exactly, rebuild ALL r syndromes for the new zone
    geometry (G: 4 -> 8, new Vandermonde coefficients g^(k·i)) and carry
    the step counter as a host value."""
    mesh, state, specs, _ = setup
    state = jax.tree.map(jnp.copy, state)
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", redundancy=3,
                                          block_words=64, window=3),
                     donate=False)
    cur = state
    for i in range(2):                     # strictly mid-window
        cur = _evolve(cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(i))
    assert pool.engine.needs_flush
    moved = pool.rescale(mesh81)
    assert not pool.engine.needs_flush, "rescale must have flushed"
    assert moved.protector.group_size == 8
    assert moved.redundancy == 3
    assert moved.step == 2
    for k, v in cur.items():
        np.testing.assert_array_equal(np.asarray(moved.state[k]),
                                      np.asarray(v))
    fresh = moved.protector.init(moved.state)
    _assert_protection_equal(fresh, moved.prot, Mode.MLPC)
    # the new zone still solves a triple loss
    want = np.asarray(moved.state["w1"]).copy()
    bad, ev = failure.inject_multi_rank_loss(moved.protector, moved.prot,
                                             (2, 5, 7))
    moved._est = dataclasses.replace(moved._est, prot=bad)
    rep = moved.recover(Fault.multi_loss(*ev.lost_ranks))
    assert rep.verified
    np.testing.assert_array_equal(np.asarray(moved.state["w1"]), want)


def test_pool_rescale_reresolves_footprint_callables(setup, mesh81):
    """Callable footprint args (Server's decode sizing) are functions of
    the zone layout, which changes with G — rescale must re-resolve them
    against the NEW mesh's layout, not reuse the old resolution."""
    mesh, state, specs, _ = setup
    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc", block_words=64,
                                          window=2),
                     dirty_leaf_idx=lambda lo: range(len(lo.slots)),
                     dirty_capacity=lambda lo: lo.n_blocks,
                     donate=False)
    assert pool.engine.dirty_capacity == pool.protector.layout.n_blocks
    moved = pool.rescale(mesh81)
    new_nb = moved.protector.layout.n_blocks
    assert new_nb != pool.protector.layout.n_blocks, \
        "test needs geometries whose page counts differ"
    assert moved.engine.dirty_capacity == new_nb, \
        "capacity callable must re-resolve against the new layout"
