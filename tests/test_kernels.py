"""Pallas kernel vs pure-jnp oracle: exact-equality sweeps (interpret mode).

Each kernel is swept across block counts / widths and validated bit-for-bit
against kernels/ref.py — uint32 integer math, so equality is exact, not
allclose-with-tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import commit_fused, fletcher, ops, ref, xor_parity

U32 = jnp.uint32


def rand_u32(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=shape, dtype=np.uint32))


SHAPES = [(1, 128), (2, 256), (8, 1024), (16, 1024), (24, 512), (64, 128)]


@pytest.mark.parametrize("shape", SHAPES)
def test_fletcher_kernel_vs_ref(shape):
    blocks = rand_u32(shape, seed=shape[0])
    out_k = fletcher.fletcher_blocks(blocks, interpret=True)
    out_r = ref.fletcher_blocks_ref(blocks)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("shape", SHAPES + [(4096,), (1024,), (8, 8)])
def test_xor_delta_kernel_vs_ref(shape):
    a = rand_u32(shape, seed=1)
    b = rand_u32(shape, seed=2)
    out_k = xor_parity.xor_delta(a, b, interpret=True)
    out_r = ref.xor_delta_ref(a, b)
    assert out_k.shape == a.shape
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("shape", [(8, 1024), (512, 128), (1024,)])
def test_xor_accum_kernel_vs_ref(shape):
    p = rand_u32(shape, seed=3)
    d = rand_u32(shape, seed=4)
    out_k = xor_parity.xor_accum(p, d, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_k),
                                  np.asarray(ref.xor_accum_ref(p, d)))


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_commit_kernel_vs_ref(shape):
    old = rand_u32(shape, seed=5)
    new = rand_u32(shape, seed=6)
    d_k, c_k = commit_fused.fused_commit(old, new, interpret=True)
    d_r, c_r = ref.fused_commit_ref(old, new)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_fused_commit_is_delta_plus_fletcher():
    """Cross-check the fused kernel against the two separate kernels."""
    old = rand_u32((8, 1024), seed=7)
    new = rand_u32((8, 1024), seed=8)
    d, c = commit_fused.fused_commit(old, new, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(d), np.asarray(xor_parity.xor_delta(old, new,
                                                       interpret=True)))
    np.testing.assert_array_equal(
        np.asarray(c), np.asarray(fletcher.fletcher_blocks(new,
                                                           interpret=True)))


def test_xor_properties():
    """Algebra the parity scheme relies on: self-inverse, commutativity."""
    a, b, c = (rand_u32((4, 64), seed=s) for s in (9, 10, 11))
    z = jnp.zeros_like(a)
    # delta(x, x) == 0
    np.testing.assert_array_equal(
        np.asarray(xor_parity.xor_delta(a, a, interpret=True)), np.asarray(z))
    # accum(accum(p, d), d) == p  (idempotent repair)
    p1 = xor_parity.xor_accum(a, b, interpret=True)
    p2 = xor_parity.xor_accum(p1, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(a))
    # order-free patches: (p ^ d1) ^ d2 == (p ^ d2) ^ d1
    lhs = xor_parity.xor_accum(xor_parity.xor_accum(a, b, interpret=True), c,
                               interpret=True)
    rhs = xor_parity.xor_accum(xor_parity.xor_accum(a, c, interpret=True), b,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


def test_ops_dispatch_cpu_uses_ref():
    """On CPU the wrapper must route to the jnp oracle (no Pallas lowering)."""
    a = rand_u32((4, 128), seed=12)
    b = rand_u32((4, 128), seed=13)
    np.testing.assert_array_equal(
        np.asarray(ops.xor_delta(a, b)),
        np.asarray(ref.xor_delta_ref(a, b)))
    np.testing.assert_array_equal(
        np.asarray(ops.fletcher_blocks(a)),
        np.asarray(ref.fletcher_blocks_ref(a)))
    d1, c1 = ops.fused_commit(a, b)
    d2, c2 = ref.fused_commit_ref(a, b)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_ops_interpret_flag_forces_pallas():
    a = rand_u32((8, 1024), seed=14)
    b = rand_u32((8, 1024), seed=15)
    np.testing.assert_array_equal(
        np.asarray(ops.xor_delta(a, b, interpret=True)),
        np.asarray(ref.xor_delta_ref(a, b)))
