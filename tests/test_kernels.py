"""Pallas kernel vs pure-jnp oracle: exact-equality sweeps (interpret mode).

Each kernel is swept across block counts / widths and validated bit-for-bit
against kernels/ref.py — uint32 integer math, so equality is exact, not
allclose-with-tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import commit_fused, fletcher, ops, ref, xor_parity

U32 = jnp.uint32


def rand_u32(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=shape, dtype=np.uint32))


SHAPES = [(1, 128), (2, 256), (8, 1024), (16, 1024), (24, 512), (64, 128)]


@pytest.mark.parametrize("shape", SHAPES)
def test_fletcher_kernel_vs_ref(shape):
    blocks = rand_u32(shape, seed=shape[0])
    out_k = fletcher.fletcher_blocks(blocks, interpret=True)
    out_r = ref.fletcher_blocks_ref(blocks)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("shape", SHAPES + [(4096,), (1024,), (8, 8)])
def test_xor_delta_kernel_vs_ref(shape):
    a = rand_u32(shape, seed=1)
    b = rand_u32(shape, seed=2)
    out_k = xor_parity.xor_delta(a, b, interpret=True)
    out_r = ref.xor_delta_ref(a, b)
    assert out_k.shape == a.shape
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("shape", [(8, 1024), (512, 128), (1024,)])
def test_xor_accum_kernel_vs_ref(shape):
    p = rand_u32(shape, seed=3)
    d = rand_u32(shape, seed=4)
    out_k = xor_parity.xor_accum(p, d, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_k),
                                  np.asarray(ref.xor_accum_ref(p, d)))


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_commit_kernel_vs_ref(shape):
    old = rand_u32(shape, seed=5)
    new = rand_u32(shape, seed=6)
    d_k, c_k = commit_fused.fused_commit(old, new, interpret=True)
    d_r, c_r = ref.fused_commit_ref(old, new)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_fused_commit_is_delta_plus_fletcher():
    """Cross-check the fused kernel against the two separate kernels."""
    old = rand_u32((8, 1024), seed=7)
    new = rand_u32((8, 1024), seed=8)
    d, c = commit_fused.fused_commit(old, new, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(d), np.asarray(xor_parity.xor_delta(old, new,
                                                       interpret=True)))
    np.testing.assert_array_equal(
        np.asarray(c), np.asarray(fletcher.fletcher_blocks(new,
                                                           interpret=True)))


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_verify_commit_kernel_vs_ref(shape):
    old = rand_u32(shape, seed=20)
    new = rand_u32(shape, seed=21)
    stored = ref.fletcher_blocks_ref(old)
    d_k, c_k, b_k = commit_fused.fused_verify_commit(old, new, stored,
                                                     interpret=True)
    d_r, c_r, b_r = ref.fused_verify_commit_ref(old, new, stored)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_r))


def test_fused_verify_commit_is_composition():
    """One sweep == verify(old) + delta + fletcher(new) composed."""
    old = rand_u32((16, 512), seed=22)
    new = rand_u32((16, 512), seed=23)
    stored = fletcher.fletcher_blocks(old, interpret=True)
    d, c, bad = commit_fused.fused_verify_commit(old, new, stored,
                                                 interpret=True)
    np.testing.assert_array_equal(
        np.asarray(d),
        np.asarray(xor_parity.xor_delta(old, new, interpret=True)))
    np.testing.assert_array_equal(
        np.asarray(c),
        np.asarray(fletcher.fletcher_blocks(new, interpret=True)))
    assert not np.asarray(bad).any(), "clean old row must verify clean"


@pytest.mark.parametrize("bitpos", [0, 13, 31])
def test_fused_verify_commit_flags_corrupt_old(bitpos):
    """A corrupted old row must flip exactly its block's verify bit."""
    n, bw = 8, 256
    old = rand_u32((n, bw), seed=24)
    new = rand_u32((n, bw), seed=25)
    stored = ref.fletcher_blocks_ref(old)
    scribbled = np.asarray(old).copy()
    scribbled[3, 17] ^= np.uint32(1 << bitpos)
    _, _, bad = commit_fused.fused_verify_commit(
        jnp.asarray(scribbled), new, stored, interpret=True)
    bad = np.asarray(bad)
    assert bad[3], "scribbled block must fail verification"
    assert bad.sum() == 1, "only the scribbled block may be flagged"
    # the jnp oracle agrees
    _, _, bad_r = ref.fused_verify_commit_ref(jnp.asarray(scribbled), new,
                                              stored)
    np.testing.assert_array_equal(bad, np.asarray(bad_r))


def test_fused_verify_commit_ops_dispatch():
    """CPU wrapper routes to the oracle; interpret flag forces Pallas."""
    old = rand_u32((4, 128), seed=26)
    new = rand_u32((4, 128), seed=27)
    stored = ref.fletcher_blocks_ref(old)
    for kw in ({}, {"interpret": True}):
        d, c, b = ops.fused_verify_commit(old, new, stored, **kw)
        d_r, c_r, b_r = ref.fused_verify_commit_ref(old, new, stored)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c_r))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(b_r))


def test_fused_commit_old_terms_kernel_vs_ref():
    """Zero stored terms turn the verify sweep into raw old-term output."""
    old = rand_u32((8, 256), seed=30)
    new = rand_u32((8, 256), seed=31)
    d_k, c_k, o_k = commit_fused.fused_commit_old_terms(old, new,
                                                        interpret=True)
    d_r, c_r, o_r = ref.fused_commit_old_terms_ref(old, new)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(o_k), np.asarray(o_r))
    np.testing.assert_array_equal(
        np.asarray(ops.fused_commit_old_terms(old, new)[2]),
        np.asarray(ref.fletcher_blocks_ref(old)))


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_accum_commit_kernel_vs_ref(shape):
    acc = rand_u32(shape, seed=40)
    old = rand_u32(shape, seed=41)
    new = rand_u32(shape, seed=42)
    a_k, o_k, n_k = commit_fused.fused_accum_commit(acc, old, new,
                                                    interpret=True)
    a_r, o_r, n_r = ref.fused_accum_commit_ref(acc, old, new)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(o_k), np.asarray(o_r))
    np.testing.assert_array_equal(np.asarray(n_k), np.asarray(n_r))


def test_fused_accum_commit_telescopes():
    """W accumulate steps must land the single-delta row_0 ^ row_W, so the
    epoch flush can apply one accumulated patch for the whole window."""
    rows = [rand_u32((8, 256), seed=50 + i) for i in range(5)]
    acc = jnp.zeros_like(rows[0])
    for old, new in zip(rows[:-1], rows[1:]):
        acc, old_ck, new_ck = ops.fused_accum_commit(acc, old, new)
        np.testing.assert_array_equal(np.asarray(old_ck),
                                      np.asarray(ref.fletcher_blocks_ref(old)))
        np.testing.assert_array_equal(np.asarray(new_ck),
                                      np.asarray(ref.fletcher_blocks_ref(new)))
    np.testing.assert_array_equal(np.asarray(acc),
                                  np.asarray(rows[0] ^ rows[-1]))


def test_fused_kernels_odd_block_counts():
    """Tile picking must handle block counts not divisible by TILE_BLOCKS."""
    for n in (3, 12, 17):
        old = rand_u32((n, 128), seed=n)
        new = rand_u32((n, 128), seed=n + 1)
        d, c = commit_fused.fused_commit(old, new, interpret=True)
        d_r, c_r = ref.fused_commit_ref(old, new)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(d_r))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c_r))


def test_xor_properties():
    """Algebra the parity scheme relies on: self-inverse, commutativity."""
    a, b, c = (rand_u32((4, 64), seed=s) for s in (9, 10, 11))
    z = jnp.zeros_like(a)
    # delta(x, x) == 0
    np.testing.assert_array_equal(
        np.asarray(xor_parity.xor_delta(a, a, interpret=True)), np.asarray(z))
    # accum(accum(p, d), d) == p  (idempotent repair)
    p1 = xor_parity.xor_accum(a, b, interpret=True)
    p2 = xor_parity.xor_accum(p1, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(a))
    # order-free patches: (p ^ d1) ^ d2 == (p ^ d2) ^ d1
    lhs = xor_parity.xor_accum(xor_parity.xor_accum(a, b, interpret=True), c,
                               interpret=True)
    rhs = xor_parity.xor_accum(xor_parity.xor_accum(a, c, interpret=True), b,
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


def test_ops_dispatch_cpu_uses_ref():
    """On CPU the wrapper must route to the jnp oracle (no Pallas lowering)."""
    a = rand_u32((4, 128), seed=12)
    b = rand_u32((4, 128), seed=13)
    np.testing.assert_array_equal(
        np.asarray(ops.xor_delta(a, b)),
        np.asarray(ref.xor_delta_ref(a, b)))
    np.testing.assert_array_equal(
        np.asarray(ops.fletcher_blocks(a)),
        np.asarray(ref.fletcher_blocks_ref(a)))
    d1, c1 = ops.fused_commit(a, b)
    d2, c2 = ref.fused_commit_ref(a, b)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_ops_interpret_flag_forces_pallas():
    a = rand_u32((8, 1024), seed=14)
    b = rand_u32((8, 1024), seed=15)
    np.testing.assert_array_equal(
        np.asarray(ops.xor_delta(a, b, interpret=True)),
        np.asarray(ref.xor_delta_ref(a, b)))
