"""Flash-attention custom VJP vs naive dense attention: forward values and
gradients must agree to f32 tolerance across causal/window/GQA variants and
chunk shapes (including chunk > seq: single-tile degenerate case)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def dense_reference(q, k, v, causal, window):
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, S, K, g, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qr, k) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, S, H, hd)


CASES = [
    # (S, T, H, K, hd, causal, window, chunk)
    (16, 16, 4, 2, 8, True, None, 8),
    (16, 16, 4, 2, 8, True, None, 256),    # single tile
    (16, 16, 4, 4, 8, False, None, 8),     # MHA, bidirectional
    (24, 24, 6, 2, 8, True, 8, 8),         # sliding window
    (16, 16, 4, 1, 8, True, 4, 8),         # MQA + window
    (12, 12, 4, 2, 8, True, None, 5),      # chunk not dividing seq
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_dense(case):
    S, T, H, K, hd, causal, window, chunk = case
    key = jax.random.PRNGKey(sum(case[:5]))
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (2, T, K, hd), jnp.float32)
    v = jax.random.normal(kv, (2, T, K, hd), jnp.float32)
    out = A.attend(q, k, v, causal=causal, window=window, chunk=chunk)
    ref = dense_reference(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES)
def test_gradients_match_dense(case):
    S, T, H, K, hd, causal, window, chunk = case
    key = jax.random.PRNGKey(100 + sum(case[:5]))
    kq, kk, kv, kt = jax.random.split(key, 4)
    q = jax.random.normal(kq, (2, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (2, T, K, hd), jnp.float32)
    v = jax.random.normal(kv, (2, T, K, hd), jnp.float32)
    tgt = jax.random.normal(kt, (2, S, H, hd), jnp.float32)

    def loss_flash(q, k, v):
        out = A.attend(q, k, v, causal=causal, window=window, chunk=chunk)
        return jnp.sum((out - tgt) ** 2)

    def loss_dense(q, k, v):
        return jnp.sum((dense_reference(q, k, v, causal, window) - tgt) ** 2)

    g_flash = jax.jit(jax.grad(loss_flash, argnums=(0, 1, 2)))(q, k, v)
    g_dense = jax.jit(jax.grad(loss_dense, argnums=(0, 1, 2)))(q, k, v)
    for gf, gd, name in zip(g_flash, g_dense, "qkv"):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gd),
                                   rtol=5e-4, atol=5e-5,
                                   err_msg=f"d{name} mismatch {case}")


def test_no_quadratic_residuals():
    """The VJP must not stack S^2 score residuals: for S=1024, hd=16, the
    largest live buffer in the compiled grad program must stay well under
    the S^2 f32 score-matrix size."""
    S, H, K, hd, chunk = 1024, 4, 2, 16, 128
    q = jax.ShapeDtypeStruct((1, S, H, hd), jnp.float32)
    kv = jax.ShapeDtypeStruct((1, S, K, hd), jnp.float32)

    def loss(q, k, v):
        return jnp.sum(A.attend(q, k, v, causal=True, chunk=chunk))

    compiled = jax.jit(jax.grad(loss, argnums=(0, 1, 2))).lower(
        q, kv, kv).compile()
    mem = compiled.memory_analysis()
    s2_bytes = S * S * K * (H // K) * 4          # per-batch f32 score matrix
    assert mem.temp_size_in_bytes < s2_bytes / 2, (
        f"temp {mem.temp_size_in_bytes} vs S^2 scores {s2_bytes}: "
        "quadratic residuals are back")
