"""Assigned-architecture smoke tests (deliverable f).

Each of the ten architectures instantiates its REDUCED config (same family,
small dims) and runs one forward + one protected train step on CPU, asserting
output shapes and the absence of NaNs.  The FULL configs are exercised by the
dry-run only (launch/dryrun.py) and are shape-checked here without
allocation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import WORKLOADS
from repro.configs.base import TrainConfig, workload_skips
from repro.configs.registry import get_config, list_archs
from repro.models import api
from repro.models.transformer import build_model
from repro.optim import build_optimizer

ARCHS = list_archs()

# exact published configs (the assignment's table)
EXPECTED = {
    "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv=8, vocab=202048),
    "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                n_kv=16, d_ff=1408, vocab=163840),
    "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                  n_kv=16, d_ff=8192, vocab=256206),
    "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv=8,
                        d_ff=16384, vocab=256000),
    "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14, n_kv=2,
                       d_ff=4864, vocab=151936),
    "glm4-9b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv=2,
                    d_ff=13696, vocab=151552),
    "qwen3-0.6b": dict(n_layers=28, d_model=1024, n_heads=16, n_kv=8,
                       d_ff=3072, vocab=151936),
    "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64, n_kv=8,
                          d_ff=22016, vocab=65536),
    "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10, n_kv=1,
                              d_ff=7680, vocab=256000),
    "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4, n_kv=4,
                       d_ff=0, vocab=50304),
}


def test_all_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_shapes(arch):
    """Full config param tree builds abstractly (no allocation) and its
    parameter count lands within 25% of the name's billion-scale claim."""
    cfg = get_config(arch)
    n = api.count_params(cfg)
    claimed = {
        "llama4-maverick-400b-a17b": 400e9,
        # assignment pins 48L x 64e (the HF Moonlight release is 27L);
        # at the assigned depth the analytic count is ~28B
        "moonshot-v1-16b-a3b": 28e9,
        "minitron-8b": 8e9, "qwen2-0.5b": 0.5e9, "glm4-9b": 9e9,
        "qwen3-0.6b": 0.6e9, "chameleon-34b": 34e9,
        "recurrentgemma-2b": 2e9, "xlstm-1.3b": 1.3e9,
        "seamless-m4t-large-v2": 2.3e9,
    }[arch]
    assert 0.6 * claimed < n < 1.6 * claimed, (arch, n, claimed)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S - cfg.mm_positions),
                             0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.mm_positions:
        batch["mm_embeds"] = 0.01 * jnp.ones(
            (B, cfg.mm_positions, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    if cfg.enc_layers:
        batch["src_embeds"] = 0.01 * jnp.ones(
            (B, S, cfg.d_model), jnp.dtype(cfg.compute_dtype))

    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    optimizer = build_optimizer(TrainConfig(microbatches=1), cfg)
    state = api.init_train_state(model, optimizer, jax.random.PRNGKey(0))
    step = jax.jit(api.make_train_step(model, optimizer,
                                       TrainConfig(microbatches=1)))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved, arch
    for leaf in jax.tree.leaves(new_state["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 8
    cache = model.init_cache(B, T)
    if cfg.enc_layers:
        src = 0.01 * jnp.ones((B, T, cfg.d_model),
                              jnp.dtype(cfg.compute_dtype))
        cache["cross"] = model.build_cross_cache(
            params, model.encode(params, src))
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(
        params, tok, cache, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_workload_skip_policy(arch):
    """long_500k runs iff the architecture is sub-quadratic (DESIGN.md §4)."""
    cfg = get_config(arch)
    skip = workload_skips(cfg, WORKLOADS["long_500k"])
    if arch in ("recurrentgemma-2b", "xlstm-1.3b"):
        assert skip is None
    else:
        assert skip is not None
    for wl in ("train_4k", "prefill_32k", "decode_32k"):
        assert workload_skips(cfg, WORKLOADS[wl]) is None


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_abstract(arch):
    """input_specs stand-ins exist for every workload cell (dry-run contract)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    for wl_name, wl in WORKLOADS.items():
        if workload_skips(cfg, wl):
            continue
        if wl.kind in ("train", "prefill"):
            ab = api.batch_abstract(cfg, wl)
            assert ab["tokens"].shape == (wl.global_batch,
                                          wl.seq_len - cfg.mm_positions)
        else:
            ab = api.decode_abstract(cfg, wl, model)
            assert ab["token"].shape == (wl.global_batch,)
            assert all(hasattr(l, "shape")
                       for l in jax.tree.leaves(ab["cache"]))
