"""XOR collectives: the distributed realization of Pangolin's atomic-XOR
algebra.  Each collective must equal a host-side XOR reference, for any
operand content, and the three variants must agree with each other."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import collectives as coll


def put_rows(mesh, rows):
    """rows: (G, n) np.uint32 -> sharded (G*n,) array, row g on data-rank g."""
    g, n = rows.shape
    arr = jnp.asarray(rows.reshape(-1))
    return jax.device_put(arr, NamedSharding(mesh, P(("data",))))


def run_zone(mesh, fn, x, out_spec):
    f = shard_map(fn, mesh=mesh, in_specs=(P(("data",)),),
                  out_specs=out_spec, check_vma=False)
    return jax.jit(f)(x)


@pytest.mark.parametrize("n", [8, 64, 256])
def test_xor_reduce_scatter(mesh42, n):
    g = mesh42.shape["data"]
    rng = np.random.default_rng(n)
    rows = rng.integers(0, 2**32, size=(g, n), dtype=np.uint32)
    x = put_rows(mesh42, rows)
    out = run_zone(mesh42, lambda r: coll.xor_reduce_scatter(r, "data"),
                   x, P(("data",)))
    want = functools.reduce(np.bitwise_xor, rows)  # (n,)
    np.testing.assert_array_equal(np.asarray(out), want)


@pytest.mark.parametrize("n", [8, 60])   # 60: needs padding inside all_reduce
def test_xor_all_reduce(mesh42, n):
    g = mesh42.shape["data"]
    rng = np.random.default_rng(n + 1)
    rows = rng.integers(0, 2**32, size=(g, n), dtype=np.uint32)
    x = put_rows(mesh42, rows)
    # every rank gets the full XOR; stack outputs to verify each rank's copy
    out = run_zone(mesh42, lambda r: coll.xor_all_reduce(r, "data")[None],
                   x, P(("data",)))
    want = functools.reduce(np.bitwise_xor, rows)
    got = np.asarray(out).reshape(g, n)
    for r in range(g):
        np.testing.assert_array_equal(got[r], want)


def test_xor_tree_reduce_matches_all_reduce(mesh81):
    g = mesh81.shape["data"]
    rng = np.random.default_rng(42)
    rows = rng.integers(0, 2**32, size=(g, 32), dtype=np.uint32)
    x = put_rows(mesh81, rows)
    out_tree = run_zone(mesh81, lambda r: coll.xor_tree_reduce(r, "data")[None],
                        x, P(("data",)))
    want = functools.reduce(np.bitwise_xor, rows)
    got = np.asarray(out_tree).reshape(g, 32)
    for r in range(g):
        np.testing.assert_array_equal(got[r], want)


def test_xor_fold_matches_reduce():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 5, 8, 13):
        x = rng.integers(0, 2**32, size=(n, 16), dtype=np.uint32)
        out = coll.xor_fold(jnp.asarray(x), axis=0)
        want = functools.reduce(np.bitwise_xor, x)
        np.testing.assert_array_equal(np.asarray(out), want)


def test_all_gather_row_inverse_of_scatter(mesh42):
    g = mesh42.shape["data"]
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 2**32, size=(g, 16), dtype=np.uint32)
    x = put_rows(mesh42, rows)

    def fn(r):
        seg = coll.xor_reduce_scatter(r, "data")
        return coll.all_gather_row(seg, "data")[None]

    out = run_zone(mesh42, fn, x, P(("data",)))
    want = functools.reduce(np.bitwise_xor, rows)
    got = np.asarray(out).reshape(g, 16)
    for r in range(g):
        np.testing.assert_array_equal(got[r], want)
