"""Blockwise double-buffered streaming commit pipeline (ISSUE 6).

Three layers, all exact-equality (uint32 integer math):

  * kernels — the streamed Pallas kernels (interpret mode) must be
    bit-identical to the flat kernels AND the jnp oracles for every
    chunk geometry: single-block rows, ragged tails (n % chunk != 0),
    and rows many chunks long ("larger than VMEM"), across the whole
    syndrome-stack range r in {1..4}; the loop-carried row digest must
    equal `checksum.combine` of the emitted per-block terms.
  * collectives — the chunked syndrome reduce-scatter / delta fold must
    be bit-identical to the unchunked collective (chunking slices the
    segment axis, so the concatenated pieces are positionally identical
    and the GF weighting commutes element-wise).
  * engines — a Protector forced onto the streamed path
    (stream_threshold_words=1) must commit bit-identically to the flat
    protector, and the deferred bulk engine (the fused_accum_commit
    accumulator) must land bit-identical to the synchronous engine at
    every window boundary with streaming enabled, W in {1, 16}.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import checksum as cksum
from repro.core import gf
from repro.core.epoch import DeferredProtector
from repro.core.txn import Mode, Protector
from repro.dist import collectives as coll
from repro.kernels import commit_fused, fletcher, gf_parity, ops, ref
from tests.conftest import small_state

U32 = jnp.uint32


def rand_u32(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=shape, dtype=np.uint32))


def assert_trees_equal(got, want):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def coeffs_for(r, me=3):
    return jnp.asarray([gf.pow_g_int(k * me) for k in range(r)], U32)


# (n_blocks, block_words, chunk_blocks): single-block, ragged tails,
# exact multiples, and a many-chunk row standing in for >> VMEM
GEOMS = [(1, 128, 4), (3, 128, 1), (5, 256, 2), (8, 128, 4),
         (17, 128, 4), (33, 128, 8), (64, 512, 4)]


@pytest.mark.parametrize("n,bw,cb", GEOMS)
def test_stream_single_parity_kernels_vs_flat_and_ref(n, bw, cb):
    old, new = rand_u32((n, bw), seed=n), rand_u32((n, bw), seed=n + 1)
    stored = ref.fletcher_blocks_ref(old)

    d_s, c_s, dig = commit_fused.fused_commit_stream(
        old, new, chunk_blocks=cb, interpret=True)
    d_f, c_f = commit_fused.fused_commit(old, new, interpret=True)
    assert_trees_equal((d_s, c_s), (d_f, c_f))
    assert_trees_equal((d_s, c_s, dig), ref.fused_commit_stream_ref(old, new))
    # the loop-carried digest == combine of the emitted per-block terms
    np.testing.assert_array_equal(np.asarray(dig),
                                  np.asarray(cksum.combine(c_s, bw)))

    out_s = commit_fused.fused_verify_commit_stream(
        old, new, stored, chunk_blocks=cb, interpret=True)
    assert_trees_equal(out_s[:3], commit_fused.fused_verify_commit(
        old, new, stored, interpret=True))
    assert_trees_equal(out_s, ref.fused_verify_commit_stream_ref(
        old, new, stored))

    out_s = commit_fused.fused_commit_old_terms_stream(
        old, new, chunk_blocks=cb, interpret=True)
    assert_trees_equal(out_s[:3], commit_fused.fused_commit_old_terms(
        old, new, interpret=True))
    assert_trees_equal(out_s, ref.fused_commit_old_terms_stream_ref(old, new))

    ck_s, dig = fletcher.fletcher_stream(new, chunk_blocks=cb,
                                         interpret=True)
    assert_trees_equal((ck_s, dig), ref.fletcher_stream_ref(new))


@pytest.mark.parametrize("n,bw,cb", GEOMS)
def test_stream_accum_kernel_vs_flat_and_ref(n, bw, cb):
    acc = rand_u32((n, bw), seed=n + 2)
    old, new = rand_u32((n, bw), seed=n + 3), rand_u32((n, bw), seed=n + 4)
    out_s = commit_fused.fused_accum_commit_stream(
        acc, old, new, chunk_blocks=cb, interpret=True)
    assert_trees_equal(out_s[:3], commit_fused.fused_accum_commit(
        acc, old, new, interpret=True))
    assert_trees_equal(out_s, ref.fused_accum_commit_stream_ref(
        acc, old, new))
    np.testing.assert_array_equal(
        np.asarray(out_s[3]), np.asarray(cksum.combine(out_s[2], bw)))


@pytest.mark.parametrize("n,bw,cb", [(1, 128, 4), (5, 256, 2), (17, 128, 4),
                                     (33, 128, 8)])
@pytest.mark.parametrize("r", [2, 3, 4])
def test_stream_syndrome_kernels_vs_flat_and_ref(n, bw, cb, r):
    """One streamed pass must emit ALL r weighted planes bit-identically
    to the flat stacked kernel — the row is read once per commit
    regardless of the redundancy."""
    old, new = rand_u32((n, bw), seed=7 * n), rand_u32((n, bw), seed=7 * n + 1)
    stored = ref.fletcher_blocks_ref(old)
    co = coeffs_for(r)

    out_s = gf_parity.fused_commit_s_stream(old, new, co, chunk_blocks=cb,
                                            interpret=True)
    assert_trees_equal(out_s[:2], gf_parity.fused_commit_s(
        old, new, co, interpret=True))
    assert_trees_equal(out_s, ref.fused_commit_s_stream_ref(old, new, co))

    out_s = gf_parity.fused_verify_commit_s_stream(
        old, new, stored, co, chunk_blocks=cb, interpret=True)
    assert_trees_equal(out_s[:3], gf_parity.fused_verify_commit_s(
        old, new, stored, co, interpret=True))
    assert_trees_equal(out_s, ref.fused_verify_commit_s_stream_ref(
        old, new, stored, co))
    np.testing.assert_array_equal(
        np.asarray(out_s[3]), np.asarray(cksum.combine(out_s[1], bw)))


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_ops_stream_dispatch_r_sweep(r):
    """ops-level dispatch: coeffs=None (r=1) routes to the single-parity
    stream and reshapes the delta plane; interpret and CPU-oracle routes
    agree bit-for-bit."""
    old, new = rand_u32((6, 128), seed=40), rand_u32((6, 128), seed=41)
    stored = ref.fletcher_blocks_ref(old)
    co = coeffs_for(r) if r > 1 else None
    for interpret in (None, True):      # None -> CPU oracle route
        sd, ck, dig = ops.fused_commit_s_stream(old, new, co,
                                                chunk_blocks=4,
                                                interpret=interpret)
        assert sd.shape == (r, 6, 128)
        want_sd = (old ^ new)[None] if r == 1 else ref.sdelta_stack_ref(
            old ^ new, co)
        assert_trees_equal((sd, ck), (want_sd, ref.fletcher_blocks_ref(new)))
        np.testing.assert_array_equal(np.asarray(dig),
                                      np.asarray(cksum.combine(ck, 128)))
        sd, ck, bad, dig = ops.fused_verify_commit_s_stream(
            old, new, stored, co, chunk_blocks=4, interpret=interpret)
        assert not np.asarray(bad).any()
        np.testing.assert_array_equal(np.asarray(sd), np.asarray(want_sd))


def test_syndrome_scale_stacked_kernel_vs_oracle():
    """Satellite: one stacked-plane kernel replaces the per-plane gf_scale
    loop — 2-D and 1-D deltas (the flush path flattens), 1024-divisible
    and not."""
    co = coeffs_for(3)
    for shape, seed in [((8, 1024), 50), ((7, 96), 51), ((4096,), 52),
                        ((1000,), 53)]:
        d = rand_u32(shape, seed=seed)
        got = ops.syndrome_scale(d, co, interpret=True)
        want = ref.sdelta_stack_ref(d, co)
        assert got.shape == (3,) + shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(ops.syndrome_scale(d, co)), np.asarray(want))
    # r=1 stays the PR 1 program: the raw delta, never recomputed
    d = rand_u32((4, 64), seed=54)
    np.testing.assert_array_equal(
        np.asarray(ops.syndrome_scale(d, None)), np.asarray(d)[None])


def test_stream_policy_thresholds():
    kw = dict(threshold_words=1 << 20, chunk_words=1 << 16)
    assert ops.stream_chunk_blocks(256, 1024, **kw) is None   # 1 MB < 4 MiB
    assert ops.stream_chunk_blocks(4096, 1024, **kw) == 64    # 16 MiB row
    assert ops.stream_chunk_blocks(4096, 1024, threshold_words=0,
                                   chunk_words=1 << 16) is None
    # chunk never exceeds the row, never drops below one page
    assert ops.stream_chunk_blocks(4, 1024, threshold_words=1,
                                   chunk_words=1 << 16) == 4
    assert ops.stream_chunk_blocks(8, 4096, threshold_words=1,
                                   chunk_words=64) == 1


# -- chunked collectives ------------------------------------------------------

def put_rows(mesh, rows):
    return jax.device_put(jnp.asarray(rows.reshape(-1)),
                          NamedSharding(mesh, P(("data",))))


@pytest.mark.parametrize("r", [1, 3])
@pytest.mark.parametrize("chunks", [2, 4, 7])
def test_chunked_syndrome_reduce_scatter_matches_unchunked(mesh81, r,
                                                           chunks):
    g = mesh81.shape["data"]
    n = 64 * g
    rows = np.random.default_rng(r * 10 + chunks).integers(
        0, 2**32, size=(g, n), dtype=np.uint32)
    x = put_rows(mesh81, rows)

    def run(c):
        f = shard_map(
            lambda row: coll.syndrome_reduce_scatter(row, r, "data",
                                                     chunks=c),
            mesh=mesh81, in_specs=(P(("data",)),),
            out_specs=P(None, ("data",)), check_vma=False)
        return np.asarray(jax.jit(f)(x))

    np.testing.assert_array_equal(run(chunks), run(1))


@pytest.mark.parametrize("r", [1, 3])
def test_chunked_syndrome_apply_delta_matches_unchunked(mesh81, r):
    g = mesh81.shape["data"]
    n = 64 * g
    rng = np.random.default_rng(77 + r)
    sdelta = rng.integers(0, 2**32, size=(g, r, n), dtype=np.uint32)
    synd = rng.integers(0, 2**32, size=(g, r, n // g), dtype=np.uint32)
    sd = jax.device_put(jnp.asarray(sdelta.reshape(g * r, n)),
                        NamedSharding(mesh81, P(("data",))))
    sy = jax.device_put(jnp.asarray(synd.reshape(g * r, n // g)),
                        NamedSharding(mesh81, P(("data",))))

    def run(c):
        f = shard_map(
            lambda s, d: coll.syndrome_apply_delta(
                s.reshape(r, n // g), d.reshape(r, n), "data", chunks=c),
            mesh=mesh81, in_specs=(P(("data",)), P(("data",))),
            out_specs=P(None, ("data",)), check_vma=False)
        return np.asarray(jax.jit(f)(sy, sd))

    np.testing.assert_array_equal(run(4), run(1))


# -- engine-level bit-identity ------------------------------------------------

def _assert_protection_equal(pa, pb, mode):
    np.testing.assert_array_equal(np.asarray(pa.synd), np.asarray(pb.synd))
    np.testing.assert_array_equal(np.asarray(pa.digest),
                                  np.asarray(pb.digest))
    np.testing.assert_array_equal(np.asarray(pa.row), np.asarray(pb.row))
    if mode.has_cksums:
        np.testing.assert_array_equal(np.asarray(pa.cksums),
                                      np.asarray(pb.cksums))


def make_protector(mesh, state, specs, mode, **kw):
    kw.setdefault("block_words", 64)
    return Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                     **kw)


STREAM_KW = dict(stream_threshold_words=1, stream_chunk_words=128)


@pytest.mark.parametrize("mode,red", [(Mode.MLPC, 1), (Mode.MLPC, 3),
                                      (Mode.MLP, 2)])
def test_streamed_protector_commits_match_flat(mesh42, mode, red):
    """stream_threshold_words=1 forces every bulk commit through the
    streamed kernels + chunked collectives; the protected state must
    stay bit-identical to the flat protector's after every commit."""
    state, specs, _ = small_state(mesh42)
    p_flat = make_protector(mesh42, state, specs, mode, redundancy=red,
                            stream_threshold_words=0)
    p_str = make_protector(mesh42, state, specs, mode, redundancy=red,
                           **STREAM_KW)
    assert p_str.stream_chunk() is not None, \
        "test must exercise the streamed path"
    pr_f, pr_s = p_flat.init(state), p_str.init(state)
    cur = state
    for i in range(3):
        cur = jax.tree.map(lambda x: (x * 1.01 + 0.01).astype(x.dtype), cur)
        key = jax.random.PRNGKey(i)
        pr_f, ok_f = p_flat.commit(pr_f, cur, rng_key=key, data_cursor=i,
                                   verify_old=True)
        pr_s, ok_s = p_str.commit(pr_s, cur, rng_key=key, data_cursor=i,
                                  verify_old=True)
        assert bool(ok_f) and bool(ok_s)
        _assert_protection_equal(pr_f, pr_s, mode)
    # the non-verifying commit takes the fletcher_stream + rebuild route
    cur = jax.tree.map(lambda x: (x + 1).astype(x.dtype), cur)
    pr_f, _ = p_flat.commit(pr_f, cur)
    pr_s, _ = p_str.commit(pr_s, cur)
    _assert_protection_equal(pr_f, pr_s, mode)


@pytest.mark.parametrize("window", [1, 16])
@pytest.mark.parametrize("red", [1, 3])
def test_streamed_deferred_bulk_matches_sync_at_boundaries(mesh42, window,
                                                           red):
    """The deferred bulk engine's fused_accum_commit path, with the
    streaming threshold forced on: at every window boundary (and per
    step for the digest) it must land exactly where the synchronous
    streamed engine lands."""
    state, specs, _ = small_state(mesh42)
    p = make_protector(mesh42, state, specs, Mode.MLPC, redundancy=red,
                       **STREAM_KW)
    prot_sync = p.init(state)
    eng = DeferredProtector(p, window=window, donate=False)
    est = eng.init(state)
    cur = state
    steps = 2 * window if window > 1 else 3
    for i in range(steps):
        cur = jax.tree.map(lambda x: (x * 1.02 + 0.005).astype(x.dtype),
                           cur)
        key = jax.random.PRNGKey(100 + i)
        prot_sync, ok_s = p.commit(prot_sync, cur, rng_key=key)
        est, ok_d = eng.commit(est, cur, rng_key=key)
        assert bool(ok_s) and bool(ok_d)
        np.testing.assert_array_equal(np.asarray(prot_sync.digest),
                                      np.asarray(est.prot.digest))
        if (i + 1) % window == 0:
            _assert_protection_equal(prot_sync, est.prot, Mode.MLPC)
