"""The trip-count-aware HLO cost model feeding the roofline: validated
against XLA's own cost_analysis on unrolled programs, and against analytic
expectations on scanned programs (where XLA under-counts loop bodies)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis, hlo_cost


def compiled_text(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return compiled.as_text(), cost


def test_matmul_flops_match_xla():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    text, xla = compiled_text(lambda x, y: x @ y, a, b)
    tot = hlo_cost.analyze_text(text)
    want = 2 * 128 * 256 * 512
    assert tot.flops == pytest.approx(want, rel=0.02)
    assert float(xla.get("flops", 0)) == pytest.approx(want, rel=0.02)


def test_scan_multiplies_body_flops():
    """XLA counts the while body once; the cost model must multiply by the
    trip count."""
    n_iters, m = 7, 64
    w = jax.ShapeDtypeStruct((n_iters, m, m), jnp.float32)
    x = jax.ShapeDtypeStruct((m, m), jnp.float32)

    def scanned(ws, x0):
        def body(x, w):
            return w @ x, ()
        out, _ = jax.lax.scan(body, x0, ws)
        return out

    text, xla = compiled_text(scanned, w, x)
    tot = hlo_cost.analyze_text(text)
    body_flops = 2 * m * m * m
    assert tot.flops == pytest.approx(n_iters * body_flops, rel=0.1)
    # and XLA indeed under-counts (sanity check of the premise)
    assert float(xla.get("flops", 0)) <= body_flops * 2


def test_unrolled_vs_scanned_agree():
    """Total flops of the same computation must match whether scanned or
    unrolled — the invariant the trip-count roll-up exists to provide."""
    n_iters, m = 5, 32
    ws = jnp.ones((n_iters, m, m), jnp.float32)
    x0 = jnp.ones((m, m), jnp.float32)

    def scanned(ws, x0):
        def body(x, w):
            return w @ x, ()
        return jax.lax.scan(body, x0, ws)[0]

    def unrolled(ws, x0):
        x = x0
        for i in range(n_iters):
            x = ws[i] @ x
        return x

    t_s, _ = compiled_text(scanned, ws, x0)
    t_u, _ = compiled_text(unrolled, ws, x0)
    f_s = hlo_cost.analyze_text(t_s).flops
    f_u = hlo_cost.analyze_text(t_u).flops
    assert f_s == pytest.approx(f_u, rel=0.1)


def test_parse_hlo_finds_entry():
    text, _ = compiled_text(lambda x: x + 1.0,
                            jax.ShapeDtypeStruct((8,), jnp.float32))
    comps = hlo_cost.parse_hlo(text)
    assert any(c.is_entry for c in comps.values())


def test_roofline_terms_bound_selection():
    r = hlo_analysis.roofline_terms(flops=1e15, hbm_bytes=1e9, wire_bytes=1e6)
    assert r.bound == "compute"
    r = hlo_analysis.roofline_terms(flops=1e9, hbm_bytes=1e13, wire_bytes=1e6)
    assert r.bound == "memory"
    r = hlo_analysis.roofline_terms(flops=1e9, hbm_bytes=1e9, wire_bytes=1e13)
    assert r.bound == "collective"
    r = hlo_analysis.roofline_terms(1e12, 1e9, 1e6, model_flops=5e11)
    assert r.useful_ratio == pytest.approx(0.5)


def test_collective_parsing_shard_map(mesh42):
    """psum inside shard_map must be seen as an all-reduce with wire bytes
    2 (G-1)/G * payload."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    n = 1024

    def f(x):
        return jax.lax.psum(x, "data")

    sm = shard_map(f, mesh=mesh42, in_specs=P(), out_specs=P(),
                   check_vma=False)
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    text, _ = compiled_text(sm, x)
    tot = hlo_cost.analyze_text(text)
    assert tot.coll_counts["all-reduce"] >= 1
    g = 4
    want = 2 * (g - 1) / g * n * 4
    assert tot.wire_bytes["all-reduce"] == pytest.approx(want, rel=0.05)


def test_collective_parsing_all_gather(mesh42):
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.all_gather(x, "data", axis=0, tiled=True)

    sm = shard_map(f, mesh=mesh42, in_specs=P(("data",)), out_specs=P(),
                   check_vma=False)
    x = jax.ShapeDtypeStruct((64,), jnp.float32)   # 16 per rank
    text, _ = compiled_text(sm, x)
    tot = hlo_cost.analyze_text(text)
    assert tot.coll_counts["all-gather"] >= 1
    g = 4
    want = (g - 1) / g * 64 * 4      # result bytes convention
    assert tot.wire_bytes["all-gather"] == pytest.approx(want, rel=0.05)


def test_memory_bytes_reasonable():
    """Fusion-aware byte count for y = x @ w: reads x, w; writes y."""
    m = 256
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    text, _ = compiled_text(lambda x, y: x @ y, a, a)
    tot = hlo_cost.analyze_text(text)
    want = 3 * m * m * 4
    assert tot.hbm_bytes == pytest.approx(want, rel=0.25)
