"""Async commit pipeline (core/pipeline.py + Pool.commit_async).

The pipeline is bookkeeping around device scalars the commit programs
already produce, so the bar is BIT-IDENTITY: an N-deep pipeline drained
at any boundary must land the exact protection bits synchronous
resolution lands — across {sync, deferred} engines, redundancy
r in {1, 3}, ring depths {1, 2, 4, 8}, mid-flight device-canary aborts,
and a fault arriving with k commits still in flight.  On top of that:
out-of-order verdict resolution, the merged-window transaction protocol
(disjoint footprints coalesce, conflicts serialize), the
no-host-sync-at-dispatch guarantee (satellite 1's assertion: zero
`jax.device_get` calls during steady-state async dispatch, including
the replicated window-meta mirror), and the exemplar linkage from the
resolve-latency histogram back to trace span ids
(scripts/trace_check.py --prom).
"""
import dataclasses
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtectConfig
from repro.core.pipeline import CommitRing, CommitTicket
from repro.kernels import ops as kops
from repro.obs.export import prometheus_text
from repro.obs.trace import Tracer
from repro.pool import Fault, Pool
from repro.runtime import failure
from tests.conftest import small_state


def _evolve(cur):
    return jax.tree.map(lambda x: (x * 1.01 + 0.003).astype(x.dtype), cur)


def _chain(state, n):
    """The deterministic state chain both pools commit (independent of
    either pool's resolution policy, so divergence is the pool's)."""
    out, cur = [], state
    for _ in range(n):
        cur = _evolve(cur)
        out.append(cur)
    return out


def _assert_protection_equal(pa, pb):
    np.testing.assert_array_equal(np.asarray(pa.digest),
                                  np.asarray(pb.digest))
    np.testing.assert_array_equal(np.asarray(pa.synd), np.asarray(pb.synd))
    np.testing.assert_array_equal(np.asarray(pa.row), np.asarray(pb.row))


def _assert_state_equal(sa, sb):
    for la, lb in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# -- host-only ring / ticket semantics (no device work) -----------------------


class _FakeScalar:
    """A device-scalar stand-in with controllable readiness."""

    def __init__(self, value, ready=False):
        self.value = bool(value)
        self._ready = bool(ready)

    def is_ready(self):
        return self._ready

    def __bool__(self):
        return self.value


def test_ticket_resolves_once_and_fires_callback():
    fired = []
    t = CommitTicket(0, True, on_resolve=fired.append)
    assert not t.resolved and t.ready()          # host bool: always ready
    assert t.result() is True
    assert t.resolved and t.resolve_latency_ms is not None
    assert fired == [t]
    # the callback saw the CACHED verdict (set before firing)
    assert fired[0].result() is True
    t.result()                                   # idempotent: fires once
    assert fired == [t]


def test_ticket_void_skips_device_and_is_deterministic():
    t = CommitTicket(0, _FakeScalar(True, ready=False))
    assert t.void(False) is False                # never consults the scalar
    assert t.voided and t.result() is False      # resolution is sticky


def test_ring_polls_out_of_dispatch_order():
    ring = CommitRing(4)
    slow = _FakeScalar(True, ready=False)
    fast = _FakeScalar(True, ready=True)
    t0 = ring.submit(CommitTicket(0, slow))
    t1 = ring.submit(CommitTicket(1, fast))
    t2 = ring.submit(CommitTicket(2, fast))
    done = ring.poll()                           # t1/t2 land before t0
    assert done == [t1, t2] and not t0.resolved and len(ring) == 1
    slow._ready = True
    assert ring.poll() == [t0] and len(ring) == 0


def test_ring_backpressure_force_resolves_oldest():
    depths = []
    ring = CommitRing(2, on_depth=depths.append)
    t0 = ring.submit(CommitTicket(0, True))
    t1 = ring.submit(CommitTicket(1, True))
    t2 = ring.submit(CommitTicket(2, True))      # full: t0 force-resolved
    assert t0.resolved and not t1.resolved and not t2.resolved
    assert ring.in_flight == [t1, t2]
    assert ring.drain() == [t1, t2]              # dispatch order
    assert depths == [1, 2, 2, 0]

    bad = CommitRing(3)
    for s in range(3):
        bad.submit(CommitTicket(s, True))
    voided = bad.void_all(False)
    assert len(voided) == 3 and all(t.voided for t in voided)
    assert all(t.result() is False for t in voided)


def test_pipeline_depth_config_validation():
    with pytest.raises(Exception):
        ProtectConfig(mode="mlpc", redundancy=1, pipeline_depth=0)
    with pytest.raises(AssertionError):
        CommitRing(0)


# -- drained pipeline == synchronous resolution, engines x r x depth ----------


@pytest.mark.parametrize("window", [1, 4], ids=["sync", "deferred"])
@pytest.mark.parametrize("red", [1, 3])
def test_drained_pipeline_bit_identical(mesh42, window, red):
    """ISSUE bar: for every depth in {1, 2, 4, 8}, dispatch the same
    chain of commits through the ring, drain at the boundary, and the
    full protection stack must equal the synchronous engine's bits —
    both engines, r in {1, 3}."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=red, window=window,
                        block_words=64)
    ref = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    chain = _chain(state, 2 * max(window, 2))
    for i, s in enumerate(chain):                # synchronous reference
        assert bool(ref.commit(s, data_cursor=i,
                               rng_key=jax.random.PRNGKey(i)))
    ref.flush()

    for depth in (1, 2, 4, 8):
        pcfg = dataclasses.replace(cfg, pipeline_depth=depth)
        pool = Pool.open(state, specs, mesh=mesh42, config=pcfg,
                         donate=False, protector=ref.protector)
        tickets = [pool.commit_async(s, data_cursor=i,
                                     rng_key=jax.random.PRNGKey(i))
                   for i, s in enumerate(chain)]
        assert pool.in_flight <= depth           # ring back-pressure held
        pool.drain()
        assert pool.in_flight == 0
        assert all(t.resolved and t.result() for t in tickets)
        pool.flush()
        _assert_protection_equal(pool.prot, ref.prot)
        _assert_state_equal(pool.state, ref.state)


# -- staged device canaries: mid-flight aborts ---------------------------------


@pytest.mark.parametrize("window", [1, 4], ids=["sync", "deferred"])
def test_staged_abort_mid_flight_bit_identical(mesh42, window):
    """A device-side canary verdict the host cannot know at dispatch
    ([T, T, F, T, T] staged through `kops.stage_verdict`) must abort
    commit 2 INSIDE the ring exactly as the host-known abort does, with
    the abort counter settling at resolution."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=window,
                        block_words=64, pipeline_depth=4)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    ref = Pool.open(state, specs, mesh=mesh42, config=dataclasses.replace(
        cfg, pipeline_depth=1), donate=False, protector=pool.protector)
    chain = _chain(state, 5)
    verdicts = [True, True, False, True, True]

    tickets = []
    for i, s in enumerate(chain):
        dev = kops.stage_verdict([jnp.asarray(verdicts[i])])
        tickets.append(pool.commit_async(s, data_cursor=i,
                                         canary_ok=dev))
        assert tickets[-1].staged
    aborted_before = pool.metrics.counter(
        "pool_commit_aborted_total").value
    pool.drain()
    assert [t.result() for t in tickets] == verdicts
    # staged abort bookkeeping deferred to resolution, exactly one abort
    assert pool.metrics.counter("pool_commit_aborted_total").value == \
        aborted_before + 1
    pool.flush()

    for i, s in enumerate(chain):                # host-known reference
        ok = ref.commit(s, data_cursor=i, canary_ok=verdicts[i])
        assert bool(ok) == verdicts[i]
    ref.flush()
    _assert_protection_equal(pool.prot, ref.prot)
    _assert_state_equal(pool.state, ref.state)


# -- fault arrival with k commits in flight ------------------------------------


def test_recover_with_inflight_commits(mesh42):
    """Recovery must drain the ring first: with k=3 unresolved tickets
    at injection, `recover` resolves them deterministically, repairs,
    and the end state is bit-identical to a fault-free pool running the
    same chain."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=4,
                        block_words=64, pipeline_depth=4)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    ref = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False,
                    protector=pool.protector)
    chain = _chain(state, 6)
    for i, s in enumerate(chain[:3]):
        pool.commit_async(s, data_cursor=i)
    pool.drain()

    burst = [pool.commit_async(s, data_cursor=3 + i)
             for i, s in enumerate(chain[3:])]
    assert pool.in_flight == 3
    assert pool.stats()["in_flight"] == 3
    assert pool.metrics.gauge("pool_inflight_depth").value == 3
    pool.inject(lambda p, pr: failure.inject_rank_loss(p, pr, rank=1))
    rep = pool.recover(Fault.rank_loss(1))
    assert rep.verified
    assert pool.in_flight == 0                   # recovery drained first
    assert all(t.resolved and t.result() for t in burst)
    pool.flush()

    for i, s in enumerate(chain):
        ref.commit(s, data_cursor=i)
    ref.flush()
    _assert_protection_equal(pool.prot, ref.prot)
    _assert_state_equal(pool.state, ref.state)


# -- merged-window transaction protocol ----------------------------------------


def test_disjoint_transactions_coalesce(mesh42):
    """Disjoint page footprints join ONE merge group — no seal between
    them, the coalesced counter ticks, and the telescoped flush lands
    the same bits as serial transactions."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=4,
                        block_words=64)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    ref = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False,
                    protector=pool.protector)
    chain = _chain(state, 3)

    for i, s in enumerate(chain):
        with pool.transaction(data_cursor=i, pages=[2 * i, 2 * i + 1]) \
                as tx:
            tx.stage(s)
        assert tx.ok
    assert pool.metrics.counter("pool_txn_coalesced_total").value == 2
    assert pool.metrics.counter("pool_txn_serialized_total").value == 0
    pool.flush()                                 # one telescoped flush

    for i, s in enumerate(chain):
        with ref.transaction(data_cursor=i) as tx:
            tx.stage(s)
    ref.flush()
    _assert_protection_equal(pool.prot, ref.prot)


def test_conflicting_transactions_serialize(mesh42):
    """An overlapping footprint (or a whole-state transaction) seals the
    open merge group — the serialized counter ticks and the group's
    window flushes before the conflicting transaction joins a fresh
    one."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=4,
                        block_words=64)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    chain = _chain(state, 3)

    with pool.transaction(data_cursor=0, pages=[0, 1]) as tx:
        tx.stage(chain[0])
    with pool.transaction(data_cursor=1, pages=[1, 2]) as tx:  # overlap
        tx.stage(chain[1])
    assert pool.metrics.counter("pool_txn_serialized_total").value == 1
    with pool.transaction(data_cursor=2) as tx:  # None = whole state
        tx.stage(chain[2])
    assert pool.metrics.counter("pool_txn_serialized_total").value == 2
    assert pool.metrics.counter("pool_txn_coalesced_total").value == 0
    pool.flush()
    rep = pool.scrub()
    assert rep.parity_ok and rep.bad_locations == []


# -- no host sync at dispatch (satellite 1) ------------------------------------


def test_async_dispatch_never_syncs_host(mesh42, monkeypatch):
    """Steady-state `commit_async` on the deferred bulk engine — with
    window-meta replication ON (the bulk default, now an async
    all-gather instead of the old blocking `device_get`) — must make
    ZERO `jax.device_get` calls at dispatch.  Draining (verdict fetch)
    is where the sync belongs, and it shows up exactly there."""
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=2, window=4,
                        block_words=64, pipeline_depth=4)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False)
    assert pool.engine is not None and pool.engine.replicate_meta
    chain = _chain(state, 12)
    for i, s in enumerate(chain[:8]):            # warm every program
        pool.commit_async(s, data_cursor=i)
    pool.drain()

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])
    for i, s in enumerate(chain[8:]):            # steady state: dispatch
        pool.commit_async(s, data_cursor=8 + i)
    assert calls == [], "commit_async dispatch blocked on the host"
    pool.drain()                                 # resolution fetches
    assert len(calls) > 0


# -- PoolGroup waves ride the same ring ----------------------------------------


def test_group_waves_through_ring(mesh42):
    """A tenancy commit wave dispatched through `PoolGroup.commit_async`
    is one ticket whose verdict folds every tenant's device verdict and
    whose extras carry the per-tenant map; wave resolve latency lands in
    the group histogram with the wave's span exemplar."""
    from repro.tenancy import PoolGroup

    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=1, window=1,
                        block_words=64)
    grp = PoolGroup(mesh42, pipeline_depth=2)
    for tid in ("alice", "bob"):
        grp.admit(tid, jax.tree.map(lambda x: x + 0, state), specs,
                  config=cfg)

    tickets = []
    for k in range(1, 3):
        updates = {tid: jax.tree.map(
            lambda x: (x * (1 + 0.01 * k)).astype(x.dtype), state)
            for tid in ("alice", "bob")}
        tickets.append(grp.commit_async(updates))
    drained = grp.drain()
    assert drained == tickets
    for t in tickets:
        assert t.result() is True                # AND over the wave
        assert set(t.extras["verdicts"]) == {"alice", "bob"}
        assert all(bool(jax.device_get(v))
                   for v in t.extras["verdicts"].values())
    hist = grp.metrics.histogram("group_wave_resolve_ms")
    assert hist.count == 2
    assert any(e is not None for e in hist.exemplars)


# -- exemplars: resolve-latency histogram -> trace span linkage ----------------


def _load_trace_check():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "trace_check.py")
    spec = importlib.util.spec_from_file_location("trace_check", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_resolve_histogram_exemplars_link_to_trace(mesh42, tmp_path):
    """The p99 commit sample must carry its dispatch trace span id into
    the Prometheus export (` # {span_id="N"} v` bucket suffixes), and
    scripts/trace_check.py --prom must validate every exemplar against
    the trace — and flag a dangling one."""
    trace = str(tmp_path / "pool.jsonl")
    state, specs, _ = small_state(mesh42)
    cfg = ProtectConfig(mode="mlpc", redundancy=1, window=1,
                        block_words=64, pipeline_depth=2)
    tracer = Tracer(trace)
    pool = Pool.open(state, specs, mesh=mesh42, config=cfg, donate=False,
                     tracer=tracer)
    for i, s in enumerate(_chain(state, 4)):
        pool.commit_async(s, data_cursor=i)
    pool.drain()
    tracer.close()

    text = prometheus_text(pool.metrics)
    ex_lines = [ln for ln in text.splitlines()
                if "pool_commit_resolve_ms_bucket" in ln
                and '# {span_id="' in ln]
    assert ex_lines, "no exemplar suffix on any resolve bucket"

    tc = _load_trace_check()
    prom = tmp_path / "pool.prom"
    prom.write_text(text)
    assert tc.check_exemplars(str(prom), [trace]) == []
    assert tc.main([trace, "--prom", str(prom)]) == 0

    # a dangling exemplar (span id absent from the trace) must FAIL
    bad = tmp_path / "bad.prom"
    bad.write_text(ex_lines[0].replace('span_id="', 'span_id="99'))
    assert tc.check_exemplars(str(bad), [trace]) != []
    assert tc.main([trace, "--prom", str(bad)]) == 1
    # and a .prom with no exemplars at all is a linkage violation
    empty = tmp_path / "empty.prom"
    empty.write_text("pool_commits_total 4\n")
    assert tc.check_exemplars(str(empty), [trace]) != []
