"""Zone layout: pytree <-> word-row flattening must be bit-exact and the
page math (columns, slots) must match the paper's 2-D zone semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import layout as layout_mod


def mixed_tree(seed=0, leaves=3):
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16, jnp.int8, jnp.uint32, jnp.float16]
    tree = {}
    for i in range(leaves):
        dt = dtypes[i % len(dtypes)]
        shape = tuple(rng.integers(1, 7, size=rng.integers(1, 4)))
        n = int(np.prod(shape))
        raw = rng.integers(0, 256, size=n * jnp.dtype(dt).itemsize,
                           dtype=np.uint8)
        x = jax.lax.bitcast_convert_type(
            jnp.asarray(raw).reshape(n, jnp.dtype(dt).itemsize), dt
        ).reshape(shape) if jnp.dtype(dt).itemsize > 1 else \
            jnp.asarray(raw[:n].view(np.dtype(jnp.dtype(dt).name)),
                        dtype=dt).reshape(shape)
        tree[f"leaf{i}"] = x
    return tree


@given(st.integers(0, 50), st.integers(1, 6), st.sampled_from([1, 2, 4]))
@settings(max_examples=25, deadline=None)
def test_flatten_unflatten_roundtrip(seed, n_leaves, group):
    tree = mixed_tree(seed, n_leaves)
    lo = layout_mod.build_layout(tree, group, block_words=16)
    row = layout_mod.flatten_row(lo, tree)
    assert row.dtype == jnp.uint32
    assert row.shape[0] == lo.row_words
    assert lo.row_words % (group * 16) == 0
    back = layout_mod.unflatten_row(lo, row)
    for k in tree:
        a, b = np.asarray(tree[k]), np.asarray(back[k])
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()


def test_layout_slot_offsets_contiguous():
    tree = {"a": jnp.zeros((3, 5), jnp.float32),
            "b": jnp.zeros((7,), jnp.bfloat16)}
    lo = layout_mod.build_layout(tree, 2, block_words=8)
    offs = [s.offset for s in lo.slots]
    assert offs[0] == 0
    assert offs[1] == lo.slots[0].n_words
    assert lo.payload_words == sum(s.n_words for s in lo.slots)


def test_leaf_and_range_pages():
    tree = {"a": jnp.zeros((16,), jnp.uint32),     # words 0..15
            "b": jnp.zeros((16,), jnp.uint32)}     # words 16..31
    lo = layout_mod.build_layout(tree, 1, block_words=8)
    np.testing.assert_array_equal(layout_mod.leaf_pages(lo, 0), [0, 1])
    np.testing.assert_array_equal(layout_mod.leaf_pages(lo, 1), [2, 3])
    np.testing.assert_array_equal(layout_mod.range_pages(lo, 6, 4), [0, 1])
    np.testing.assert_array_equal(layout_mod.range_pages(lo, 8, 8), [1])


def test_overhead_report_fractions():
    tree = {"a": jnp.zeros((1024 * 16,), jnp.float32)}
    for g in (2, 4, 16):
        lo = layout_mod.build_layout(tree, g, block_words=1024)
        rep = lo.overhead_report()
        # parity is 1/G of the (padded) row
        assert rep["parity_bytes_per_rank"] * g == lo.row_words * 4
        assert rep["parity_fraction"] == pytest.approx(1.0 / g, rel=0.05)
        assert rep["replication_fraction"] == 1.0
        # checksums are tiny: 8 bytes per 4 KB page
        assert rep["checksum_fraction"] < 0.01


def test_layout_with_shardings(mesh42):
    """Local (sharded) shapes, not global shapes, define the row."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jax.ShapeDtypeStruct((8, 64), jnp.float32)}
    sh = {"w": NamedSharding(mesh42, P("data", "model"))}
    lo = layout_mod.build_layout(tree, 4, sh, block_words=16)
    # local shard: (2, 32) = 64 words
    assert lo.slots[0].shape == (2, 32)
    assert lo.slots[0].n_words == 64
