"""Transactional protection ladder (paper Table 2 modes) over a real mesh:
commit / abort / scrub / rank-loss recovery / scribble repair, plus the
hybrid parity paths' equivalence (patch == bulk for the same update)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import layout as layout_mod
from repro.core.txn import Mode, Protector
from tests.conftest import small_state

MODES = [Mode.MLPC, Mode.MLP, Mode.ML, Mode.NONE, Mode.REPLICA]


def make_protector(mesh, state, specs, mode, **kw):
    kw.setdefault("block_words", 64)
    return Protector(mesh, jax.eval_shape(lambda: state), specs, mode=mode,
                     **kw)


@pytest.fixture(scope="module")
def setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    return mesh42, state, specs, shardings


@pytest.mark.parametrize("mode", MODES)
def test_init_commit_abort(setup, mode):
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, mode)
    prot = p.init(state)
    assert int(prot.step) == 0
    assert (prot.parity is not None) == mode.has_parity
    assert (prot.cksums is not None) == mode.has_cksums
    assert (prot.replica is not None) == mode.has_replica
    assert (prot.log is not None) == mode.has_log

    commit = jax.jit(p.make_commit())
    new_state = jax.tree.map(lambda x: (x * 1.5 + 1).astype(x.dtype), state)
    prot2, ok = commit(prot, new_state, rng_key=jax.random.PRNGKey(1))
    assert bool(ok)
    assert int(prot2.step) == 1
    np.testing.assert_array_equal(np.asarray(prot2.state["w1"]),
                                  np.asarray(new_state["w1"]))
    if mode.has_replica:
        np.testing.assert_array_equal(np.asarray(prot2.replica["w1"]),
                                      np.asarray(new_state["w1"]))

    # canary abort: nothing moves, step does not advance
    prot3, ok3 = commit(prot2, jax.tree.map(jnp.zeros_like, new_state),
                        canary_ok=False)
    assert not bool(ok3)
    assert int(prot3.step) == 1
    np.testing.assert_array_equal(np.asarray(prot3.state["w1"]),
                                  np.asarray(prot2.state["w1"]))
    if mode.has_parity:
        np.testing.assert_array_equal(np.asarray(prot3.parity),
                                      np.asarray(prot2.parity))


@pytest.mark.parametrize("mode", [Mode.MLPC, Mode.MLP])
def test_rank_loss_recovery_bit_exact(setup, mode):
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, mode)
    prot = p.init(state)
    w1 = np.asarray(prot.state["w1"]).copy()
    w2_bits = np.asarray(prot.state["w2"]).view(np.uint16).copy()

    for lost in range(mesh.shape["data"]):
        garbled = w1.copy()
        rows_per = w1.shape[0] // mesh.shape["data"]
        garbled[lost * rows_per:(lost + 1) * rows_per] = np.nan
        bad = dict(prot.state)
        bad["w1"] = jax.device_put(garbled, shardings["w1"])
        prot_bad = dataclasses.replace(prot, state=bad)
        prot_rec, ok = p.recover_rank(prot_bad, lost)
        if mode.has_cksums:
            assert bool(ok), f"verification after recovering rank {lost}"
        np.testing.assert_array_equal(np.asarray(prot_rec.state["w1"]), w1)
        np.testing.assert_array_equal(
            np.asarray(prot_rec.state["w2"]).view(np.uint16), w2_bits)


def test_recovery_is_idempotent(setup):
    """Re-running recovery after it succeeded must be a no-op (paper §3.6)."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    prot_rec, ok = p.recover_rank(prot, 1)
    assert bool(ok)
    prot_rec2, ok2 = p.recover_rank(prot_rec, 1)
    assert bool(ok2)
    np.testing.assert_array_equal(np.asarray(prot_rec2.state["w1"]),
                                  np.asarray(prot.state["w1"]))


def test_scrub_detects_and_repair_fixes_scribble(setup):
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    w1 = np.asarray(prot.state["w1"]).copy()

    scr = w1.copy()
    scr[2, 3] = -1234.5            # data-rank 1 holds rows 2:4
    bad = dict(prot.state)
    bad["w1"] = jax.device_put(scr, shardings["w1"])
    prot_bad = dataclasses.replace(prot, state=bad)

    rep = p.scrub(prot_bad)
    badmask = np.asarray(rep["bad_pages"])
    assert badmask.any(), "scrub must detect the scribble"
    assert not np.asarray(rep["synd_ok"]).all(), \
        "XOR invariant must be broken"

    locs = [(int(i[0]), int(i[-1])) for i in np.argwhere(badmask)]
    prot_fix, okf = p.repair_pages(prot_bad, [r for r, _ in locs],
                                   [pg for _, pg in locs])
    assert bool(okf)
    np.testing.assert_array_equal(np.asarray(prot_fix.state["w1"]), w1)
    # pool is clean again
    rep2 = p.scrub(prot_fix)
    assert not np.asarray(rep2["bad_pages"]).any()
    assert np.asarray(rep2["synd_ok"]).all()


def test_multi_page_scribble_repair(setup):
    """Two scribbles in DIFFERENT page columns are repairable; the paper's
    guarantee covers one lost page per column (§3.1)."""
    from repro.runtime import failure
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    w1 = np.asarray(prot.state["w1"]).copy()

    # rank 1's flat row: pages 0 and 1 (distinct page columns)
    prot_bad, event = failure.inject_scribble(p, prot, rank=1,
                                              word_offsets=[5, 70])
    rep = p.scrub(prot_bad)
    locs = [(int(i[0]), int(i[-1]))
            for i in np.argwhere(np.asarray(rep["bad_pages"]))]
    assert len(set(pg for _, pg in locs)) >= 2, locs
    prot_fix, okf = p.repair_pages(prot_bad, [r for r, _ in locs],
                                   [pg for _, pg in locs])
    assert bool(okf)
    np.testing.assert_array_equal(np.asarray(prot_fix.state["w1"]), w1)


def test_same_column_double_fault_is_unrecoverable(setup):
    """Two corruptions in the SAME page column defeat single parity — the
    paper's documented limit (§3.1).  Verification must report failure
    rather than silently accepting wrong data."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    scr = np.asarray(prot.state["w1"]).copy()
    scr[0, 5] = 1e30      # rank 0, page column 0
    scr[4, 5] = -1e30     # rank 2, same page column
    bad = dict(prot.state)
    bad["w1"] = jax.device_put(scr, shardings["w1"])
    prot_bad = dataclasses.replace(prot, state=bad)
    rep = p.scrub(prot_bad)
    locs = [(int(i[0]), int(i[-1]))
            for i in np.argwhere(np.asarray(rep["bad_pages"]))]
    cols = [pg for _, pg in locs]
    assert len(cols) != len(set(cols)), "setup: same column twice"
    _, okf = p.repair_pages(prot_bad, [r for r, _ in locs], cols)
    assert not bool(okf), "repair must report failure, not fake success"


def test_verify_old_aborts_on_corrupt_input(setup):
    """The paper verifies an object's checksum when the micro-buffer opens;
    committing on top of corrupt state must abort, not launder corruption."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    scr = np.asarray(prot.state["w1"]).copy()
    scr[1, 1] = 777.0
    bad = dict(prot.state)
    bad["w1"] = jax.device_put(scr, shardings["w1"])
    prot_bad = dataclasses.replace(prot, state=bad)
    commit = jax.jit(p.make_commit(verify_old=True))
    new_state = jax.tree.map(lambda x: (x + 1).astype(x.dtype),
                             prot_bad.state)
    prot2, ok = commit(prot_bad, new_state, rng_key=jax.random.PRNGKey(0))
    assert not bool(ok)
    assert int(prot2.step) == 0


def test_patch_path_equals_bulk_path(setup):
    """Incremental parity (dirty pages only) must land exactly where a full
    rebuild lands — the hybrid scheme's two sides agree (paper §3.5)."""
    mesh, state, specs, shardings = setup
    abstract = jax.eval_shape(lambda: state)
    p_patch = Protector(mesh, abstract, specs, mode=Mode.MLPC,
                        block_words=64, hybrid_threshold=1.1)  # force patch
    p_bulk = Protector(mesh, abstract, specs, mode=Mode.MLPC,
                       block_words=64, hybrid_threshold=0.0)   # force bulk
    prot_a = p_patch.init(state)
    prot_b = p_bulk.init(state)
    np.testing.assert_array_equal(np.asarray(prot_a.parity),
                                  np.asarray(prot_b.parity))

    # modify only leaf "w1" -> dirty pages are w1's page columns.
    # (dict leaves flatten alphabetically: scale=0, w1=1, w2=2)
    new_state = dict(state)
    new_state["w1"] = state["w1"] * 2 + 1
    lo = p_patch.layout
    dirty = layout_mod.leaf_pages(lo, 1).tolist()

    commit_patch = jax.jit(p_patch.make_commit(dirty_pages=dirty))
    commit_bulk = jax.jit(p_bulk.make_commit())
    prot_a2, ok_a = commit_patch(prot_a, new_state,
                                 rng_key=jax.random.PRNGKey(2))
    prot_b2, ok_b = commit_bulk(prot_b, new_state,
                                rng_key=jax.random.PRNGKey(2))
    assert bool(ok_a) and bool(ok_b)
    np.testing.assert_array_equal(np.asarray(prot_a2.parity),
                                  np.asarray(prot_b2.parity))
    np.testing.assert_array_equal(np.asarray(prot_a2.cksums),
                                  np.asarray(prot_b2.cksums))
    # and recovery still works from the patched parity
    prot_rec, ok = p_patch.recover_rank(prot_a2, 3)
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(prot_rec.state["w1"]),
                                  np.asarray(new_state["w1"]))


def test_row_cache_tracks_state(setup):
    """ProtectedState.row must stay bit-identical to flatten(state) across
    init -> commit -> abort -> recovery (the single-sweep engine trusts it
    as the old operand)."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)

    def row_of(pr):
        """Reference row: rebuilt from the live state by a fresh init."""
        return np.asarray(p.init(pr.state).row)

    np.testing.assert_array_equal(np.asarray(prot.row), row_of(prot))
    commit = jax.jit(p.make_commit())
    new_state = jax.tree.map(lambda x: (x * 2 + 1).astype(x.dtype), state)
    prot2, ok = commit(prot, new_state, rng_key=jax.random.PRNGKey(0))
    assert bool(ok)
    np.testing.assert_array_equal(np.asarray(prot2.row), row_of(prot2))
    # abort: the cache must stay on the old row
    prot3, ok3 = commit(prot2, jax.tree.map(jnp.zeros_like, state),
                        canary_ok=False)
    assert not bool(ok3)
    np.testing.assert_array_equal(np.asarray(prot3.row),
                                  np.asarray(prot2.row))
    # recovery rebuilds (never trusts) the cache
    prot4, ok4 = p.recover_rank(prot2, 2)
    assert bool(ok4)
    np.testing.assert_array_equal(np.asarray(prot4.row), row_of(prot4))


def test_commit_cache_keys_distinct_dirty_sets(setup):
    """Protector.commit must compile one program per (dirty set, verify)
    — the old cache keyed on _dirty_key but always built the bulk commit,
    so a metadata-only commit would wrongly re-sweep everything (and a
    dirty-page commit would wrongly share the empty-set program)."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    prot = p.init(state)
    lo = p.layout
    dirty = layout_mod.leaf_pages(lo, 1).tolist()       # w1's pages

    new_state = dict(state)
    new_state["w1"] = state["w1"] * 2 + 1
    prot_a, ok_a = p.commit(prot, new_state, dirty_pages=dirty,
                            rng_key=jax.random.PRNGKey(1))
    assert bool(ok_a)
    # metadata-only commit: same state back, zero dirty pages => parity,
    # checksums and digest unchanged
    prot_b, ok_b = p.commit(prot_a, prot_a.state, dirty_pages=[],
                            rng_key=jax.random.PRNGKey(2))
    assert bool(ok_b)
    np.testing.assert_array_equal(np.asarray(prot_b.parity),
                                  np.asarray(prot_a.parity))
    np.testing.assert_array_equal(np.asarray(prot_b.cksums),
                                  np.asarray(prot_a.cksums))
    np.testing.assert_array_equal(np.asarray(prot_b.digest),
                                  np.asarray(prot_a.digest))
    # the dirty-page commit really updated protection (distinct program)
    assert not np.array_equal(np.asarray(prot_a.parity),
                              np.asarray(prot.parity))
    keys = [k for k in p._jit_cache if k[0] == "commit"]
    assert len(keys) == 2, keys
    # and the patched protection still recovers a lost rank bit-exactly
    prot_rec, okr = p.recover_rank(prot_b, 1)
    assert bool(okr)
    np.testing.assert_array_equal(np.asarray(prot_rec.state["w1"]),
                                  np.asarray(new_state["w1"]))


def test_verify_old_patch_path_aborts_on_corrupt_dirty_page(setup):
    """The patch path verifies the pages being opened: committing on top
    of a corrupted dirty page must abort."""
    mesh, state, specs, shardings = setup
    p = make_protector(mesh, state, specs, Mode.MLPC,
                       hybrid_threshold=1.1)              # force patch
    prot = p.init(state)
    lo = p.layout
    dirty = layout_mod.leaf_pages(lo, 1).tolist()
    scr = np.asarray(prot.state["w1"]).copy()
    scr[1, 1] = 777.0                                     # inside w1's pages
    bad = dict(prot.state)
    bad["w1"] = jax.device_put(scr, shardings["w1"])
    prot_bad = dataclasses.replace(prot, state=bad)
    new_state = dict(prot_bad.state)
    new_state["w1"] = prot_bad.state["w1"] + 1
    prot2, ok = p.commit(prot_bad, new_state, dirty_pages=dirty,
                         verify_old=True, rng_key=jax.random.PRNGKey(3))
    assert not bool(ok)
    assert int(prot2.step) == 0


def test_mlp_digest_matches_full_recompute_on_patch(setup):
    """MLP (no stored checksums) keeps its row digest incrementally on the
    patch path; it must equal the bulk path's digest bit-for-bit."""
    mesh, state, specs, shardings = setup
    p_patch = make_protector(mesh, state, specs, Mode.MLP,
                             hybrid_threshold=1.1)
    p_bulk = make_protector(mesh, state, specs, Mode.MLP,
                            hybrid_threshold=0.0)
    prot_a = p_patch.init(state)
    prot_b = p_bulk.init(state)
    lo = p_patch.layout
    dirty = layout_mod.leaf_pages(lo, 1).tolist()
    new_state = dict(state)
    new_state["w1"] = state["w1"] * 3 - 2
    prot_a2, ok_a = p_patch.commit(prot_a, new_state, dirty_pages=dirty,
                                   rng_key=jax.random.PRNGKey(4))
    prot_b2, ok_b = p_bulk.commit(prot_b, new_state,
                                  rng_key=jax.random.PRNGKey(4))
    assert bool(ok_a) and bool(ok_b)
    np.testing.assert_array_equal(np.asarray(prot_a2.digest),
                                  np.asarray(prot_b2.digest))
    np.testing.assert_array_equal(np.asarray(prot_a2.parity),
                                  np.asarray(prot_b2.parity))


def test_protection_overhead_report(setup):
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    rep = p.overhead_report()
    assert rep["mode"] == "mlpc"
    assert rep["group_size"] == mesh.shape["data"]
    # parity = 1/G of the padded row
    assert rep["protection_fraction"] < 1.0 / mesh.shape["data"] + 0.35
    rep_r = make_protector(mesh, state, specs, Mode.REPLICA).overhead_report()
    assert rep_r["protection_fraction"] == 1.0


def test_abstract_protected_matches_real(setup):
    """Dry-run stand-ins must mirror the real protected state's structure."""
    mesh, state, specs, _ = setup
    p = make_protector(mesh, state, specs, Mode.MLPC)
    abstract = p.abstract_protected(jax.eval_shape(lambda: state))
    real = p.init(state)
    ab_leaves = jax.tree.leaves(abstract)
    re_leaves = jax.tree.leaves(real)
    assert len(ab_leaves) == len(re_leaves)
    for a, r in zip(ab_leaves, re_leaves):
        assert tuple(a.shape) == tuple(r.shape), (a.shape, r.shape)
        assert jnp.dtype(a.dtype) == jnp.dtype(r.dtype)


def test_multipod_mesh_commit_and_recover(mesh_pod):
    """The zone axis generalizes to a 3-axis mesh (pod replication above it)."""
    from jax.sharding import NamedSharding
    specs = {"w": P("data", "model")}
    state = {"w": jnp.arange(4 * 32, dtype=jnp.float32).reshape(4, 32)}
    sh = jax.tree.map(lambda s: NamedSharding(mesh_pod, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    state = jax.tree.map(jax.device_put, state, sh)
    p = Protector(mesh_pod, jax.eval_shape(lambda: state), specs,
                  mode=Mode.MLPC, block_words=16)
    prot = p.init(state)
    commit = jax.jit(p.make_commit())
    new_state = {"w": state["w"] * 2}
    prot2, ok = commit(prot, new_state, rng_key=jax.random.PRNGKey(0))
    assert bool(ok)
    prot_rec, okr = p.recover_rank(prot2, 1)
    assert bool(okr)
    np.testing.assert_array_equal(np.asarray(prot_rec.state["w"]),
                                  np.asarray(new_state["w"]))
