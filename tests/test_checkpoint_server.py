"""Backstop-tier checkpointing (digest-verified, atomic) and the protected
serving path (decode with incremental cache protection)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ProtectConfig
from repro.runtime.server import Server


# -- checkpoint ---------------------------------------------------------------

def make_state():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save(7, state, extra={"cursor": 3}, blocking=True)
    assert mgr.list_steps() == [7]
    step, restored, extra = mgr.restore_latest()
    assert step == 7
    np.testing.assert_array_equal(
        np.asarray(restored["['params']['w']"]
                   if isinstance(restored, dict) and
                   "['params']['w']" in restored
                   else jax.tree.leaves(restored)[0]),
        np.arange(12, dtype=np.float32).reshape(3, 4))
    assert extra["cursor"] == 3


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_state())
    mgr.wait()
    assert mgr.list_steps() == [3, 4]


def test_checkpoint_digest_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(), blocking=True)
    # corrupt the payload region of the arrays file (flip bytes in the
    # second half, past the zip local headers, to hit array data)
    path = os.path.join(str(tmp_path), "step_1", "arrays.npz")
    data = bytearray(open(path, "rb").read())
    for frac in (0.45, 0.5, 0.55, 0.6):
        data[int(len(data) * frac)] ^= 0xFF
    open(path, "wb").write(bytes(data))
    with pytest.raises(Exception):
        mgr.restore(1)


def test_checkpoint_restore_with_specs(tmp_path, mesh42):
    specs = {"w": P("data", None)}
    state = {"w": jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)}
    st = jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh42, sp)),
        state, specs)
    mgr = CheckpointManager(str(tmp_path), mesh=mesh42, state_specs=specs)
    mgr.save(5, st, blocking=True)
    restored, _ = mgr.restore(5)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert restored["w"].sharding.spec == P("data", None)


# -- server ---------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(mesh42):
    cfg = ModelConfig(
        name="t_srv", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv=2, d_ff=64, vocab=128, param_dtype="float32",
        compute_dtype="float32")
    from repro.models.transformer import build_model
    model = build_model(cfg, mesh42)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("protect", ["mlpc", "none"])
def test_server_generates(served, mesh42, protect):
    cfg, params = served
    srv = Server(cfg, ProtectConfig(mode=protect, block_words=64), mesh42,
                 batch=4, max_len=32)
    srv.start(params)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 5), 0, cfg.vocab)
    out = srv.generate(prompt, n_new=4)
    assert out.shape == (4, 4)
    assert out.min() >= 0 and out.max() < cfg.vocab


def test_server_protected_matches_unprotected(served, mesh42):
    """Cache protection must not change decode results (bit-identical path)."""
    cfg, params = served
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 6), 0, cfg.vocab)
    outs = {}
    for mode in ("mlpc", "none"):
        srv = Server(cfg, ProtectConfig(mode=mode, block_words=64), mesh42,
                     batch=4, max_len=32)
        srv.start(params)
        outs[mode] = srv.generate(prompt, n_new=5)
    np.testing.assert_array_equal(outs["mlpc"], outs["none"])


def test_server_cache_scribble_recovery(served, mesh42):
    """Corrupt the live KV cache mid-generation; scrub+repair; decoding
    continues and matches the uncorrupted run."""
    import dataclasses as dc
    from repro.runtime import failure
    cfg, params = served
    prompt = jax.random.randint(jax.random.PRNGKey(3), (4, 6), 0, cfg.vocab)

    srv_ref = Server(cfg, ProtectConfig(mode="mlpc", block_words=64), mesh42,
                     batch=4, max_len=32)
    srv_ref.start(params)
    ref = srv_ref.generate(prompt, n_new=6)

    srv = Server(cfg, ProtectConfig(mode="mlpc", block_words=64), mesh42,
                 batch=4, max_len=32)
    srv.start(params)
    tok = srv.prefill(prompt)
    # corrupt rank 0's cache shard, silently
    bad_prot, event = failure.inject_scribble(srv.protector, srv.prot,
                                              rank=0, word_offsets=[11])
    srv.prot = bad_prot
    # scrub-and-repair (the server's periodic scrub path)
    from repro.core.scrub import Scrubber
    scrubber = Scrubber(srv.protector, period=1)
    srv.prot, report = scrubber.run(srv.prot)
    assert report.bad_locations and report.repair_ok
    out = [np.asarray(jax.device_get(tok))]
    for _ in range(5):
        tok = srv.step(tok)
        out.append(np.asarray(jax.device_get(tok)))
    got = np.stack(out, axis=1)
    np.testing.assert_array_equal(got, ref)
