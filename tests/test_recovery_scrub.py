"""Failure injection -> detection -> online recovery (paper §3.6, §4.6),
plus the scrubbing policy (§3.3) and the redo log (§3.4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import microbuffer, recovery, redolog
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector
from repro.runtime import failure
from tests.conftest import small_state


@pytest.fixture(scope="module")
def prot_setup(mesh42):
    state, specs, shardings = small_state(mesh42)
    p = Protector(mesh42, jax.eval_shape(lambda: state), specs,
                  mode=Mode.MLPC, block_words=64)
    return p, p.init(state), shardings


def test_inject_rank_loss_and_recover(prot_setup):
    p, prot, _ = prot_setup
    w1 = np.asarray(prot.state["w1"]).copy()
    bad_prot, event = failure.inject_rank_loss(p, prot, rank=2)
    assert event.kind == "rank_loss" and event.lost_rank == 2
    # rank 2's rows actually corrupted
    assert not np.array_equal(np.asarray(bad_prot.state["w1"]), w1)
    rec_prot, report = recovery.recover_from_rank_loss(p, bad_prot, 2)
    assert report.verified
    np.testing.assert_array_equal(np.asarray(rec_prot.state["w1"]), w1)


def test_inject_scribble_detect_by_scrub_then_repair(prot_setup):
    p, prot, _ = prot_setup
    w1 = np.asarray(prot.state["w1"]).copy()
    bad_prot, event = failure.inject_scribble(p, prot, rank=1,
                                              word_offsets=[5, 6, 130])
    assert event.kind == "scribble"
    # silent: state differs but nothing raised yet
    assert not np.array_equal(np.asarray(bad_prot.state["w1"]), w1)

    scrubber = Scrubber(p, period=3)
    assert not scrubber.due()
    for _ in range(3):
        scrubber.on_commit()
    assert scrubber.due()
    fixed_prot, report = scrubber.run(bad_prot)
    assert report.checked
    assert report.bad_locations, "scrub must find the scribble"
    assert report.repaired and report.repair_ok
    np.testing.assert_array_equal(np.asarray(fixed_prot.state["w1"]), w1)


def test_scrub_clean_pool_reports_nothing(prot_setup):
    p, prot, _ = prot_setup
    scrubber = Scrubber(p, period=1)
    out_prot, report = scrubber.run(prot)
    assert report.checked and not report.bad_locations
    assert report.parity_ok
    assert not report.repaired


def test_recovery_requires_parity(mesh42):
    state, specs, _ = small_state(mesh42)
    p = Protector(mesh42, jax.eval_shape(lambda: state), specs, mode=Mode.ML,
                  block_words=64)
    prot = p.init(state)
    with pytest.raises(RuntimeError, match="parity"):
        recovery.recover_from_rank_loss(p, prot, 0)
    with pytest.raises(RuntimeError, match="parity"):
        recovery.recover_from_scribble(p, prot, [(0, 0)])


def test_freeze_resume_hooks_called(prot_setup):
    p, prot, _ = prot_setup
    calls = []
    recovery.recover_from_rank_loss(
        p, prot, 0, freeze=lambda: calls.append("freeze"),
        resume=lambda: calls.append("resume"))
    assert calls == ["freeze", "resume"]


# -- canary / micro-buffer ----------------------------------------------------

def test_canary_intact_and_smashed():
    buf = microbuffer.guard(jnp.zeros((256,), jnp.uint32))
    assert bool(microbuffer.check(buf))
    smashed = failure.smashed_canary_buffer(256)
    assert not bool(microbuffer.check(smashed))


def test_canary_nd():
    x = jnp.zeros((4, 8), jnp.uint32)
    g = microbuffer.guard_nd(x)
    assert bool(microbuffer.check_nd(g))
    assert microbuffer.interior_nd(g).shape == x.shape
    g2 = g.at[-1, 0].set(jnp.uint32(1))
    assert not bool(microbuffer.check_nd(g2))
    with pytest.raises(TypeError):
        microbuffer.guard_nd(jnp.zeros((2, 2), jnp.float32))


def test_split_roundtrip():
    row = jnp.arange(64, dtype=jnp.uint32)
    g = microbuffer.guard(row)
    payload, canary = microbuffer.split(g)
    np.testing.assert_array_equal(np.asarray(payload), np.asarray(row))
    assert np.all(np.asarray(canary) == 0xDEADBEEF)


# -- redo log -----------------------------------------------------------------

def test_redolog_append_mark_lookup():
    log = redolog.make(8)
    key = jax.random.PRNGKey(7)
    dig = jnp.asarray([3, 4], jnp.uint32)
    log = redolog.append(log, 5, 100, key, dig)
    rec = redolog.lookup(log, 5)
    assert int(rec["step"]) == 5
    assert int(rec["data_cursor"]) == 100
    assert int(rec["mark"]) == 0          # not yet committed
    log = redolog.commit_mark(log, 5)
    rec = redolog.lookup(log, 5)
    assert int(rec["mark"]) == 1
    np.testing.assert_array_equal(np.asarray(rec["digest"]), [3, 4])


def test_redolog_ring_wraparound():
    log = redolog.make(4)
    key = jax.random.PRNGKey(0)
    for s in range(1, 7):
        log = redolog.append(log, s, s * 10, key,
                             jnp.zeros((2,), jnp.uint32))
        log = redolog.commit_mark(log, s)
    # capacity 4: steps 3..6 survive, 1-2 overwritten
    assert int(redolog.lookup(log, 6)["step"]) == 6
    assert int(redolog.lookup(log, 2)["step"]) == 6   # slot reused


def test_replayable_steps_contiguity():
    log = redolog.make(8)
    key = jax.random.PRNGKey(0)
    for s in (4, 5, 7):   # gap at 6
        log = redolog.append(log, s, s, key, jnp.zeros((2,), jnp.uint32))
        log = redolog.commit_mark(log, s)
    log = redolog.append(log, 6, 6, key, jnp.zeros((2,), jnp.uint32))
    # 6 appended but never marked -> replay stops before it
    assert redolog.replayable_steps(log, 3) == [4, 5]
    assert redolog.replayable_steps(log, 4) == [5]
    assert redolog.replayable_steps(log, 7) == []
