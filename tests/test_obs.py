"""The telemetry plane (repro/obs): registry semantics, span-trace
linkage through live pools on both engines, HealthReport transitions,
the Prometheus golden, and the zero-compiled-byte invariant.

Everything in repro.obs must stay jax-free (the commit path publishes
into it on every transaction); the final test pins that an instrumented
pool compiles the exact program a bare engine compiles.
"""
import numpy as np
import pytest

from repro import obs
from repro.configs.base import ProtectConfig
from repro.obs.export import prometheus_text, write_metrics
from repro.obs.health import CRITICAL, DEGRADED, GREEN, assess
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_buckets)
from repro.obs.trace import Tracer, load_jsonl, validate_events
from repro.pool import Fault, Pool
from tests.conftest import small_state


# -- registry / histogram semantics -------------------------------------------


def test_obs_is_jax_free():
    import sys
    import importlib
    for name in ("repro.obs", "repro.obs.metrics", "repro.obs.trace",
                 "repro.obs.health", "repro.obs.export"):
        mod = importlib.import_module(name)
        src = open(mod.__file__).read()
        assert "import jax" not in src, f"{name} imports jax"
    assert "repro.obs.metrics" in sys.modules


def test_histogram_percentile_tracks_numpy():
    """Bucket-interpolated percentiles within one bucket width (~15%,
    the default 8-per-decade spacing) of numpy's exact answer."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=1.0, sigma=1.2, size=4000)
    h = Histogram()
    for s in samples:
        h.observe(float(s))
    for q in (50, 90, 99):
        exact = float(np.percentile(samples, q))
        est = h.percentile(q)
        assert abs(est - exact) / exact < 0.16, (q, est, exact)
    assert h.count == len(samples)
    assert h.min == pytest.approx(samples.min())
    assert h.max == pytest.approx(samples.max())
    assert h.mean == pytest.approx(samples.mean())


def test_histogram_tight_distribution_clamps_to_extrema():
    h = Histogram()
    for _ in range(10):
        h.observe(7.5)
    # every sample identical: percentiles must not smear across the
    # bucket — the observed-extrema clamp pins them exactly
    assert h.percentile(50) == 7.5
    assert h.percentile(99) == 7.5
    s = h.summary()
    assert s["n"] == 10 and s["min"] == s["max"] == 7.5


def test_histogram_empty_returns_none():
    h = Histogram()
    assert h.percentile(50) is None
    assert h.summary()["p50"] is None and h.summary()["n"] == 0


def test_default_buckets_span_and_spacing():
    edges = default_buckets()
    assert edges[0] == pytest.approx(1e-3)
    assert edges[-1] == pytest.approx(1e5)
    ratios = [b / a for a, b in zip(edges, edges[1:])]
    assert all(r == pytest.approx(10 ** 0.125) for r in ratios)


def test_registry_label_children_and_idempotence():
    reg = MetricsRegistry()
    full = reg.counter("scrub_runs_total", kind="full")
    pre = reg.counter("scrub_runs_total", kind="precheck")
    full.inc(3)
    pre.inc()
    assert full is not pre
    assert reg.counter("scrub_runs_total", kind="full") is full
    snap = reg.snapshot()
    assert snap["scrub_runs_total"] == {"kind=full": 3.0,
                                        "kind=precheck": 1.0}
    with pytest.raises(AssertionError):
        reg.gauge("scrub_runs_total", kind="full")   # type collision
    with pytest.raises(AssertionError):
        full.inc(-1)                                 # monotone


# -- Prometheus exposition -----------------------------------------------------


def test_prometheus_text_golden():
    reg = MetricsRegistry()
    reg.counter("pool_commits_total").inc(42)
    reg.gauge("pool_window", engine="deferred").set(4)
    h = reg.histogram("wall_ms", buckets=[1.0, 10.0], kind="full")
    for v in (0.5, 2.0, 3.0, 99.0):
        h.observe(v)
    assert prometheus_text(reg) == (
        "# TYPE pool_commits_total counter\n"
        "pool_commits_total 42\n"
        "# TYPE pool_window gauge\n"
        'pool_window{engine="deferred"} 4\n'
        "# TYPE wall_ms histogram\n"
        'wall_ms_bucket{kind="full",le="1"} 1\n'
        'wall_ms_bucket{kind="full",le="10"} 3\n'
        'wall_ms_bucket{kind="full",le="+Inf"} 4\n'
        'wall_ms_sum{kind="full"} 104.5\n'
        'wall_ms_count{kind="full"} 4\n')


def test_write_metrics_files(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    paths = write_metrics(reg, str(tmp_path), prefix="pool",
                          stats={"mode": "mlpc"})
    assert open(paths["prom"]).read().endswith("c 1\n")
    import json
    assert json.load(open(paths["stats"]))["mode"] == "mlpc"


# -- tracer / validation -------------------------------------------------------


def test_tracer_span_linkage_and_jsonl(tmp_path):
    tr = Tracer(str(tmp_path / "t.jsonl"))
    fid = tr.emit("fault", fault_kind="rank_loss", lost_rank=2)
    with tr.span("recovery", faults=[fid]) as sp:
        sp.annotate(verified=True)
    assert validate_events(tr.events) == []
    tr.close()
    disk = load_jsonl(str(tmp_path / "t.jsonl"))
    assert disk == tr.events
    assert disk[1]["faults"] == [fid] and disk[2]["verified"] is True
    assert [e["ev"] for e in disk] == ["point", "begin", "end"]


def test_validate_events_catches_violations():
    tr = Tracer()
    tr.emit("fault")                      # id 0, never linked
    tr.begin("recovery", faults=[7])      # orphan link + dangling span
    bad = validate_events(tr.events)
    assert any("never linked" in b for b in bad)
    assert any("never ended" in b for b in bad)
    assert any("orphan" in b for b in bad)


def test_span_exception_records_error():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("recovery", faults=[]):
            raise ValueError("boom")
    assert tr.events[-1]["error"] == "ValueError"
    assert validate_events(tr.events) == []


# -- live pools: trace linkage on both engines x stack heights ----------------


@pytest.mark.parametrize("window,red", [(1, 1), (1, 3), (4, 1), (4, 3)])
def test_pool_trace_links_fault_to_recovery(mesh42, window, red):
    import jax
    from repro.runtime import failure
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", redundancy=red,
                                          window=window, block_words=64),
                     donate=False)
    cur = state
    for i in range(3):
        cur = jax.tree.map(lambda x: (x * 1.01).astype(x.dtype), cur)
        pool.commit(cur, rng_key=jax.random.PRNGKey(i))
    ev = pool.inject(lambda p, prot: failure.seeded_rank_loss(
        p, prot, seed=0, rank=1))
    rep = pool.recover(Fault.from_event(ev))
    assert rep.verified and rep.reverified
    assert rep.solve_ms >= 0 and rep.total_ms >= rep.solve_ms
    events = pool.tracer.events
    assert validate_events(events) == []
    faults = [e for e in events if e.get("kind") == "fault"]
    spans = [e for e in events
             if e["ev"] == "begin" and e["kind"] == "recovery"]
    assert len(faults) == 1 and len(spans) == 1
    assert spans[0]["faults"] == [faults[0]["id"]]
    end = [e for e in events
           if e["ev"] == "end" and e["id"] == spans[0]["id"]][0]
    assert end["recovery_kind"] == "rank_loss" and end["verified"]
    st = pool.stats()
    assert st["commits"] == 3 and st["recoveries"] == 1
    assert st["commit_dispatch_ms"]["n"] == 3
    assert st["metrics"]["pool_recoveries_total"]["kind=rank_loss"] == 1


# -- scrub coverage accounting (the satellite fix) ----------------------------


def test_scrub_coverage_exact_across_precheck_only_cycles(mesh42):
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", window=1,
                                          block_words=64),
                     donate=False)
    sc = pool.scrubber
    pages = sc.pool_pages
    assert pages > 0
    pool.precheck()
    pool.precheck()
    pool.scrub()
    cov = sc.coverage()
    # exact accounting: 2 prechecks (digest pass over every page) + 1
    # full scrub (syndrome verify over every page)
    assert cov["prechecks"] == 2 and cov["full_scrubs"] == 1
    assert cov["pages_checked"] == 3 * pages
    assert cov["pages_syndrome_verified"] == pages
    assert cov["full_fraction"] == pytest.approx(1 / 3)
    assert pool.stats()["scrub"] == cov
    assert pool.health().status == GREEN


# -- HealthReport transitions --------------------------------------------------


def _base_signals(**over):
    kw = dict(window=4, max_window=4, dropped_replicas=[], suspect=False,
              redundancy=2, budget_exhausted=False, scrub_coverage=None,
              unrepaired_pages=0, reverify_failed=False, recoveries=0,
              recovery_followups=0)
    kw.update(over)
    return kw


def test_assess_transitions_pure():
    assert assess(**_base_signals()).status == GREEN
    r = assess(**_base_signals(dropped_replicas=[2]))
    assert r.status == DEGRADED and "straggler" in r.reasons[0]
    assert assess(**_base_signals(window=1)).status == DEGRADED
    assert assess(**_base_signals(suspect=True)).status == DEGRADED
    r = assess(**_base_signals(budget_exhausted=True))
    assert r.status == CRITICAL and r.budget_remaining == 0
    assert assess(**_base_signals(reverify_failed=True)).status == CRITICAL
    assert assess(**_base_signals(unrepaired_pages=3)).status == CRITICAL
    # critical outranks degraded when both fire
    r = assess(**_base_signals(dropped_replicas=[1],
                               budget_exhausted=True))
    assert r.status == CRITICAL and len(r.reasons) == 2
    assert r.to_dict()["status"] == CRITICAL


def test_pool_health_straggler_drop_and_heal(mesh42):
    import jax
    from repro.dist.straggler import StragglerPolicy
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", window=4,
                                          block_words=64),
                     donate=False,
                     straggler_policy=StragglerPolicy(4, threshold=2.0,
                                                      window=2))
    assert pool.health().status == GREEN
    slow = [0.01, 0.06, 0.01, 0.01]
    for _ in range(2):
        pool.commit(state, rng_key=jax.random.PRNGKey(0))
        pool.observe_commit_times(slow)
    rep = pool.health()
    assert rep.status == DEGRADED
    assert rep.dropped_replicas == [1]
    assert any("straggler" in r for r in rep.reasons)
    assert pool.stats()["metrics"]["pool_straggler_drop_total"][""] == 1
    # heal: normal observations push the slow samples out of the window
    for _ in range(2):
        pool.observe_commit_times([0.01] * 4)
    assert pool.health().dropped_replicas == []
    assert pool.stats()["metrics"]["pool_straggler_heal_total"][""] == 1


def test_pool_health_budget_exhaust_and_rearm(mesh42):
    import jax
    from repro.runtime import failure
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", redundancy=1,
                                          window=1, block_words=64),
                     donate=False)
    ev = pool.inject(lambda p, prot: failure.seeded_multi_rank_loss(
        p, prot, seed=0, e=2))
    with pytest.raises(RuntimeError, match="syndrome budget exhausted"):
        pool.recover(Fault.from_event(ev))
    rep = pool.health()
    assert rep.status == CRITICAL and rep.budget_exhausted
    assert rep.budget_remaining == 0
    assert any("budget" in r for r in rep.reasons)
    # the raise happened inside the recovery span: trace stays valid and
    # the fault ids are still linked (begin carries them)
    assert validate_events(pool.tracer.events) == []
    assert pool.tracer.events[-1]["error"] == "RuntimeError"
    # re-arm (checkpoint-tier restore path): fresh protection clears it
    pool.init(state)
    assert pool.health().status == GREEN
    assert pool.stats()["metrics"]["pool_budget_exhausted_total"][""] == 1


def test_pool_recovery_then_clean_scrub_heals_suspicion(mesh42):
    import jax
    from repro.runtime import failure
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", window=1,
                                          block_words=64),
                     donate=False)
    ev = pool.inject(lambda p, prot: failure.seeded_rank_loss(
        p, prot, seed=0, rank=2))
    pool.recover(Fault.from_event(ev))
    rep = pool.health()
    assert rep.status == DEGRADED and rep.suspect
    report = pool.scrub()
    assert report.checked and not report.suspect
    assert pool.health().status == GREEN


# -- the zero-compiled-byte invariant -----------------------------------------


def test_instrumented_pool_compiles_identical_bytes(mesh42):
    """A wired registry/tracer must not change the commit program: the
    facade-routed program and the bare protector's compile to the same
    XLA bytes accessed (the benchmark gates this for both engines; the
    sync engine's check is cheap enough to pin in tier-1)."""
    import jax
    state, specs, _ = small_state(mesh42)
    pool = Pool.open(state, specs, mesh=mesh42,
                     config=ProtectConfig(mode="mlpc", window=1,
                                          block_words=64),
                     donate=False)
    new_state = jax.tree.map(lambda x: (x * 1.01).astype(x.dtype), state)
    key = jax.random.PRNGKey(0)

    def bytes_of(fn):
        cost = fn.lower(pool.prot, new_state,
                        rng_key=key).compile().cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        return float(cost.get("bytes accessed", 0.0))

    instr = bytes_of(pool.commit_program())
    bare = bytes_of(jax.jit(pool.protector.make_commit()))
    assert instr == bare


# -- public surface ------------------------------------------------------------


def test_obs_reexports():
    assert obs.MetricsRegistry is MetricsRegistry
    assert obs.Tracer is Tracer
    assert obs.validate_events is validate_events
    assert {obs.GREEN, obs.DEGRADED, obs.CRITICAL} == {
        "green", "degraded", "critical"}
    import repro
    assert repro.MetricsRegistry is MetricsRegistry
    assert repro.HealthReport is obs.HealthReport
