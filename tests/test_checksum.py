"""Fletcher-64 checksum properties (the paper's Adler32 substitute, §3.5).

The two properties Pangolin exploits must hold exactly:
  1. combine rule — per-block checksums fold into the whole-row digest;
  2. incremental update — cost ∝ modified range, result == full recompute.
Plus the detection class: any 1-2 word corruption inside a block flips the
block's checksum.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import checksum as ck

U32 = jnp.uint32


def rand_row(n_words, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 2**32, size=n_words, dtype=np.uint32))


@pytest.mark.parametrize("n_blocks,bw", [(1, 64), (4, 64), (8, 128),
                                         (16, 1024), (3, 256)])
def test_block_checksums_shape(n_blocks, bw):
    row = rand_row(n_blocks * bw, seed=n_blocks)
    c = ck.block_checksums(row, bw)
    assert c.shape == (n_blocks, 2) and c.dtype == U32


@given(st.integers(1, 16), st.sampled_from([32, 64, 128]), st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_combine_rule(n_blocks, bw, seed):
    """combine(per-block) == digest of the whole row computed in one block."""
    row = rand_row(n_blocks * bw, seed)
    per_block = ck.block_checksums(row, bw)
    combined = ck.combine(per_block, bw)
    whole = ck.block_checksums(row, n_blocks * bw)[0]
    np.testing.assert_array_equal(np.asarray(combined), np.asarray(whole))


@given(st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_digest_equals_combine(seed):
    row = rand_row(8 * 64, seed)
    np.testing.assert_array_equal(
        np.asarray(ck.digest(row, 64)),
        np.asarray(ck.combine(ck.block_checksums(row, 64), 64)))


@given(st.integers(1, 8), st.integers(0, 99), st.data())
@settings(max_examples=30, deadline=None)
def test_incremental_update_blocks(n_dirty, seed, data):
    """update_blocks on dirty pages == full recompute."""
    n_blocks, bw = 8, 64
    rng = np.random.default_rng(seed)
    old = rand_row(n_blocks * bw, seed)
    cks = ck.block_checksums(old, bw)
    dirty = sorted(data.draw(st.sets(st.integers(0, n_blocks - 1),
                                     min_size=1, max_size=n_dirty)))
    new = np.asarray(old).copy()
    for b in dirty:
        new[b * bw:(b + 1) * bw] = rng.integers(0, 2**32, size=bw,
                                                dtype=np.uint32)
    new = jnp.asarray(new)
    idx = jnp.asarray(dirty, jnp.int32)
    pages = new.reshape(-1, bw)[idx]
    inc = ck.update_blocks(cks, pages, idx, bw)
    full = ck.block_checksums(new, bw)
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(full))


@given(st.integers(0, 63), st.integers(1, 32), st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_update_range_within_block(start, length, seed):
    """Word-granular range update == recompute (the Adler32 range property)."""
    bw = 128
    length = min(length, bw - start)
    rng = np.random.default_rng(seed)
    old = rand_row(bw, seed)
    cks = ck.block_checksums(old, bw)[0]
    new = np.asarray(old).copy()
    new[start:start + length] = rng.integers(0, 2**32, size=length,
                                             dtype=np.uint32)
    new = jnp.asarray(new)
    inc = ck.update_range(cks, old[start:start + length],
                          new[start:start + length], start, bw)
    full = ck.block_checksums(new, bw)[0]
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(full))


@given(st.integers(0, 7), st.integers(0, 63), st.integers(1, 32),
       st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_detects_any_word_flip(block, word, bitpos, seed):
    """Flipping any bits of any word flips the block's checksum (A changes)."""
    n_blocks, bw = 8, 64
    row = rand_row(n_blocks * bw, seed)
    cks = ck.block_checksums(row, bw)
    bad = np.asarray(row).copy()
    bad[block * bw + word] ^= np.uint32(1 << (bitpos % 32))
    badmask = ck.verify_blocks(jnp.asarray(bad), cks, bw)
    assert bool(badmask[block])
    # only that block flagged
    others = np.asarray(badmask).copy()
    others[block] = False
    assert not others.any()


def test_detects_two_word_swap():
    """Fletcher's positional term catches reordering (plain sum would not)."""
    bw = 64
    row = rand_row(bw, 7)
    arr = np.asarray(row).copy()
    if arr[3] == arr[10]:
        arr[10] += 1
    arr[3], arr[10] = arr[10], arr[3]
    cks = ck.block_checksums(row, bw)
    bad = ck.verify_blocks(jnp.asarray(arr), cks, bw)
    assert bool(bad[0])


def test_verify_clean():
    row = rand_row(4 * 64, 3)
    cks = ck.block_checksums(row, 64)
    assert not np.asarray(ck.verify_blocks(row, cks, 64)).any()
