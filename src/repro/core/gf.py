"""GF(2^32) arithmetic for the generalized Reed-Solomon syndrome stack.

Pangolin's zone holds a single XOR parity row, so a zone tolerates exactly
one concurrent failure (§3.1).  The syndrome stack extends the scheme to
any r <= 4 simultaneous rank losses, Reed-Solomon style, while staying
linear over XOR — so every piece of the existing parity machinery (delta
telescoping, patch scatters, deferred-epoch batching) applies verbatim:

    S_k = g^(k·0)·row_0 ^ g^(k·1)·row_1 ^ ... ^ g^(k·(G-1))·row_{G-1}

for k = 0..r-1, with multiplication in GF(2^32) over the word lanes
(S_0 is classic XOR parity P, S_1 the former Q).  Losing e <= r ranks
a_0 < ... < a_{e-1} leaves the e x e Vandermonde system

    S_k ^ s_k = XOR_j g^(k·a_j) · X_j          k = 0..e-1

(s_k = survivor syndromes, X_j = the lost rows) whose matrix
V[k][j] = g^(k·a_j) is Vandermonde in the distinct nonzero points g^a_j,
hence invertible for any distinct ranks because g is a *primitive*
element — so the solve below always succeeds for any e <= r <= G-1.

Field choice: the word size IS the lane width (u32), so parity words and
Q words are the same shape and every XOR kernel is reusable.  The reduction
polynomial is the degree-32 primitive pentanomial

    x^32 + x^22 + x^2 + x + 1          (POLY = 0x400007)

(the classic maximal-length LFSR tap set 32/22/2/1), with generator
g = x = 2.  Primitivity (verified: ord(g) = 2^32 - 1 against all prime
factors 3·5·17·257·65537) guarantees distinct nonzero g^i for every rank
index that could ever appear.

Two implementation layers:

  * host integers (`*_int`) — exact Python arithmetic for the scalar
    constants (rank coefficients, Vandermonde inverses) that jitted code
    consumes as compile-time literals;
  * jnp (`xtime` / `mul_const` / `mul_pow_g`) — element-wise carry-less
    multiply over u32 buffers, usable inside shard_map and as the oracle
    the Pallas kernels (kernels/gf_parity.py) are tested against.
    `mul_const` is the 32-step shift-and-conditional-XOR clmul, branch-free
    so it vectorizes on the VPU and accepts a *traced* scalar coefficient
    (the per-rank g^i looked up by axis_index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
MASK = (1 << 32) - 1
# x^32 + x^22 + x^2 + x + 1 — primitive over GF(2), generator g = x = 2.
POLY = 0x400007
ORDER = (1 << 32) - 1           # multiplicative group order (g is primitive)


# ---------------------------------------------------------------------------
# host-side exact arithmetic (scalar constants for jitted consumers)
# ---------------------------------------------------------------------------

def xtime_int(x: int) -> int:
    """Multiply by g (carry-less doubling) on a host integer."""
    x &= MASK
    return ((x << 1) & MASK) ^ (POLY if x >> 31 else 0)


def mul_int(a: int, b: int) -> int:
    """Full GF(2^32) product of two host integers (shift-and-add clmul)."""
    a &= MASK
    b &= MASK
    acc = 0
    while b:
        if b & 1:
            acc ^= a
        a = xtime_int(a)
        b >>= 1
    return acc


def pow_int(a: int, e: int) -> int:
    """a^e by square-and-multiply (e reduced mod the group order)."""
    if a == 0:
        return 0
    e %= ORDER
    r = 1
    while e:
        if e & 1:
            r = mul_int(r, a)
        a = mul_int(a, a)
        e >>= 1
    return r


def inv_int(a: int) -> int:
    """Multiplicative inverse a^(2^32 - 2); a must be nonzero."""
    if a & MASK == 0:
        raise ZeroDivisionError("GF(2^32) inverse of 0")
    return pow_int(a, ORDER - 1)


@functools.lru_cache(maxsize=None)
def pow_g_int(k: int) -> int:
    """g^k as a host integer (rank coefficient)."""
    r = 1
    for _ in range(k % ORDER if k >= ORDER else k):
        r = xtime_int(r)
    return r


@functools.lru_cache(maxsize=None)
def pow_g_table(g: int) -> tuple:
    """(g^0, ..., g^{G-1}) — per-rank S_1 coefficients for a zone of size G."""
    out, cur = [], 1
    for _ in range(g):
        out.append(cur)
        cur = xtime_int(cur)
    return tuple(out)


def pow_g_array(g: int) -> np.ndarray:
    """`pow_g_table` as a u32 ndarray (device lookup by axis_index)."""
    return np.asarray(pow_g_table(g), np.uint32)


@functools.lru_cache(maxsize=None)
def syndrome_table(g: int, r: int) -> tuple:
    """Per-rank syndrome coefficients for a zone of size G, r syndromes.

    Entry [i][k] = g^(k·i): rank i's weight in syndrome S_k.  Column 0 is
    all-ones (S_0 = XOR parity); column 1 is `pow_g_table` (the former Q).
    """
    return tuple(tuple(pow_g_int(k * i) for k in range(r))
                 for i in range(g))


def syndrome_array(g: int, r: int) -> np.ndarray:
    """`syndrome_table` as a (G, r) u32 ndarray (axis_index lookup)."""
    return np.asarray(syndrome_table(g, r), np.uint32)


def solve_two_int(p: int, q: int, rank_a: int, rank_b: int) -> tuple:
    """Host oracle for the 2x2 Vandermonde solve (tests)."""
    return tuple(solve_e_int((p, q), (rank_a, rank_b)))


# ---------------------------------------------------------------------------
# general e x e Vandermonde solve (host-exact constants)
# ---------------------------------------------------------------------------

def vandermonde_int(lost_ranks) -> tuple:
    """V[k][j] = g^(k·a_j) for the erased ranks a_j (rows = syndromes)."""
    ranks = tuple(int(a) for a in lost_ranks)
    e = len(ranks)
    return tuple(tuple(pow_g_int(k * a) for a in ranks) for k in range(e))


@functools.lru_cache(maxsize=None)
def inv_vandermonde_int(lost_ranks: tuple) -> tuple:
    """Exact inverse of the erasure Vandermonde matrix, host integers.

    Gauss-Jordan over GF(2^32): addition is XOR, so elimination is
    row_i ^= factor · row_pivot with exact `mul_int`/`inv_int`.  The
    matrix is Vandermonde in distinct nonzero points g^a_j (g primitive,
    a_j distinct), so a nonzero pivot always exists and the inverse is
    exact — no numerics anywhere.
    """
    ranks = tuple(int(a) for a in lost_ranks)
    assert len(set(ranks)) == len(ranks), (
        f"erased ranks must be distinct, got {ranks}")
    e = len(ranks)
    m = [list(row) + [1 if i == k else 0 for i in range(e)]
         for k, row in enumerate(vandermonde_int(ranks))]
    for col in range(e):
        piv = next(i for i in range(col, e) if m[i][col])
        m[col], m[piv] = m[piv], m[col]
        scale = inv_int(m[col][col])
        m[col] = [mul_int(scale, v) for v in m[col]]
        for i in range(e):
            if i != col and m[i][col]:
                f = m[i][col]
                m[i] = [v ^ mul_int(f, w) for v, w in zip(m[i], m[col])]
    return tuple(tuple(row[e:]) for row in m)


def solve_e_int(deficits, lost_ranks) -> list:
    """Host oracle for the general solve: scalar syndromes -> lost words."""
    inv = inv_vandermonde_int(tuple(int(a) for a in lost_ranks))
    return [functools.reduce(
        lambda acc, kv: acc ^ mul_int(kv[1], deficits[kv[0]]),
        enumerate(row), 0) for row in inv]


# ---------------------------------------------------------------------------
# jnp element-wise arithmetic (shard_map-safe; Pallas oracle)
# ---------------------------------------------------------------------------

def xtime(x: jax.Array) -> jax.Array:
    """Element-wise multiply by g: (x << 1) ^ ((x >> 31) * POLY)."""
    assert x.dtype == U32, x.dtype
    return (x << U32(1)) ^ ((x >> U32(31)) * U32(POLY))


def mul_const(x: jax.Array, coeff) -> jax.Array:
    """Element-wise GF(2^32) multiply of a u32 buffer by one coefficient.

    `coeff` may be a Python int or a traced u32 scalar (e.g. the rank's
    g^i gathered from `pow_g_array` by `lax.axis_index`).  Branch-free
    32-step clmul: step i XORs in x·g^i masked by coefficient bit i —
    pure VPU ops, bit-identical to the host `mul_int` per lane.
    """
    assert x.dtype == U32, x.dtype
    coeff = jnp.asarray(coeff, U32)
    acc = jnp.zeros_like(x)
    cur = x
    for i in range(32):
        bit = (coeff >> U32(i)) & U32(1)
        acc = acc ^ (bit * cur)
        cur = xtime(cur)
    return acc


def mul_pow_g(x: jax.Array, k: int) -> jax.Array:
    """Element-wise multiply by g^k for a *static* k (rank index).

    Small k unrolls as k doublings (cheaper than the full clmul); large k
    falls back to `mul_const` with the host-computed coefficient.
    """
    k = int(k)
    assert k >= 0, k
    if k >= 32:
        return mul_const(x, pow_g_int(k))
    for _ in range(k):
        x = xtime(x)
    return x


def rank_syndrome_coeffs(group_size: int, r: int,
                         axis_name: str) -> jax.Array:
    """This rank's syndrome coefficient vector (g^(k·me))_{k<r}.

    One (G, r) table lookup by `lax.axis_index` — the single place the
    coefficient scheme lives, shared by the commit engines, the epoch
    flush, and the syndrome collective.  Entry 0 is always 1 (S_0 is
    plain XOR parity); consumers statically skip the k=0 multiply.
    """
    from jax import lax
    table = jnp.asarray(syndrome_array(group_size, r))
    return table[lax.axis_index(axis_name)]


def solve_e(deficits: jax.Array, lost_ranks) -> tuple:
    """Solve the e-erasure Vandermonde system element-wise.

    `deficits` is the (e, n) stack of syndrome deficits
    S_k ^ s_k = XOR_j g^(k·a_j)·X_j for the erased ranks a_j (static,
    distinct ints).  The inverse matrix constants are exact host
    integers folded into the program, so the device does e constant
    multiplies and e-1 XORs per word per lost row.  Returns the e lost
    rows' segments (X_0, ..., X_{e-1}) in `lost_ranks` order.
    """
    ranks = tuple(int(a) for a in lost_ranks)
    e = len(ranks)
    assert deficits.shape[0] == e, (deficits.shape, ranks)
    inv = inv_vandermonde_int(ranks)
    out = []
    for row in inv:
        acc = None
        for k, c in enumerate(row):
            term = mul_const(deficits[k], c) if c != 1 else deficits[k]
            acc = term if acc is None else acc ^ term
        out.append(acc)
    return tuple(out)


def solve_two(p: jax.Array, q: jax.Array, rank_a: int, rank_b: int) -> tuple:
    """The e=2 specialization of `solve_e` (P+Q double-loss solve)."""
    rank_a, rank_b = int(rank_a), int(rank_b)
    assert rank_a != rank_b, "double-loss solve needs two distinct ranks"
    return solve_e(jnp.stack([p, q]), (rank_a, rank_b))
