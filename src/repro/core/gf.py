"""GF(2^32) arithmetic for the dual-parity (P+Q) erasure code.

Pangolin's zone holds a single XOR parity row, so a zone tolerates exactly
one concurrent failure (§3.1).  The second syndrome Q extends the scheme to
any TWO simultaneous rank losses, Reed-Solomon style, while staying linear
over XOR — so every piece of the existing parity machinery (delta
telescoping, patch scatters, deferred-epoch batching) applies verbatim:

    P = row_0 ^ row_1 ^ ... ^ row_{G-1}
    Q = g^0·row_0 ^ g^1·row_1 ^ ... ^ g^{G-1}·row_{G-1}

with multiplication in GF(2^32) over the word lanes.  Losing ranks a < b
leaves the 2x2 Vandermonde system

    P ^ S_p = A ^ B              S_p, S_q = survivor syndromes
    Q ^ S_q = g^a·A ^ g^b·B      A, B    = the lost rows

whose determinant g^a ^ g^b is nonzero for a != b because g is a
*primitive* element — so the solve below always succeeds.

Field choice: the word size IS the lane width (u32), so parity words and
Q words are the same shape and every XOR kernel is reusable.  The reduction
polynomial is the degree-32 primitive pentanomial

    x^32 + x^22 + x^2 + x + 1          (POLY = 0x400007)

(the classic maximal-length LFSR tap set 32/22/2/1), with generator
g = x = 2.  Primitivity (verified: ord(g) = 2^32 - 1 against all prime
factors 3·5·17·257·65537) guarantees distinct nonzero g^i for every rank
index that could ever appear.

Two implementation layers:

  * host integers (`*_int`) — exact Python arithmetic for the scalar
    constants (rank coefficients, Vandermonde inverses) that jitted code
    consumes as compile-time literals;
  * jnp (`xtime` / `mul_const` / `mul_pow_g`) — element-wise carry-less
    multiply over u32 buffers, usable inside shard_map and as the oracle
    the Pallas kernels (kernels/gf_parity.py) are tested against.
    `mul_const` is the 32-step shift-and-conditional-XOR clmul, branch-free
    so it vectorizes on the VPU and accepts a *traced* scalar coefficient
    (the per-rank g^i looked up by axis_index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
MASK = (1 << 32) - 1
# x^32 + x^22 + x^2 + x + 1 — primitive over GF(2), generator g = x = 2.
POLY = 0x400007
ORDER = (1 << 32) - 1           # multiplicative group order (g is primitive)


# ---------------------------------------------------------------------------
# host-side exact arithmetic (scalar constants for jitted consumers)
# ---------------------------------------------------------------------------

def xtime_int(x: int) -> int:
    """Multiply by g (carry-less doubling) on a host integer."""
    x &= MASK
    return ((x << 1) & MASK) ^ (POLY if x >> 31 else 0)


def mul_int(a: int, b: int) -> int:
    """Full GF(2^32) product of two host integers (shift-and-add clmul)."""
    a &= MASK
    b &= MASK
    acc = 0
    while b:
        if b & 1:
            acc ^= a
        a = xtime_int(a)
        b >>= 1
    return acc


def pow_int(a: int, e: int) -> int:
    """a^e by square-and-multiply (e reduced mod the group order)."""
    if a == 0:
        return 0
    e %= ORDER
    r = 1
    while e:
        if e & 1:
            r = mul_int(r, a)
        a = mul_int(a, a)
        e >>= 1
    return r


def inv_int(a: int) -> int:
    """Multiplicative inverse a^(2^32 - 2); a must be nonzero."""
    if a & MASK == 0:
        raise ZeroDivisionError("GF(2^32) inverse of 0")
    return pow_int(a, ORDER - 1)


@functools.lru_cache(maxsize=None)
def pow_g_int(k: int) -> int:
    """g^k as a host integer (rank coefficient)."""
    r = 1
    for _ in range(k % ORDER if k >= ORDER else k):
        r = xtime_int(r)
    return r


@functools.lru_cache(maxsize=None)
def pow_g_table(g: int) -> tuple:
    """(g^0, ..., g^{G-1}) — per-rank Q coefficients for a zone of size G."""
    out, cur = [], 1
    for _ in range(g):
        out.append(cur)
        cur = xtime_int(cur)
    return tuple(out)


def pow_g_array(g: int) -> np.ndarray:
    """`pow_g_table` as a u32 ndarray (device lookup by axis_index)."""
    return np.asarray(pow_g_table(g), np.uint32)


def solve_two_int(p: int, q: int, rank_a: int, rank_b: int) -> tuple:
    """Host oracle for the 2x2 Vandermonde solve (tests)."""
    ga, gb = pow_g_int(rank_a), pow_g_int(rank_b)
    b = mul_int(q ^ mul_int(ga, p), inv_int(ga ^ gb))
    return p ^ b, b


# ---------------------------------------------------------------------------
# jnp element-wise arithmetic (shard_map-safe; Pallas oracle)
# ---------------------------------------------------------------------------

def xtime(x: jax.Array) -> jax.Array:
    """Element-wise multiply by g: (x << 1) ^ ((x >> 31) * POLY)."""
    assert x.dtype == U32, x.dtype
    return (x << U32(1)) ^ ((x >> U32(31)) * U32(POLY))


def mul_const(x: jax.Array, coeff) -> jax.Array:
    """Element-wise GF(2^32) multiply of a u32 buffer by one coefficient.

    `coeff` may be a Python int or a traced u32 scalar (e.g. the rank's
    g^i gathered from `pow_g_array` by `lax.axis_index`).  Branch-free
    32-step clmul: step i XORs in x·g^i masked by coefficient bit i —
    pure VPU ops, bit-identical to the host `mul_int` per lane.
    """
    assert x.dtype == U32, x.dtype
    coeff = jnp.asarray(coeff, U32)
    acc = jnp.zeros_like(x)
    cur = x
    for i in range(32):
        bit = (coeff >> U32(i)) & U32(1)
        acc = acc ^ (bit * cur)
        cur = xtime(cur)
    return acc


def mul_pow_g(x: jax.Array, k: int) -> jax.Array:
    """Element-wise multiply by g^k for a *static* k (rank index).

    Small k unrolls as k doublings (cheaper than the full clmul); large k
    falls back to `mul_const` with the host-computed coefficient.
    """
    k = int(k)
    assert k >= 0, k
    if k >= 32:
        return mul_const(x, pow_g_int(k))
    for _ in range(k):
        x = xtime(x)
    return x


def rank_coeff(group_size: int, axis_name: str) -> jax.Array:
    """This rank's Q Vandermonde coefficient g^me (shard_map-only).

    One table lookup by `lax.axis_index` — the single place the
    coefficient scheme lives, shared by the commit engines, the epoch
    flush, and the GF collective.
    """
    from jax import lax
    table = jnp.asarray(pow_g_array(group_size))
    return table[lax.axis_index(axis_name)]


def solve_two(p: jax.Array, q: jax.Array, rank_a: int, rank_b: int) -> tuple:
    """Solve the double-loss Vandermonde system element-wise.

    `p` = P ^ S_p (= A ^ B) and `q` = Q ^ S_q (= g^a·A ^ g^b·B) for lost
    ranks a != b (static ints).  The scalar constants — g^a and the
    determinant inverse — are exact host integers folded into the program,
    so the device does two constant multiplies and two XORs per word.
    Returns (A, B), the lost rows' segments.
    """
    rank_a, rank_b = int(rank_a), int(rank_b)
    assert rank_a != rank_b, "double-loss solve needs two distinct ranks"
    ga = pow_g_int(rank_a)
    det_inv = inv_int(ga ^ pow_g_int(rank_b))
    b = mul_const(q ^ mul_const(p, ga), det_inv)
    return p ^ b, b
