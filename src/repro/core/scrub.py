"""Periodic scrubbing (Pangolin §3.3).

The scrubber walks the whole pool's checksums every `period` transactions
(Fig. 6 of the paper) and hands any mismatches to recovery.  It freezes the
pool (the trainer stops committing) while repair runs — scrub-triggered
repair shares the recovery routine with failure-event-triggered repair.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import txn as txn_mod


@dataclasses.dataclass
class ScrubReport:
    step: int
    checked: bool
    bad_locations: list          # [(rank, page), ...]
    parity_ok: Optional[bool]
    repaired: bool
    repair_ok: Optional[bool]
    row_cache_ok: Optional[bool] = None   # cached row == flatten(state)
    # per-syndrome invariant verdicts, index k = S_k (entry 0 mirrors
    # parity_ok); None when the mode keeps no syndromes
    synd_ok: Optional[list] = None
    # True when this report came from the rank-local pre-check (folded
    # syndrome compare, no full-row collective) rather than a global scrub
    local_only: bool = False
    # checksum-mismatch block count from the pre-check's compact verdict
    # (the pre-check reduces bad blocks to a replicated scalar on device;
    # locations come from the escalated global scrub); None when the
    # report carries per-block locations instead
    bad_count: Optional[int] = None

    @property
    def suspect(self) -> bool:
        """Any signal that the pool (or its redundancy) is unhealthy."""
        return (bool(self.bad_locations) or bool(self.bad_count)
                or self.parity_ok is False
                or (self.synd_ok is not None and not all(self.synd_ok))
                or self.row_cache_ok is False)


class Scrubber:
    """Transaction-count-based scrubbing with online repair.

    `engine` (optional) is a DeferredProtector to feed scrub pressure
    back into: a suspect scrub collapses its window toward 1, a clean
    scrub lets it regrow (adaptive window sizing — redundancy lag never
    compounds while the pool looks unhealthy).  `growth_commits` (> 0)
    additionally regrows a shrunken window under sustained *clean-commit
    load*: every N consecutive clean commits doubles it back toward the
    ceiling, so a pool committing heavily between scrubs is not stuck at
    W=1 until the next scrub period lands.
    """

    def __init__(self, protector: txn_mod.Protector, period: int = 0,
                 auto_repair: bool = True, engine=None,
                 growth_commits: int = 0):
        self.protector = protector
        self.period = period          # 0 = disabled
        self.auto_repair = auto_repair
        self.engine = engine          # Optional[DeferredProtector]
        self.growth_commits = int(growth_commits)   # 0 = scrub-only growth
        self._since = 0
        self._clean_streak = 0
        # telemetry (repro.obs): the Pool assigns its registry here; all
        # publication is host-side counter math on values this class
        # already fetched, so a wired registry never adds device traffic
        self.metrics = None           # Optional[obs.MetricsRegistry]
        # coverage accounting — prechecks and full scrubs are distinct
        # verification events: BOTH check every rank's state blocks
        # against the checksum table (pool_pages = G x n_blocks pages
        # per pass), but only a FULL scrub verifies the syndrome stack
        # against the full rows; the pre-check's folded compare moves
        # O(r*G) words and is a compressed consistency signal, not
        # syndrome coverage.  Tracking both cumulative counters makes
        # the coverage fraction exact across precheck-only cycles
        # (previously local prechecks were indistinguishable from full
        # scrubs in any record).
        self.pool_pages = (protector.layout.n_blocks
                           * protector.group_size)
        self.n_prechecks = 0
        self.n_full_scrubs = 0
        self.pages_checked = 0            # checksum-verified (all kinds)
        self.pages_syndrome_verified = 0  # full-row syndrome coverage
        self.last_suspect: Optional[bool] = None
        # budgeted-scheduler hooks (repro.tenancy.scheduler): commit-age
        # counters a shared scheduler reads to rank tenants and bound
        # every tenant's full-scrub age.  `commits_since_check` resets on
        # ANY verification pass (precheck or full); `commits_since_full`
        # only on a full scrub — together with `pool_pages` (the page
        # cost of one pass over this pool) they are the whole interface.
        self.commits_since_check = 0
        self.commits_since_full = 0

    def coverage(self) -> dict:
        """Exact verification-coverage record (see __init__ notes)."""
        passes = self.n_prechecks + self.n_full_scrubs
        return {
            "pool_pages": self.pool_pages,
            "prechecks": self.n_prechecks,
            "full_scrubs": self.n_full_scrubs,
            "pages_checked": self.pages_checked,
            "pages_syndrome_verified": self.pages_syndrome_verified,
            # of all scrub passes, the fraction that carried full
            # syndrome coverage (precheck-only cycles dilute this —
            # exactly the staleness signal Vilamb says must be visible)
            "full_fraction": (self.n_full_scrubs / passes
                              if passes else None),
            # of all checksum page-checks, the fraction also covered by
            # a full-row syndrome verification
            "syndrome_coverage": (self.pages_syndrome_verified
                                  / self.pages_checked
                                  if self.pages_checked else None),
        }

    def _publish(self, kind: str, report, wall_ms: float) -> None:
        """Fold one scrub pass into the registry (no-op when unwired)."""
        self.last_suspect = report.suspect
        if self.metrics is None:
            return
        reg = self.metrics
        reg.counter("scrub_runs_total", kind=kind).inc()
        if report.suspect:
            reg.counter("scrub_suspect_total", kind=kind).inc()
        reg.histogram("scrub_wall_ms", kind=kind).observe(wall_ms)
        reg.counter("scrub_pages_verified_total",
                    kind=kind).inc(self.pool_pages)
        if report.bad_locations:
            reg.counter("scrub_bad_pages_total").inc(
                len(report.bad_locations))
        if report.bad_count:
            reg.counter("scrub_precheck_bad_blocks_total").inc(
                report.bad_count)
        if report.synd_ok is not None and not all(report.synd_ok):
            reg.counter("scrub_digest_mismatch_total").inc(
                sum(1 for v in report.synd_ok if not v))
        cov = self.coverage()
        if cov["full_fraction"] is not None:
            reg.gauge("scrub_coverage_full_fraction").set(
                cov["full_fraction"])

    def due(self) -> bool:
        if self.period <= 0:
            return False
        return self._since >= self.period

    def on_commit(self, clean: bool = True):
        """Count a commit toward the scrub cadence.  `clean` is the
        host-known verdict (the static canary / resolved commit ok): a
        dirty commit resets the clean streak; a long enough streak
        regrows the adaptive window under load."""
        self._since += 1
        self.commits_since_check += 1
        self.commits_since_full += 1
        if not clean:
            self._clean_streak = 0
            return
        self._clean_streak += 1
        # growth lands only at an epoch boundary (no open window):
        # stretching an already-open epoch would let redundancy lag past
        # the cadence it opened under (report_pressure's invariant).
        # The streak persists across a skipped boundary, so the first
        # post-flush commit after the threshold grows the window.
        if (self.engine is not None and self.growth_commits > 0
                and self._clean_streak >= self.growth_commits
                and self.engine.window < self.engine.max_window
                and not self.engine.needs_flush):
            self.engine.report_pressure(False)    # sustained clean load
            self._clean_streak = 0

    def note_suspect(self):
        """Reset the clean streak (a failure event was handled)."""
        self._clean_streak = 0

    def mark_checked(self):
        """Restart the scrub cadence: a check stood in for a full scrub
        (e.g. a clean rank-local pre-check on the pool's cadence)."""
        self._since = 0

    def _host_report(self, prot, out: dict, *, local: bool) -> tuple:
        """Fetch the scrub outputs in one device_get; build the report."""
        out = dict(out)
        out["step"] = prot.step
        host = jax.device_get(out)
        bad_locations = []
        if "bad_pages" in host:
            # (*mesh_dims, n_blocks) -> (G, n_blocks): a page is bad if
            # any non-data mesh coordinate flags it (vectorized union)
            bad = np.asarray(host["bad_pages"])
            data_pos = self.protector.axis_names.index(
                self.protector.data_axis)
            bad = np.moveaxis(bad, data_pos, 0)
            bad = bad.any(axis=tuple(range(1, bad.ndim - 1)))
            ranks, pages = np.nonzero(bad)
            bad_locations = list(zip(ranks.tolist(), pages.tolist()))
        synd_ok = ([bool(v) for v in np.asarray(host["synd_ok"])]
                   if "synd_ok" in host else None)
        parity_ok = synd_ok[0] if synd_ok else None
        row_cache_ok = (bool(host["row_cache_ok"])
                        if "row_cache_ok" in host else None)
        bad_count = (int(host["bad_count"])
                     if "bad_count" in host else None)
        return bad_locations, ScrubReport(
            int(host["step"]), True, bad_locations, parity_ok, False,
            None, row_cache_ok=row_cache_ok, synd_ok=synd_ok,
            local_only=local, bad_count=bad_count)

    def precheck(self, prot: txn_mod.ProtectedState) -> ScrubReport:
        """Rank-local scrub: the cheap pre-check before a global scrub.

        Verifies this rank's state blocks against the checksum table,
        the row cache against the live state, and this rank's syndrome
        segments against everyone's rows via the folded-syndrome compare
        (Protector.make_local_scrub) — zone traffic O(r·G) words instead
        of the r full-row reduce-scatters, with the GF weighting on
        device via the stacked-plane kernel.  Every output is a scalar
        verdict (bad_count / synd_ok / row_cache_ok), so the one
        device_get here moves a few words, not a per-block table.  No
        repair and no cadence
        reset: a suspect pre-check should escalate to `run`.  The
        adaptive window IS fed either way — a clean pre-check standing
        in for a scrub must regrow a shrunken window exactly like a
        clean global scrub would, or full_scrub_every=N would slow
        regrowth by N.
        """
        mode = self.protector.mode
        if not (mode.has_cksums or mode.has_parity):
            return ScrubReport(int(prot.step), False, [], None, False,
                               None, local_only=True)
        t0 = time.perf_counter()
        _, report = self._host_report(
            prot, self.protector.local_scrub(prot), local=True)
        self.n_prechecks += 1
        self.pages_checked += self.pool_pages
        self.commits_since_check = 0
        self._publish("precheck", report,
                      (time.perf_counter() - t0) * 1e3)
        if self.engine is not None:
            self.engine.report_pressure(report.suspect)
            if report.suspect:
                self._clean_streak = 0
        return report

    def run(self, prot: txn_mod.ProtectedState,
            freeze: Optional[Callable] = None,
            resume: Optional[Callable] = None):
        """Scrub (and repair) the pool.  Returns (prot, ScrubReport)."""
        self._since = 0
        mode = self.protector.mode
        if not (mode.has_cksums or mode.has_parity):
            return prot, ScrubReport(int(prot.step), False, [], None,
                                     False, None)
        if freeze is not None:
            freeze()
        t0 = time.perf_counter()
        # one transfer for every scrub output (plus the step counter) —
        # the old code issued a device_get per field and then walked
        # np.argwhere rows in Python
        bad_locations, report = self._host_report(
            prot, self.protector.scrub(prot), local=False)
        if bad_locations and self.auto_repair and mode.has_parity:
            ranks = [r for r, _ in bad_locations]
            pages = [p for _, p in bad_locations]
            prot, ok = self.protector.repair_pages(prot, ranks, pages)
            report.repaired = True
            report.repair_ok = bool(jax.device_get(ok))
            if self.metrics is not None:
                self.metrics.counter("scrub_repairs_total").inc()
                if not report.repair_ok:
                    self.metrics.counter(
                        "scrub_repair_failures_total").inc()
        wall_ms = (time.perf_counter() - t0) * 1e3
        self.n_full_scrubs += 1
        self.pages_checked += self.pool_pages
        self.commits_since_check = 0
        self.commits_since_full = 0
        if mode.has_parity:
            self.pages_syndrome_verified += self.pool_pages
        self._publish("full", report, wall_ms)
        if resume is not None:
            resume()
        if self.engine is not None:
            # adaptive window: errors shrink W toward 1, clean regrows it
            self.engine.report_pressure(report.suspect)
            if report.suspect:
                self._clean_streak = 0
        return prot, report
