"""Micro-buffering and canaries (Pangolin §3.2).

In the paper, a micro-buffer is a DRAM shadow copy of an NVMM object: the
application mutates the shadow, and commit propagates it.  JAX state is
already functional — `train_step`/`serve_step` *produce* the shadow copy —
so micro-buffering's isolation property holds by construction.  What does
not hold by construction is the paper's *canary*: a guard word that detects
buffer overruns before they are committed.  Custom (Pallas) kernels can
write out of bounds if a BlockSpec/grid is mis-specified, which is exactly
the "scribble before commit" failure the canary catches.

We therefore stage kernel outputs in guarded buffers: `guard()` appends a
canary page of a fixed pattern, kernels write the interior, and
`check(...)` verifies the canary at commit.  On mismatch the transaction
aborts without touching protected state (txn.commit selects the old state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CANARY_WORD = jnp.uint32(0xDEADBEEF)
CANARY_WORDS = 128  # one canary "page" of guard words


def guard(row: jax.Array) -> jax.Array:
    """Append a canary page to a 1-D uint32 buffer."""
    canary = jnp.full((CANARY_WORDS,), CANARY_WORD, jnp.uint32)
    return jnp.concatenate([row, canary])


def split(guarded: jax.Array) -> tuple[jax.Array, jax.Array]:
    return guarded[:-CANARY_WORDS], guarded[-CANARY_WORDS:]


def check(guarded: jax.Array) -> jax.Array:
    """True iff the canary is intact (no overrun into the guard page)."""
    _, canary = split(guarded)
    return jnp.all(canary == CANARY_WORD)


def guard_nd(x: jax.Array) -> jax.Array:
    """Guard an N-D staging buffer by appending a canary row on axis 0."""
    pad_shape = (1,) + tuple(x.shape[1:])
    canary = jnp.full(pad_shape, CANARY_WORD, jnp.uint32)
    if x.dtype != jnp.uint32:
        raise TypeError("guard_nd stages uint32 buffers")
    return jnp.concatenate([x, canary], axis=0)


def check_nd(guarded: jax.Array) -> jax.Array:
    return jnp.all(guarded[-1] == CANARY_WORD)


def interior_nd(guarded: jax.Array) -> jax.Array:
    return guarded[:-1]
