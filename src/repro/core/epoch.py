"""Deferred-epoch redundancy engine (beyond-paper: Vilamb-style batching).

Pangolin updates parity and checksums on every transaction.  Vilamb
(PAPERS.md) shows that for persistent-memory workloads most of that cost
can be deferred: redundancy is refreshed asynchronously over a *window* of
writes, and the redo log — which still persists per transaction — covers
the unprotected interval for crash replay.  This module is that scheme on
top of the zone layout:

  * In-window commit (`DeferredProtector.commit`), patch engine: the
    dirty-page set is unioned on-device and the redo record is appended
    + commit-marked.  Parity, the checksum table AND the cached row are
    NOT touched — the row stays pinned at the epoch-start value, which
    makes it the XOR accumulator for free (deltas telescope:
    d_1 ^ ... ^ d_W == row_start ^ row_now, so pinning the base *is*
    accumulating; an explicit delta buffer would pay a row-sized scatter
    per commit, and an eager row splice a row-sized select — measured,
    either one erases the deferral win).  The whole-row digest IS kept
    current from one sweep over the step's *modified words*, gathered
    straight from the old/new state leaves (the digest is linear in
    word position — see `checksum.update_digest_words`), so every log
    record carries a replay-verifiable digest bit-identical to the
    synchronous engine's at every step.  Per-step protection cost is
    therefore proportional to the words actually written — the paper's
    incremental ideal.
  * Bulk engine in-window commit: every step rewrites the whole row
    anyway (training), so the step runs `kernels.fused_accum_commit` —
    one sweep over (previous row, new row) folds the step's XOR delta
    into an explicit epoch accumulator (`EpochState.acc`, telescoping
    to row_start ^ row_now) and emits fresh Fletcher checksums + the
    combined row digest from the same pass.  The checksum table is
    therefore current at EVERY step, not only at boundaries; rows past
    the streaming threshold take the blockwise double-buffered
    `fused_accum_commit_stream`, which carries the digest in the loop.
  * Epoch flush (`flush`, automatic every `window` commits): the patch
    engine splices the current state into the cached row once and one
    fused sweep over both row versions on the unioned dirty pages
    yields the window's parity delta plus fresh checksums
    (`kernels.fused_commit_s`); parity consumes the delta
    (patch-scatter, or a bulk reduce-scatter past the hybrid
    threshold).  The bulk engine never re-reads the row at flush: the
    accumulator already IS the window's delta, so the flush weights it
    into the r syndrome planes (`kernels.syndrome_scale`, one stacked
    read) and folds them in with the chunked `apply_sdelta`
    reduce-scatter — S_k ^ rs(g^(k·me)·acc) equals a rebuild from the
    current row exactly, by GF/XOR linearity.  At every epoch boundary
    parity / cksums / digest / row are bit-identical to the synchronous
    engine's after the same commits.

Window-loss semantics: between flushes the parity and checksum table
describe the epoch-start state, and the cached row deliberately lags the
live state.  A crash loses no committed data (redo records persist per
step; replay from the last checkpoint reproduces the window
deterministically and verifies each step's digest), but *online* media
recovery and scrubbing need current redundancy — runtimes must `flush()`
before scrub/recovery.  The flush reads old values from the cached row
and new values from the live state leaves it splices, so corruption that
lands in an *unmodified* region mid-window is still detected by the
first post-flush scrub; corruption inside the window's own write
footprint is indistinguishable from the writes themselves until replay
verifies digests — deferral trades detection latency on exactly the
bytes the log already covers.  A full machine loss falls back to
checkpoint + redo-log replay, the Vilamb trade.  See EXPERIMENTS.md
§Perf.

Steady-state commits are allocation-free: the jitted step and flush
programs donate the previous protected state (digest, log, dirty mask,
state, and at flushes row/parity/cksums), so buffers are reused in place
instead of reallocated each step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import utils
from repro.core import checksum as ck
from repro.core import gf
from repro.core import layout as layout_mod
from repro.core import parity as parity_mod
from repro.core import redolog
from repro.core.txn import ProtectedState, Protector
from repro.dist import collectives as coll
from repro.kernels import ops as kops

PyTree = Any
U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EpochState:
    """A ProtectedState plus the deferred window's bookkeeping.

    Mid-window invariant (patch engine): `prot.row` holds the
    *epoch-start* row — the implicit XOR accumulator — while
    `prot.state` runs ahead of it; `flush` re-synchronizes.  `dirty` is
    the unioned dirty-page mask ((*mesh_dims, n_blocks) bool; None for
    the bulk engine, whose row tracks the state every step).  `pending`
    counts successful commits since the last flush (scalar u32,
    replicated — introspection; the engine's host counter drives the
    cadence).  `acc` (bulk engine only; None for patch) is the explicit
    XOR accumulator ((*mesh_dims, row_words) u32): after W accum steps
    it holds row_start ^ row_now, and the flush weights it straight
    into the syndrome stack without touching the row again.
    """
    prot: ProtectedState
    dirty: Optional[jax.Array]
    pending: jax.Array
    acc: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.prot, self.dirty, self.pending, self.acc), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class EngineHost:
    """Engine-or-sync protected-state plumbing shared by the runtimes.

    Hosts assign `_engine` (a DeferredProtector, or None for the
    synchronous cadence) and then track their protected state through
    the `prot` property.  The setter WRAPS the value into a fresh
    window, which discards in-window bookkeeping — legal only for
    states whose parity/cksums/row are current (right after
    Protector.init, a flush, or recovery).  `flush()` brings deferred
    redundancy current and is a no-op for the synchronous cadence.
    """
    _engine = None        # Optional[DeferredProtector]
    _est = None           # Optional[EpochState]   (engine cadence)
    _prot = None          # Optional[ProtectedState] (sync cadence)

    @property
    def prot(self) -> Optional[ProtectedState]:
        if self._engine is not None:
            return self._est.prot if self._est is not None else None
        return self._prot

    @prot.setter
    def prot(self, value):
        if self._engine is not None:
            self._est = (self._engine.wrap(value)
                         if value is not None else None)
        else:
            self._prot = value

    def flush(self) -> None:
        """Bring deferred redundancy current (no-op when synchronous)."""
        if self._engine is not None and self._est is not None:
            self._est = self._engine.flush_if_pending(self._est)


class DeferredProtector:
    """Windowed protection over a Protector's zone layout.

    Two flavors:

      * bulk (`dirty_leaf_idx=None`) — every commit dirties the whole
        row (training).  Per-step: flatten + digest sweep; flush:
        parity rebuild + full checksum refresh.
      * patch (`dirty_leaf_idx` = static leaf list) — commits touch a
        known leaf subset (decode).  Per-step commits take
        `dirty_words`, a tuple aligned with `dirty_leaf_idx` of per-leaf
        *word-index* arrays (or None = whole leaf), e.g. from
        `layout.time_slice_words`: position-independent shapes, so one
        compiled program serves every decode position.  `dirty_capacity`
        bounds the pages one step may touch; the flush footprint is
        bounded by window * capacity (past the hybrid threshold the
        flush goes bulk).

    `window` commits trigger an automatic flush; `donate=True` donates
    the old state into its successor for allocation-free steady state —
    callers must then drop the old EpochState and keep only the returned
    one.
    """

    def __init__(self, protector: Protector, *, window: int = 16,
                 dirty_capacity: Optional[int] = None,
                 dirty_leaf_idx: Optional[Sequence[int]] = None,
                 donate: bool = True, replicate_meta: bool = False):
        mode = protector.mode
        assert mode.has_parity or mode.has_cksums, (
            "deferred epochs batch parity/checksum work; mode "
            f"{mode.value} has neither — use Protector.commit directly")
        assert window >= 1, window
        self.p = protector
        # `window` is the ceiling; the *current* window adapts (adaptive
        # shrink: scrub pressure / failure suspicion collapse it toward 1,
        # clean scrubs regrow it by doubling — see report_pressure)
        self.max_window = window
        self.window = window
        self.donate = donate
        # telemetry (repro.obs): the Pool assigns its registry here;
        # publication is host-side arithmetic on values this class already
        # holds — wiring it never adds device traffic or retraces
        self.metrics = None           # Optional[obs.MetricsRegistry]
        # replicate_meta mirrors the window's dirty mask + digest (a few
        # hundred bytes) across the pod at every commit, so survivors of a
        # mid-window loss can bound the lost window without checkpoint +
        # log replay (see window_meta / verify_window_bound)
        self.replicate_meta = bool(replicate_meta)
        self._meta: Optional[dict] = None
        lo = protector.layout
        self.patch = dirty_leaf_idx is not None
        self.dirty_leaf_idx = (tuple(int(i) for i in dirty_leaf_idx)
                               if self.patch else None)
        if self.patch:
            # every dirty word lives inside a dirty leaf (+1 page of
            # word-overhang spill each), so the epoch's footprint can
            # never exceed the leaves' own page span — and when the
            # caller knows a tighter per-step page capacity (sliding
            # decode slots), W x that bounds it too; take the min
            leaf_bound = sum(len(layout_mod.leaf_pages(lo, i)) + 1
                             for i in self.dirty_leaf_idx)
            if dirty_capacity is not None:
                per_step = int(dirty_capacity) + len(self.dirty_leaf_idx)
            else:
                per_step = leaf_bound
            self.dirty_capacity = min(lo.n_blocks, per_step)
            self.flush_capacity = min(lo.n_blocks, leaf_bound,
                                      per_step * window)
        else:
            assert dirty_capacity is None, \
                "dirty_capacity implies a patch engine: pass dirty_leaf_idx"
            self.dirty_capacity = None
            self.flush_capacity = lo.n_blocks
        self.flush_patch = (self.patch
                            and self.flush_capacity / lo.n_blocks
                            < protector.hybrid_threshold)
        self._since = 0
        self._jit: dict = {}
        # fault-arrival point (chaos harness): called between in-window
        # commits — after commit k's bookkeeping, BEFORE the epoch flush
        # when one is due — as fn(est, since, at_boundary) -> Optional
        # [EpochState].  Returning a replaced EpochState models a fault
        # (corruption, rank loss) landing inside the window, concurrent
        # with traffic: the flush that follows must still describe
        # intended values (the row/accumulator are separate buffers the
        # state corruption never touched).  None leaves the window
        # untouched.  See repro/chaos.
        self.arrival_hook = None

    # -- lifecycle -------------------------------------------------------------

    def _zone_zeros(self, tail_shape, dtype):
        p = self.p
        arr = jnp.zeros(p._mesh_dims + tail_shape, dtype)
        return jax.device_put(arr, NamedSharding(p.mesh, p._zone_spec))

    def wrap(self, prot: ProtectedState) -> EpochState:
        """Wrap a freshly-protected state (parity/cksums/row must be
        current — i.e. right after Protector.init, a flush, or recovery)
        with an empty window."""
        self._since = 0
        lo = self.p.layout
        return EpochState(
            prot=prot,
            dirty=(self._zone_zeros((lo.n_blocks,), jnp.bool_)
                   if self.patch else None),
            pending=jnp.zeros((), U32),
            acc=(None if self.patch
                 else self._zone_zeros((lo.row_words,), U32)))

    def init(self, state: PyTree) -> EpochState:
        return self.wrap(self.p.init(state))

    @property
    def needs_flush(self) -> bool:
        return self._since > 0

    # -- adaptive window (scrub pressure / failure suspicion) -------------------

    def report_pressure(self, suspect: bool) -> int:
        """Feed scrub pressure or failure suspicion back into the window.

        Any detected error (bad pages, parity/Q mismatch, stale row
        cache) or failure event collapses the window to 1 — the engine
        degenerates to the synchronous cadence, so redundancy lag never
        compounds while the pool is suspect.  Every clean signal — a
        clean scrub, or sustained clean-commit load (the Scrubber calls
        in after `growth_commits` consecutive clean commits) — doubles
        the window back toward its configured ceiling.  Returns the new
        window size; takes effect at the next commit (an already-open
        window flushes on its old cadence at the latest).
        """
        before = self.window
        if suspect:
            self.window = 1
        else:
            self.window = min(self.max_window, max(self.window * 2, 2))
        if self.metrics is not None:
            self.metrics.gauge("pool_window").set(self.window)
            if self.window < before:
                self.metrics.counter("pool_window_collapse_total").inc()
            elif self.window > before:
                self.metrics.counter("pool_window_grow_total").inc()
        return self.window

    # -- replicated window metadata ---------------------------------------------

    @property
    def window_meta(self) -> Optional[dict]:
        """The last replicated (dirty mask + digest) snapshot, or None.

        Materializes the device-side mirror to the host lazily — the
        commit path never blocks on it (see _mirror_meta).
        """
        if self._meta is None:
            return None
        nb = self.p.layout.n_blocks
        dig, step, pending, dirty = jax.device_get(self._meta)
        meta = {"step": int(step), "pending": int(pending),
                "digest": np.asarray(dig).copy()}
        if dirty is not None:
            d = np.asarray(dirty).reshape(-1, nb).any(axis=0)
            meta["dirty_pages"] = np.nonzero(d)[0].tolist()
        else:
            meta["dirty_pages"] = None     # bulk engine: whole row in-window
        return meta

    def _mirror_meta(self, est: EpochState) -> None:
        """Mirror the window's bookkeeping across the pod.

        A few hundred bytes per commit: the unioned dirty-page mask,
        every rank's row digest, and the pending count.  On a mid-window
        rank loss the survivors' copy bounds exactly which pages the lost
        window could have touched and what the row digests must be after
        flush + reconstruction — no checkpoint + redo replay needed to
        re-derive them.  The snapshot rides the *secondary pod-axis
        all-gather* (`dist.collectives.make_meta_mirror`): one cached
        jitted reshard to the fully-replicated sharding, dispatched
        asynchronously — no `device_get`, no host sync, so the commit
        path (and an N-deep pipeline dispatching ahead) never stalls —
        and landing in fresh replicated buffers on EVERY device, so
        donation of the live EpochState can't invalidate the mirror and
        a lost rank's copy survives on the others.  `window_meta`
        fetches to host only when a failure actually consults it.
        """
        if "wmeta_mirror" not in self._jit:
            self._jit["wmeta_mirror"] = coll.make_meta_mirror(self.p.mesh)
        self._meta = self._jit["wmeta_mirror"](
            (est.prot.digest, est.prot.step, est.pending, est.dirty))

    def verify_window_bound(self, est: EpochState) -> Optional[bool]:
        """Check the live rows against the replicated digests.

        Call after flush (+ recovery): recomputes each rank's row digest
        from the live state and compares with the mirrored copy.  True
        means the survivors' metadata bounds the pool exactly — nothing
        in the lost window needs checkpoint + log replay.
        """
        if self._meta is None:
            return None
        p, lo = self.p, self.p.layout
        if "wmeta_digest" not in self._jit:
            def _dig(state):
                row = layout_mod.flatten_row(lo, state)
                return p._pack(ck.digest(row, lo.block_words))
            self._jit["wmeta_digest"] = jax.jit(p._smap(
                _dig, in_specs=(p.state_specs,), out_specs=p._zone_spec))
        dig = np.asarray(jax.device_get(
            self._jit["wmeta_digest"](est.prot.state)))
        want = np.asarray(jax.device_get(self._meta[0]))   # mirrored digest
        return bool(np.array_equal(dig, want))

    # -- in-window commit -------------------------------------------------------

    def make_step_commit(self):
        """Build the in-window commit.

        Patch engine: digest-over-modified-words + dirty union + log;
        parity, checksum table and cached row untouched.  Bulk engine:
        one `fused_accum_commit` sweep folds the step's delta into the
        explicit accumulator and refreshes checksums + digest from the
        same pass (streamed past the protector's threshold).
        """
        p, lo = self.p, self.p.layout
        mode, bw = self.p.mode, self.p.layout.block_words
        nb, rw = lo.n_blocks, lo.row_words
        patch = self.patch
        dirty_leaves = self.dirty_leaf_idx
        # static flat-vs-streamed choice (ProtectConfig threshold)
        scb = None if patch else p.stream_chunk()

        def _step(digest, dirty, acc, row_cache, state_old, state_new,
                  widx):
            digest_l = p._unpack(digest)
            outs = {}
            if patch:
                dirty_l = p._unpack(dirty)
                old_leaves = jax.tree.leaves(state_old)
                new_leaves = jax.tree.leaves(state_new)
                new_digest = digest_l
                for k, li in enumerate(dirty_leaves):
                    slot = lo.slots[li]
                    ow = utils.to_words(old_leaves[li])
                    nw = utils.to_words(new_leaves[li])
                    wi = widx[k] if widx is not None else None
                    if wi is None:          # whole leaf dirty (static)
                        off = (U32(slot.offset)
                               + jnp.arange(slot.n_words, dtype=U32))
                        o_g, n_g = ow, nw
                        pg = jnp.asarray(layout_mod.leaf_pages(lo, li),
                                         jnp.int32)
                    else:                   # dynamic word-index array
                        # overhang/OOB entries read 0 from both sides ->
                        # delta zero (see layout.time_slice_words)
                        o_g = ow.at[wi].get(mode="fill", fill_value=0)
                        n_g = nw.at[wi].get(mode="fill", fill_value=0)
                        off = U32(slot.offset) + wi.astype(U32)
                        pg = (jnp.int32(slot.offset) + wi) // bw
                    new_digest = ck.update_digest_words(
                        new_digest, o_g, n_g, off, rw)
                    # spill pages past the row end are dropped
                    dirty_l = dirty_l.at[pg].set(True, mode="drop")
                outs["dirty"] = p._pack(dirty_l)
            else:
                # bulk accum step: row_cache is last step's row, so the
                # fused sweep's delta telescopes into acc; its new-row
                # Fletcher terms serve the checksum table AND the digest
                row_new = layout_mod.flatten_row(lo, state_new)
                old_v = parity_mod.page_view(p._unpack(row_cache), bw)
                new_v = parity_mod.page_view(row_new, bw)
                acc_v = parity_mod.page_view(p._unpack(acc), bw)
                if scb is None:
                    acc_v, _, new_ck = kops.fused_accum_commit(
                        acc_v, old_v, new_v)
                    new_digest = ck.combine(new_ck, bw)
                else:
                    acc_v, _, new_ck, new_digest = (
                        kops.fused_accum_commit_stream(
                            acc_v, old_v, new_v, chunk_blocks=scb))
                outs["row"] = p._pack(row_new)
                outs["acc"] = p._pack(acc_v.reshape(-1))
                if mode.has_cksums:
                    outs["cksums"] = p._pack(new_ck)
            outs["digest"] = p._pack(new_digest)
            return outs

        z = p._zone_spec
        out_specs = {"digest": z}
        if patch:
            out_specs["dirty"] = z
        else:
            out_specs["row"] = z
            out_specs["acc"] = z
            if mode.has_cksums:
                out_specs["cksums"] = z
        protect = p._smap(
            _step,
            in_specs=(z, z, z, z, p.state_specs, p.state_specs, P()),
            out_specs=out_specs)

        def commit(prot: ProtectedState, dirty, pending, acc, state_new,
                   dirty_words, data_cursor, rng_key, canary_ok):
            # canary_ok is STATIC (host-known before dispatch): the
            # all-clear program carries no abort gating at all, and an
            # abort compiles once into this pure no-op
            if not canary_ok:
                return prot, dirty, pending, acc, jnp.zeros((), bool)
            step = prot.step + U32(1)
            outs = protect(prot.digest, dirty, acc, prot.row,
                           prot.state, state_new, dirty_words)
            # paper ordering preserved: the redo record (replicated)
            # persists per step and carries the post-step digest; only
            # the parity/checksum refresh is deferred to the flush.
            log = prot.log
            if mode.has_log:
                if rng_key is None:
                    rng_key = jax.random.PRNGKey(0)
                log = redolog.append(prot.log, step, data_cursor, rng_key,
                                     outs["digest"].reshape(-1, 2)[0])
                log = redolog.commit_mark(log, step)
            new_prot = ProtectedState(
                state=state_new, synd=prot.synd,
                cksums=outs.get("cksums", prot.cksums),
                digest=outs["digest"], replica=prot.replica, log=log,
                step=step,
                row=prot.row if patch else outs["row"])
            return (new_prot, outs.get("dirty", dirty),
                    pending + U32(1), outs.get("acc", acc),
                    jnp.ones((), bool))

        return commit

    def make_step_commit_staged(self):
        """The in-window commit with a DEVICE-side canary verdict.

        `make_step_commit` keys the canary statically — the host knows
        the verdict before dispatch, so abort compiles to a pure no-op.
        An async pipeline can't always know it: a staged canary page is
        checked by a device program whose scalar hasn't landed when the
        next commit dispatches.  This variant takes the canary as a
        traced bool: the all-clear body runs unconditionally and every
        output is selected per-leaf against the previous window state —
        on a False canary the result is bit-identical to the static
        abort no-op (old prot/dirty/pending/acc pass through, the log
        untouched), so a drained pipeline matches the synchronous
        engine exactly whichever way the verdict arrived.
        """
        inner = self.make_step_commit()

        def commit(prot: ProtectedState, dirty, pending, acc, state_new,
                   dirty_words, data_cursor, rng_key, canary):
            new_prot, new_dirty, new_pending, new_acc, _ = inner(
                prot, dirty, pending, acc, state_new, dirty_words,
                data_cursor, rng_key, True)
            v = jnp.asarray(canary, bool).reshape(())
            sel_prot, sel_dirty, sel_pending, sel_acc = jax.tree.map(
                lambda n, o: jnp.where(v, n, o),
                (new_prot, new_dirty, new_pending, new_acc),
                (prot, dirty, pending, acc))
            return sel_prot, sel_dirty, sel_pending, sel_acc, v

        return commit

    # -- epoch flush ------------------------------------------------------------

    def make_flush(self):
        """Build the once-per-epoch redundancy refresh.

        Patch engine: the current state is spliced into the
        (epoch-start) cached row; one fused sweep over both row versions
        on the unioned dirty pages yields the window's parity delta plus
        fresh checksums, or parity is rebuilt from the row wholesale
        past the hybrid threshold — algebraically identical under the
        XOR invariant.  Bulk engine: the explicit accumulator already
        holds row_start ^ row_now, so the flush never touches the row —
        `syndrome_scale` weights it into all r planes in one stacked
        read and the chunked `apply_sdelta` reduce-scatter folds them in
        (checksums were refreshed by every accum step).  The digest is
        already current in both flavors.
        """
        p, lo = self.p, self.p.layout
        mode, ax, bw = self.p.mode, self.p.data_axis, self.p.layout.block_words
        r = self.p.redundancy
        nb = lo.n_blocks
        kf = self.flush_capacity
        fpatch = self.flush_patch
        patch = self.patch
        dirty_leaves = self.dirty_leaf_idx
        # chunked collective fold count (1 below the streaming threshold)
        cc = p.coll_chunks()

        def _flush(row_cache, synd, cksums, state, dirty, acc):
            base = p._unpack(row_cache)
            synd_l = p._unpack(synd) if synd is not None else None
            cksums_l = p._unpack(cksums) if cksums is not None else None
            coeffs = (gf.rank_syndrome_coeffs(p.group_size, r, ax)
                      if (mode.has_parity and r > 1) else None)
            outs = {}
            if patch:
                row = layout_mod.update_row(lo, base, state, dirty_leaves)
                outs["row"] = p._pack(row)
            else:
                row = base                  # bulk rows track every step
            if fpatch:
                dirty_l = p._unpack(dirty)
                idx = jnp.nonzero(dirty_l, size=kf, fill_value=nb)[0]
                valid = idx < nb
                g = jnp.minimum(idx, nb - 1)
                old_p = parity_mod.gather_pages(base, g, bw)
                new_p = parity_mod.gather_pages(row, g, bw)
                if mode.has_cksums:
                    # every syndrome rides the same telescoped epoch
                    # delta: the fused sweep weights it by g^(k·me) in
                    # VMEM (r=1 routes to the single-parity kernel)
                    sdelta_p, fresh = kops.fused_commit_s(old_p, new_p,
                                                          coeffs)
                    sidx = jnp.where(valid, g, nb)
                    outs["cksums"] = p._pack(
                        cksums_l.at[sidx].set(fresh, mode="drop"))
                else:
                    delta_p = kops.xor_delta(old_p, new_p)
                    sdelta_p = kops.syndrome_scale(delta_p, coeffs)
                if mode.has_parity:
                    sdelta_p = jnp.where(valid[None, :, None], sdelta_p, 0)
                    # fill slots must route to the out-of-range sentinel,
                    # NOT the clamped page: a clamped fill would collide
                    # with a genuinely-dirty last page and its zero-delta
                    # scatter entry could overwrite the real patch
                    outs["synd"] = p._pack(parity_mod.patch_syndrome_delta(
                        synd_l, sdelta_p, jnp.where(valid, g, nb), lo,
                        ax))
            elif patch:
                # patch engine past the hybrid threshold: rebuild from
                # the spliced row wholesale — equal to S_start ^
                # rs(telescoped weighted delta) by XOR linearity
                if mode.has_parity:
                    outs["synd"] = p._pack(
                        parity_mod.build_syndromes(row, r, ax, chunks=cc))
                if mode.has_cksums:
                    outs["cksums"] = p._pack(kops.fletcher_blocks(
                        parity_mod.page_view(row, bw)))
            else:
                # bulk engine: acc == row_start ^ row_now (telescoped),
                # so S_k ^ rs(g^(k·me)·acc) == the stack rebuilt from
                # the current row, by GF/XOR linearity — one accumulator
                # read replaces the (2+r)-row flush sweep; cksums are
                # already fresh from the accum steps
                acc_l = p._unpack(acc)
                if mode.has_parity:
                    sdelta = kops.syndrome_scale(acc_l, coeffs)
                    outs["synd"] = p._pack(parity_mod.apply_sdelta(
                        synd_l, sdelta, ax, chunks=cc))
                outs["acc"] = p._pack(jnp.zeros_like(acc_l))
            if dirty is not None:
                outs["dirty"] = p._pack(jnp.zeros((nb,), jnp.bool_))
            return outs

        z = p._zone_spec
        out_specs = {}
        if mode.has_parity:
            out_specs["synd"] = z
        if mode.has_cksums and patch:
            out_specs["cksums"] = z
        if patch:
            out_specs["row"] = z
            out_specs["dirty"] = z
        else:
            out_specs["acc"] = z
        fn = p._smap(_flush, in_specs=(z, z, z, p.state_specs, z, z),
                     out_specs=out_specs)

        def flush(est: EpochState) -> EpochState:
            prot = est.prot
            outs = fn(prot.row, prot.synd, prot.cksums,
                      prot.state, est.dirty, est.acc)
            new_prot = dataclasses.replace(
                prot, synd=outs.get("synd", prot.synd),
                cksums=outs.get("cksums", prot.cksums),
                row=outs.get("row", prot.row))
            return EpochState(prot=new_prot, dirty=outs.get("dirty"),
                              pending=jnp.zeros((), U32),
                              acc=outs.get("acc", est.acc))

        return flush

    # -- cached-jit entry points -------------------------------------------------

    def _jitted(self, key, build, n_donated=1, static=()):
        if key not in self._jit:
            donate = tuple(range(n_donated)) if self.donate else ()
            self._jit[key] = jax.jit(build(), donate_argnums=donate,
                                     static_argnums=static)
        return self._jit[key]

    def commit(self, est: EpochState, state_new: PyTree, *,
               dirty_words=None, data_cursor=0, rng_key=None,
               canary_ok: bool = True):
        """One transactional update; flushes automatically at the window
        boundary.

        `dirty_words` (patch engines): tuple aligned with
        `dirty_leaf_idx` — per-leaf word-index arrays, or None entries
        (or None for the whole tuple) meaning those leaves are wholly
        dirty.  With donation on, `est` (and its buffers) must not be
        used after this call — keep only the returned EpochState.
        """
        assert dirty_words is None or self.patch, \
            "dirty_words requires a patch engine (static dirty_leaf_idx)"
        assert dirty_words is None or len(dirty_words) == len(
            self.dirty_leaf_idx)
        # canary verdict is host-known before dispatch: static, so the
        # all-clear program folds its abort select-chains away entirely
        prot, dirty, pending, acc, ok = self._jitted(
            "step", self.make_step_commit, n_donated=4, static=(8,))(
            est.prot, est.dirty, est.pending, est.acc, state_new,
            dirty_words, data_cursor, rng_key, bool(canary_ok))
        est = EpochState(prot=prot, dirty=dirty, pending=pending, acc=acc)
        return self._after_step(est), ok

    def commit_staged(self, est: EpochState, state_new: PyTree, *,
                      canary, dirty_words=None, data_cursor=0,
                      rng_key=None):
        """`commit` with a device-resident canary verdict (`canary` is
        an unfetched bool scalar, e.g. `kernels.ops.stage_verdict` over
        guarded staging buffers).  The abort select rides inside the
        program (see make_step_commit_staged), so dispatch never waits
        for the verdict — the returned `ok` is the canary itself, still
        unfetched.  Host cadence (`_since`, the boundary flush) counts
        the ATTEMPT exactly like the static path, so drained pipelines
        stay bit-identical to synchronous resolution.
        """
        assert dirty_words is None or self.patch, \
            "dirty_words requires a patch engine (static dirty_leaf_idx)"
        prot, dirty, pending, acc, ok = self._jitted(
            "step_staged", self.make_step_commit_staged, n_donated=4)(
            est.prot, est.dirty, est.pending, est.acc, state_new,
            dirty_words, data_cursor, rng_key, canary)
        est = EpochState(prot=prot, dirty=dirty, pending=pending, acc=acc)
        return self._after_step(est), ok

    def _after_step(self, est: EpochState) -> EpochState:
        """Shared post-commit host cadence: attempt count, the
        fault-arrival hook, the boundary flush, the meta mirror."""
        self._since += 1
        if self.arrival_hook is not None:
            # the mid-window fault-arrival point: the hook sees the
            # window AFTER this commit landed and BEFORE any boundary
            # flush — exactly where a concurrent fault is nastiest
            replaced = self.arrival_hook(est, self._since,
                                         self._since >= self.window)
            if replaced is not None:
                est = replaced
        if self._since >= self.window:
            est = self.flush(est)
        if self.replicate_meta:
            self._mirror_meta(est)
        return est

    def flush(self, est: EpochState) -> EpochState:
        """Refresh parity/cksums (and the row) from the window now."""
        pending = self._since
        self._since = 0
        if self.metrics is not None:
            self.metrics.counter("pool_window_flush_total").inc()
            self.metrics.histogram("pool_flush_pending").observe(pending)
        return self._jitted("flush", self.make_flush)(est)

    def flush_if_pending(self, est: EpochState) -> EpochState:
        """Flush only when in-window work exists (pre-scrub / recovery)."""
        return self.flush(est) if self.needs_flush else est
