"""Online recovery orchestration (Pangolin §3.6).

Two entry points, both funneling into the Protector's reconstruction ops:

  * `recover_from_rank_loss`  — media-error path: a failure event reports a
    lost rank (the analogue of SIGBUS reporting a poisoned page); the pool
    freezes, survivors rebuild the row from parity, the pool resumes.
  * `recover_from_scribble`   — corruption path: checksum mismatches (from a
    scrub or a verify-at-open) identify (rank, page) victims; targeted page
    reconstruction repairs them in place.

Recovery is idempotent (pure reconstruction from surviving rows + parity),
so a crash mid-recovery simply re-executes it — the paper's §3.6 guarantee.

Crash recovery (redo-log replay) lives in runtime/trainer.py, which owns the
data pipeline and step function needed to re-execute logged steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core import txn as txn_mod


@dataclasses.dataclass
class RecoveryReport:
    kind: str                    # "rank_loss" | "double_loss" | "scribble"
    lost_rank: Optional[int]
    pages: list
    verified: bool               # post-repair checksum verification passed
    frozen: bool
    lost_ranks: Optional[list] = None     # double-loss: both ranks
    # survivors' replicated window metadata bound (deferred engine):
    # {"pending", "dirty_pages", "digest_verified"} or None
    window_bound: Optional[dict] = None


def recover_from_rank_loss(protector: txn_mod.Protector,
                           prot: txn_mod.ProtectedState, lost_rank: int,
                           freeze: Optional[Callable] = None,
                           resume: Optional[Callable] = None):
    """Rebuild one data-rank's entire state shard from parity, online."""
    if not protector.mode.has_parity:
        raise RuntimeError(
            f"mode {protector.mode.value} has no parity; rank loss is "
            "unrecoverable online (restore from checkpoint instead)")
    if freeze is not None:
        freeze()
    prot, ok = protector.recover_rank(prot, lost_rank)
    verified = bool(jax.device_get(ok))
    if resume is not None:
        resume()
    return prot, RecoveryReport("rank_loss", lost_rank, [], verified,
                                freeze is not None)


def recover_from_double_loss(protector: txn_mod.Protector,
                             prot: txn_mod.ProtectedState,
                             lost_ranks: Sequence[int],
                             freeze: Optional[Callable] = None,
                             resume: Optional[Callable] = None):
    """Rebuild TWO lost data-ranks' rows from P + Q, online.

    Requires a dual-parity mode (redundancy=2): the 2x2 Vandermonde solve
    over GF(2^32) inverts both losses at once (core/parity.reconstruct_two).
    Also the escape hatch for a rank loss while a scribbled page is still
    unrepaired — name the scribbled rank as the second loss and both come
    back to intended values (single-parity Pangolin cannot untangle that
    overlap).  Idempotent like the single-loss path: pure reconstruction
    from surviving rows + both syndromes.
    """
    if not protector.mode.has_qparity:
        raise RuntimeError(
            f"mode {protector.mode.value} has no Q syndrome; a double "
            "rank loss is unrecoverable online — run redundancy=2 "
            "(mlp2/mlpc2) or restore from checkpoint")
    a, b = (int(r) for r in lost_ranks)
    if freeze is not None:
        freeze()
    prot, ok = protector.recover_two(prot, a, b)
    verified = bool(jax.device_get(ok))
    if resume is not None:
        resume()
    return prot, RecoveryReport("double_loss", None, [], verified,
                                freeze is not None,
                                lost_ranks=sorted((a, b)))


def recover_from_scribble(protector: txn_mod.Protector,
                          prot: txn_mod.ProtectedState,
                          locations: Sequence[tuple],
                          freeze: Optional[Callable] = None,
                          resume: Optional[Callable] = None):
    """Repair (rank, page) scribble victims from parity, online."""
    if not protector.mode.has_parity:
        raise RuntimeError("scribble repair requires parity")
    if freeze is not None:
        freeze()
    ranks = [r for r, _ in locations]
    pages = [p for _, p in locations]
    prot, ok = protector.repair_pages(prot, ranks, pages)
    verified = bool(jax.device_get(ok))
    if resume is not None:
        resume()
    return prot, RecoveryReport("scribble", None, list(locations), verified,
                                freeze is not None)
