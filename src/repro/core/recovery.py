"""Online recovery orchestration (Pangolin §3.6).

Two entry points, both funneling into the Protector's reconstruction ops:

  * `recover_from_rank_loss`  — media-error path: a failure event reports a
    lost rank (the analogue of SIGBUS reporting a poisoned page); the pool
    freezes, survivors rebuild the row from parity, the pool resumes.
  * `recover_from_e_loss`     — the generalized form: any e <= redundancy
    simultaneous rank losses solve through the syndrome stack's e x e
    Vandermonde inverse (beyond paper; `recover_from_double_loss` is the
    e=2 back-compat alias).
  * `recover_from_scribble`   — corruption path: checksum mismatches (from a
    scrub or a verify-at-open) identify (rank, page) victims; targeted page
    reconstruction repairs them in place.

Recovery is idempotent (pure reconstruction from surviving rows + parity),
so a crash mid-recovery simply re-executes it — the paper's §3.6 guarantee.

Crash recovery (redo-log replay) lives in runtime/trainer.py, which owns the
data pipeline and step function needed to re-execute logged steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.core import txn as txn_mod


@dataclasses.dataclass
class RecoveryReport:
    kind: str                    # "rank_loss" | "multi_loss" | "scribble"
    lost_rank: Optional[int]
    pages: list
    verified: bool               # post-repair checksum verification passed
    frozen: bool
    lost_ranks: Optional[list] = None     # multi-loss: every lost rank
    # survivors' replicated window metadata bound (deferred engine):
    # {"pending", "dirty_pages", "digest_verified"} or None
    window_bound: Optional[dict] = None
    # post-recovery re-verify (Pool.recover): the syndrome invariants
    # re-checked AFTER reconstruction — entry k is S_k's verdict; None
    # when the re-verify was skipped or the mode keeps no syndromes
    synd_ok: Optional[list] = None
    # overall post-recovery re-verify verdict (syndromes + checksums +
    # row cache); None when skipped
    reverified: Optional[bool] = None
    # async-safe re-entry (Pool.recover): faults that arrived while this
    # recovery was in flight and were drained right after it
    followups: int = 0
    # wall timings (ms) — reports and trace spans share one vocabulary:
    # queue_wait is the re-entry queue dwell (0 for a direct recovery),
    # solve the reconstruction incl. its verdict sync, reverify the
    # post-recovery syndrome/checksum re-check, total the whole
    # Pool.recover path including the pre-flush
    queue_wait_ms: Optional[float] = None
    solve_ms: Optional[float] = None
    reverify_ms: Optional[float] = None
    total_ms: Optional[float] = None

    def to_event(self) -> dict:
        """Flatten to the trace/record vocabulary: one flat dict usable
        as a span's end fields or a campaign's per-recovery record —
        RecoveryReports and trace spans stay one vocabulary."""
        ev: dict = {"kind": self.kind, "verified": bool(self.verified),
                    "followups": int(self.followups)}
        if self.lost_rank is not None:
            ev["lost_rank"] = int(self.lost_rank)
        if self.lost_ranks:
            ev["lost_ranks"] = [int(r) for r in self.lost_ranks]
        if self.pages:
            ev["pages"] = [tuple(p) for p in self.pages]
        if self.reverified is not None:
            ev["reverified"] = bool(self.reverified)
        if self.window_bound is not None:
            ev["window_bound_verified"] = bool(
                self.window_bound.get("digest_verified"))
        for f in ("queue_wait_ms", "solve_ms", "reverify_ms", "total_ms"):
            v = getattr(self, f)
            if v is not None:
                ev[f] = round(float(v), 3)
        return ev


def recover_from_rank_loss(protector: txn_mod.Protector,
                           prot: txn_mod.ProtectedState, lost_rank: int,
                           freeze: Optional[Callable] = None,
                           resume: Optional[Callable] = None):
    """Rebuild one data-rank's entire state shard from parity, online."""
    if not protector.mode.has_parity:
        raise RuntimeError(
            f"mode {protector.mode.value} has no parity; rank loss is "
            "unrecoverable online (restore from checkpoint instead)")
    if freeze is not None:
        freeze()
    t0 = time.perf_counter()
    prot, ok = protector.recover_rank(prot, lost_rank)
    verified = bool(jax.device_get(ok))
    solve_ms = (time.perf_counter() - t0) * 1e3
    if resume is not None:
        resume()
    return prot, RecoveryReport("rank_loss", lost_rank, [], verified,
                                freeze is not None, solve_ms=solve_ms)


def recover_from_e_loss(protector: txn_mod.Protector,
                        prot: txn_mod.ProtectedState,
                        lost_ranks: Sequence[int],
                        freeze: Optional[Callable] = None,
                        resume: Optional[Callable] = None):
    """Rebuild e <= r lost data-ranks' rows from the syndrome stack.

    Requires redundancy >= e: the e x e Vandermonde solve over GF(2^32)
    inverts every loss at once (core/parity.reconstruct_e).  Also the
    escape hatch for losses while a scribbled page is still unrepaired —
    name the scribbled rank as an extra loss and all come back to
    intended values (single-parity Pangolin cannot untangle that
    overlap).  Idempotent like the single-loss path: pure reconstruction
    from surviving rows + the stack.
    """
    ranks = sorted(int(a) for a in lost_ranks)
    e = len(ranks)
    r = protector.redundancy if protector.mode.has_parity else 0
    if r < e:
        # the budget-exhausted path: refusing here is the whole point —
        # an e x e solve through an r < e syndrome stack would return
        # garbage rows that verify_blocks may not even catch (the
        # checksums describe intended values, but nothing forces the
        # caller to look).  Name the dead ranks and the available budget
        # so the operator can route to the checkpoint tier.
        raise RuntimeError(
            f"syndrome budget exhausted: ranks {ranks} are lost "
            f"simultaneously (e={e}) but mode {protector.mode.value} "
            f"holds only redundancy={r} syndrome row(s) — a zone solves "
            f"at most r losses online.  Recover the pool from the "
            f"checkpoint + redo-log tier, then re-arm by re-protecting "
            f"(pool.init) or raise ProtectConfig.redundancy>={e} (<= 4) "
            "before the next storm")
    if freeze is not None:
        freeze()
    t0 = time.perf_counter()
    if e == 1:
        prot, ok = protector.recover_rank(prot, ranks[0])
    else:
        prot, ok = protector.recover_e(prot, ranks)
    verified = bool(jax.device_get(ok))
    solve_ms = (time.perf_counter() - t0) * 1e3
    if resume is not None:
        resume()
    if e == 1:
        return prot, RecoveryReport("rank_loss", ranks[0], [], verified,
                                    freeze is not None, solve_ms=solve_ms)
    return prot, RecoveryReport("multi_loss", None, [], verified,
                                freeze is not None, lost_ranks=ranks,
                                solve_ms=solve_ms)


def recover_from_double_loss(protector: txn_mod.Protector,
                             prot: txn_mod.ProtectedState,
                             lost_ranks: Sequence[int],
                             freeze: Optional[Callable] = None,
                             resume: Optional[Callable] = None):
    """Back-compat alias: the e=2 erasure recovery."""
    a, b = (int(r) for r in lost_ranks)
    return recover_from_e_loss(protector, prot, (a, b), freeze=freeze,
                               resume=resume)


def recover_from_scribble(protector: txn_mod.Protector,
                          prot: txn_mod.ProtectedState,
                          locations: Sequence[tuple],
                          freeze: Optional[Callable] = None,
                          resume: Optional[Callable] = None):
    """Repair (rank, page) scribble victims from parity, online."""
    if not protector.mode.has_parity:
        raise RuntimeError("scribble repair requires parity")
    if freeze is not None:
        freeze()
    t0 = time.perf_counter()
    ranks = [r for r, _ in locations]
    pages = [p for _, p in locations]
    prot, ok = protector.repair_pages(prot, ranks, pages)
    verified = bool(jax.device_get(ok))
    solve_ms = (time.perf_counter() - t0) * 1e3
    if resume is not None:
        resume()
    return prot, RecoveryReport("scribble", None, list(locations), verified,
                                freeze is not None, solve_ms=solve_ms)
