"""Replicated redo log (Pangolin §3.4, §3.6 "crash recovery").

Pangolin commits by (1) persisting + replicating redo log entries, (2)
setting a logging-complete mark, (3) applying object writes, (4) updating
parity; replay is idempotent.  The JAX analogue of a log entry for a train
step is the *recipe* to re-execute it deterministically — (step, data
cursor, RNG key) — plus the digest of the state it produced, so replay can
verify it landed in the same place.  Records are replicated across the pod
axis (spec () replicates them on every rank — strictly stronger than the
paper's 2x replication; the storage is a few hundred bytes).

The log is a fixed ring of K records held in device memory and mirrored to
the host by the checkpoint manager.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RedoLog:
    step: jax.Array         # (K,) u32  — step id of each record
    data_cursor: jax.Array  # (K,) u32  — data-pipeline cursor to replay
    rng: jax.Array          # (K, 2) u32 — RNG key of the step
    digest: jax.Array       # (K, 2) u32 — row digest after the step
    mark: jax.Array         # (K,) u32  — 1 = logging complete (commit mark)

    def tree_flatten(self):
        return ((self.step, self.data_cursor, self.rng, self.digest,
                 self.mark), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.step.shape[0]


def make(capacity: int = 64) -> RedoLog:
    # distinct buffers per field: the log is donated into its successor
    # on the commit hot path, and XLA rejects donating one buffer twice
    def z():
        return jnp.zeros((capacity,), U32)
    return RedoLog(step=z(), data_cursor=z(),
                   rng=jnp.zeros((capacity, 2), U32),
                   digest=jnp.zeros((capacity, 2), U32), mark=z())


def append(log: RedoLog, step, data_cursor, rng_key, digest) -> RedoLog:
    """Write a record (mark=0), to be marked complete by `commit_mark`."""
    slot = jnp.asarray(step, U32) % U32(log.capacity)
    key_words = jax.random.key_data(rng_key).astype(U32).reshape(-1)[:2]
    return RedoLog(
        step=log.step.at[slot].set(jnp.asarray(step, U32)),
        data_cursor=log.data_cursor.at[slot].set(jnp.asarray(data_cursor, U32)),
        rng=log.rng.at[slot].set(key_words),
        digest=log.digest.at[slot].set(digest.astype(U32)),
        mark=log.mark.at[slot].set(U32(0)),
    )


def commit_mark(log: RedoLog, step) -> RedoLog:
    """Set the logging-complete mark — the paper's persistent commit point."""
    slot = jnp.asarray(step, U32) % U32(log.capacity)
    return RedoLog(step=log.step, data_cursor=log.data_cursor, rng=log.rng,
                   digest=log.digest, mark=log.mark.at[slot].set(U32(1)))


def lookup(log: RedoLog, step) -> dict:
    slot = jnp.asarray(step, U32) % U32(log.capacity)
    return dict(step=log.step[slot], data_cursor=log.data_cursor[slot],
                rng=log.rng[slot], digest=log.digest[slot],
                mark=log.mark[slot])


def replayable_steps(log: RedoLog, from_step: int) -> list[int]:
    """Host-side: contiguous marked steps strictly after `from_step`."""
    steps = jax.device_get(log.step).tolist()
    marks = jax.device_get(log.mark).tolist()
    marked = {s for s, m in zip(steps, marks) if m == 1 and s > from_step}
    out, s = [], from_step + 1
    while s in marked:
        out.append(s)
        s += 1
    return out
