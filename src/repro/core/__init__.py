"""Pangolin-JAX core: the paper's contribution as composable JAX modules."""

from repro.core.txn import (  # noqa: F401
    Mode, ProtectedState, Protector, resolved_mode)
from repro.core.scrub import Scrubber, ScrubReport  # noqa: F401
from repro.core.recovery import (  # noqa: F401
    RecoveryReport, recover_from_double_loss, recover_from_e_loss,
    recover_from_rank_loss, recover_from_scribble)
from repro.core import (  # noqa: F401
    checksum, gf, layout, microbuffer, parity, redolog)
