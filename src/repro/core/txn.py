"""Fault-tolerant transactions over distributed state (Pangolin §3.4).

The `Protector` wraps a sharded state pytree (params, optimizer moments, KV
caches, ...) with Pangolin's protection stack and exposes the transactional
API:

    prot   = protector.init(state)                      # build parity+checksums
    prot', ok = protector.commit(prot, new_state, ...)  # transactional update
    report = protector.scrub(prot)                      # periodic verification
    prot'  = protector.recover_rank(prot, lost)         # online media recovery
    prot'  = protector.repair_pages(prot, rank, pages)  # online scribble repair

Commit pipeline (paper order: redo log -> objects -> parity, idempotent):
  1. redo record appended + commit-marked (replicated),
  2. canary verified (abort without touching state on mismatch),
  3. object checksums refreshed (incremental where dirty pages are known),
  4. parity updated via the hybrid patch/bulk scheme,
  5. the new state replaces the old (functional swap).

Protection-mode ladder mirrors the paper's evaluation (Table 2):
  NONE   ~ Pangolin baseline (micro-buffering + canary only)
  ML     ~ + metadata/redo-log replication
  MLP    ~ + XOR parity (media-error recovery; compare w/ REPLICA)
  MLPC   ~ + object checksums (scribble detection)
  REPLICA~ libpmemobj's replicated mode (2x storage, the paper's baseline)

Orthogonal to the ladder, `redundancy` r (1..4) selects the syndrome
stack height of the parity modes: S_0 is the XOR parity above, and each
extra syndrome S_k = XOR_i g^(k·i)·row_i (GF(2^32) Reed-Solomon,
core/gf.py) buys one more simultaneous rank loss at one more parity
fraction of storage — any e <= r losses reconstruct online
(`recover_e`).  The former MLP2/MLPC2 dual-parity modes dissolved into
(mlp|mlpc, redundancy=2); `resolved_mode` keeps the aliases working.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import checksum as ck
from repro.core import gf
from repro.core import layout as layout_mod
from repro.core import parity as parity_mod
from repro.core import redolog
from repro.dist import collectives as coll
from repro.kernels import ops as kops

PyTree = Any
U32 = jnp.uint32


class Mode(enum.Enum):
    NONE = "none"          # micro-buffering + canary only (pgl baseline)
    ML = "ml"              # + redo-log/metadata replication
    MLP = "mlp"            # + parity (syndrome stack, height = redundancy)
    MLPC = "mlpc"          # + checksums
    REPLICA = "replica"    # full replica (Pmemobj-R analogue)

    @property
    def has_parity(self) -> bool:
        return self in (Mode.MLP, Mode.MLPC)

    @property
    def has_cksums(self) -> bool:
        return self is Mode.MLPC

    @property
    def has_log(self) -> bool:
        return self in (Mode.ML, Mode.MLP, Mode.MLPC)

    @property
    def has_replica(self) -> bool:
        return self is Mode.REPLICA


# redundancy is orthogonal to the ladder now: a parity mode carries a
# syndrome stack S_0..S_{r-1} (S_0 = XOR parity; S_1 the former Q), and
# r = ProtectConfig.redundancy selects its height.  The old dual-parity
# mode names survive only as config aliases.
MAX_REDUNDANCY = 4
_MODE_ALIASES = {"mlp2": ("mlp", 2), "mlpc2": ("mlpc", 2)}


def resolved_mode(mode, redundancy: int = 1) -> tuple:
    """Resolve (mode-or-alias, redundancy) to the (Mode, r) pair.

    The former dual-parity Mode members dissolved into this: "mlp2" /
    "mlpc2" resolve to their base mode with redundancy >= 2 (an explicit
    higher `redundancy` wins, so `("mlp2", 3)` means a 3-syndrome MLP
    stack).  Raises with an actionable message for r outside 1..4 or a
    redundancy > 1 on a mode that keeps no parity to stack onto.
    """
    implied = 1
    if isinstance(mode, Mode):
        m = mode
    else:
        name, implied = _MODE_ALIASES.get(mode, (mode, 1))
        m = Mode(name)
    r = max(int(redundancy), implied)
    if not 1 <= int(redundancy) <= MAX_REDUNDANCY or \
            not 1 <= r <= MAX_REDUNDANCY:
        raise ValueError(
            f"redundancy={redundancy} — the syndrome stack holds 1 to "
            f"{MAX_REDUNDANCY} syndromes (1 = XOR parity P, 2 adds the "
            "GF(2^32) Q row, 3-4 add higher Vandermonde rows); larger "
            "stacks exceed the validated Reed-Solomon configuration")
    if r > 1 and not m.has_parity:
        raise ValueError(
            f"redundancy={r} with mode='{m.value}' — extra syndromes "
            "extend parity, they cannot replace it; use a parity mode "
            "(mlp or mlpc)")
    return m, r


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ProtectedState:
    state: PyTree
    # Syndrome stack, (*mesh_dims, r, seg_words) u32 — parity modes only.
    # Plane k holds this rank's segment of S_k = XOR_i g^(k·i)·row_i over
    # GF(2^32) (core/gf.py); plane 0 is classic XOR parity, plane 1 the
    # former Q.  Any e <= r simultaneous rank losses solve through the
    # e x e Vandermonde inverse (parity.reconstruct_e).
    synd: Optional[jax.Array]
    cksums: Optional[jax.Array]      # (*mesh_dims, n_blocks, 2) u32
    digest: Optional[jax.Array]      # (*mesh_dims, 2) u32 whole-row digest
    replica: Optional[PyTree]
    log: Optional[redolog.RedoLog]
    step: jax.Array                  # scalar u32, replicated
    # Cached flattened word row, (*mesh_dims, row_words) u32.  Invariant:
    # row == flatten_row(layout, state) whenever protection is active, so
    # commits diff rows directly instead of re-flattening the whole state
    # every step.  Rebuilt (never trusted) by recovery and repair.
    row: Optional[jax.Array] = None

    @property
    def parity(self) -> Optional[jax.Array]:
        """The S_0 (XOR parity) plane of the syndrome stack, read-only."""
        return None if self.synd is None else self.synd[..., 0, :]

    def tree_flatten(self):
        return ((self.state, self.synd, self.cksums, self.digest,
                 self.replica, self.log, self.step, self.row), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def tree_select(pred, on_true: PyTree, on_false: PyTree) -> PyTree:
    return jax.tree.map(lambda t, f: jnp.where(pred, t, f), on_true, on_false)


def _zone_clean(ok, bad, axis_name):
    """AND `no block is bad` into ok, agreed across the zone (pmin)."""
    ok = jnp.logical_and(ok, jnp.logical_not(jnp.any(bad)))
    return lax.pmin(ok.astype(jnp.int32), axis_name) > 0


def _spec_leaf(x):
    return isinstance(x, P)


class Protector:
    """Builds jitted, shard_map'd protection operations for one state layout."""

    def __init__(self, mesh: Mesh, abstract_state: PyTree, state_specs: PyTree,
                 *, data_axis: str = "data", mode: Mode = Mode.MLPC,
                 redundancy: int = 1,
                 block_words: int = layout_mod.PAGE_WORDS,
                 hybrid_threshold: float = 0.5,
                 log_capacity: int = 64,
                 stream_threshold_words: int = 1 << 20,
                 stream_chunk_words: int = 1 << 16):
        mode, redundancy = resolved_mode(mode, redundancy)
        self.mesh = mesh
        self.mode = mode
        self.data_axis = data_axis
        self.axis_names = tuple(mesh.axis_names)
        self.n_axes = len(self.axis_names)
        self.group_size = mesh.shape[data_axis]
        if mode.has_parity and redundancy > self.group_size - 1:
            raise ValueError(
                f"redundancy={redundancy} on a zone of "
                f"{self.group_size} data ranks — at most "
                f"num_ranks - 1 = {self.group_size - 1} simultaneous "
                "losses are solvable (the erasure system needs at least "
                "one survivor); shrink redundancy or grow the data axis")
        self.redundancy = redundancy if mode.has_parity else 1
        self.hybrid_threshold = hybrid_threshold
        self.log_capacity = log_capacity
        self.stream_threshold_words = int(stream_threshold_words)
        self.stream_chunk_words = int(stream_chunk_words)
        self.state_specs = state_specs

        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_specs, is_leaf=_spec_leaf)
        self.layout = layout_mod.build_layout(
            abstract_state, self.group_size, shardings,
            block_words=block_words)

        self._zone_spec = P(*self.axis_names)
        self._mesh_dims = tuple(mesh.shape[a] for a in self.axis_names)
        self._jit_cache: dict = {}

    # -- sharding helpers -----------------------------------------------------

    def parity_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self._zone_spec)

    def abstract_protected(self, abstract_state: PyTree) -> ProtectedState:
        """ShapeDtypeStruct ProtectedState (dry-run: no allocation)."""
        lo, mode = self.layout, self.mode
        zdims = self._mesh_dims

        def sds(shape, dtype=U32):
            return jax.ShapeDtypeStruct(shape, dtype)

        synd = (sds(zdims + (self.redundancy, lo.seg_words))
                if mode.has_parity else None)
        cksums = sds(zdims + (lo.n_blocks, 2)) if mode.has_cksums else None
        dig = (sds(zdims + (2,))
               if (mode.has_parity or mode.has_cksums) else None)
        row = (sds(zdims + (lo.row_words,))
               if (mode.has_parity or mode.has_cksums) else None)
        replica = (jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), abstract_state)
            if mode.has_replica else None)
        log = (jax.eval_shape(lambda: redolog.make(self.log_capacity))
               if mode.has_log else None)
        return ProtectedState(state=abstract_state, synd=synd,
                              cksums=cksums, digest=dig, replica=replica,
                              log=log, step=sds((), U32), row=row)

    def protected_specs(self) -> ProtectedState:
        """PartitionSpec tree matching ProtectedState."""
        mode = self.mode
        z = self._zone_spec
        log = (jax.tree.map(lambda _: P(),
                            jax.eval_shape(lambda: redolog.make(
                                self.log_capacity)))
               if mode.has_log else None)
        return ProtectedState(
            state=self.state_specs,
            synd=z if mode.has_parity else None,
            cksums=z if mode.has_cksums else None,
            digest=z if (mode.has_parity or mode.has_cksums) else None,
            replica=self.state_specs if mode.has_replica else None,
            log=log, step=P(),
            row=z if (mode.has_parity or mode.has_cksums) else None)

    def _pack(self, x: jax.Array) -> jax.Array:
        """Local per-rank value -> shard_map output layout (leading 1s)."""
        return x.reshape((1,) * self.n_axes + x.shape)

    def _unpack(self, x: jax.Array) -> jax.Array:
        return x.reshape(x.shape[self.n_axes:])

    def _smap(self, f, in_specs, out_specs):
        return shard_map(f, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    # -- streaming policy -----------------------------------------------------

    def stream_chunk(self) -> Optional[int]:
        """Pages per streamed VMEM chunk for full-row sweeps, or None.

        None means the local row is below `stream_threshold_words` and the
        flat whole-grid kernels keep the commit (their automatic pipelining
        wins on small rows); otherwise the blockwise double-buffered
        streaming kernels take it, `stream_chunk_words` per chunk.
        """
        lo = self.layout
        return kops.stream_chunk_blocks(
            lo.n_blocks, lo.block_words,
            threshold_words=self.stream_threshold_words,
            chunk_words=self.stream_chunk_words)

    def coll_chunks(self) -> int:
        """Slices per syndrome collective when the commit streams.

        Matches the kernel chunking scale so the per-chunk syndrome delta
        overlaps the all-to-all of the previous slice; capped at 8 —
        beyond that the per-launch latency dominates the overlap win.
        """
        if self.stream_chunk() is None:
            return 1
        return max(1, min(
            8, self.layout.seg_words // max(1, self.stream_chunk_words)))

    # -- init ------------------------------------------------------------------

    def init(self, state: PyTree, *, jit: bool = True) -> ProtectedState:
        lo, ax = self.layout, self.data_axis
        mode, r = self.mode, self.redundancy

        def _init(state):
            row = layout_mod.flatten_row(lo, state)
            outs = {}
            if mode.has_parity:
                outs["synd"] = self._pack(
                    parity_mod.build_syndromes(row, r, ax))
            if mode.has_cksums:
                cks = ck.block_checksums(row, lo.block_words)
                outs["cksums"] = self._pack(cks)
                outs["digest"] = self._pack(ck.combine(cks, lo.block_words))
            elif mode.has_parity:
                outs["digest"] = self._pack(ck.digest(row, lo.block_words))
            if mode.has_parity or mode.has_cksums:
                outs["row"] = self._pack(row)
            return outs

        out_specs = {}
        if mode.has_parity:
            out_specs["synd"] = self._zone_spec
        if mode.has_cksums:
            out_specs["cksums"] = self._zone_spec
        if mode.has_parity or mode.has_cksums:
            out_specs["digest"] = self._zone_spec
            out_specs["row"] = self._zone_spec
        fn = self._smap(_init, in_specs=(self.state_specs,),
                        out_specs=out_specs)
        if jit:
            fn = jax.jit(fn)
        outs = fn(state)
        replica = jax.tree.map(jnp.copy, state) if mode.has_replica else None
        log = redolog.make(self.log_capacity) if mode.has_log else None
        return ProtectedState(
            state=state, synd=outs.get("synd"), cksums=outs.get("cksums"),
            digest=outs.get("digest"), replica=replica, log=log,
            step=jnp.zeros((), U32), row=outs.get("row"))

    # -- commit ------------------------------------------------------------------

    def make_commit(self, dirty_pages: Optional[Sequence[int]] = None,
                    verify_old: bool = False):
        """Build the jitted commit function (single-sweep engine).

        `dirty_pages`: static page-index list when the update's footprint is
        known (decode-time KV appends); None = whole state dirty (train).
        `verify_old`: verify the old row's checksums before committing (the
        paper's verify-at-micro-buffer-open), abort on mismatch.

        The engine touches HBM once per operand.  The cached row
        (`ProtectedState.row`) stands in for the old state, so the old
        pytree is never re-flattened; the digest folds from per-block
        Fletcher terms instead of re-reading the row.  Per path:

          bulk, no verify   — old is not read at all: one fused checksum
            sweep over new + the parity reduce-scatter of new.
          bulk, verify      — old must be swept once anyway, so the fused
            kernel emits verify + parity delta + new checksums from one
            pass over (old, new) and parity consumes the delta
            (parity ^ rs(delta) == rs(new) under the XOR invariant).
          patch (dirty set) — the new row is word-spliced from the cache
            (no full re-flatten) and one fused sweep over the dirty pages
            yields [verify +] delta + checksums; the delta feeds the
            owner-scatter parity patch.  Cost ∝ modified range.

        With `verify_old` the old row is re-flattened from the live state
        (a scribble lives in the state; a clean cache would launder it);
        verification covers the full row on the bulk path and the opened
        (dirty) pages on the patch path.
        """
        lo, ax, mode = self.layout, self.data_axis, self.mode
        r = self.redundancy
        thresh = self.hybrid_threshold
        bw = lo.block_words
        # static path choice, the paper's atomic-XOR/plain-XOR crossover
        meta_only = dirty_pages is not None and len(dirty_pages) == 0
        patch = (dirty_pages is not None and not meta_only
                 and len(dirty_pages) / lo.n_blocks < thresh)
        dirty_leaves = (layout_mod.leaves_for_pages(lo, dirty_pages)
                        if (meta_only or patch) else None)
        dirty_idx = (np.asarray(list(dirty_pages), np.int32)
                     if patch else None)
        # flat-vs-streamed is a static program choice: large rows stream
        # through the double-buffered kernels and chunk the syndrome
        # collective to overlap weighting with the wire; the patch path
        # is below-threshold by construction and always stays flat
        scb = self.stream_chunk()
        cc = self.coll_chunks()

        def _protect(state_old, row_cache, synd, cksums, digest,
                     state_new, canary_ok):
            synd_l = self._unpack(synd) if synd is not None else None
            cksums_l = self._unpack(cksums) if cksums is not None else None
            digest_l = self._unpack(digest)
            # this rank's syndrome coefficient vector (g^(k·me))_k; None
            # for r=1 keeps the single-parity kernels and their program
            coeffs = (gf.rank_syndrome_coeffs(self.group_size, r, ax)
                      if r > 1 else None)
            row_old = (layout_mod.flatten_row(lo, state_old) if verify_old
                       else self._unpack(row_cache))
            if meta_only or patch:
                row_new = layout_mod.update_row(lo, row_old, state_new,
                                                dirty_leaves)
            else:
                row_new = layout_mod.flatten_row(lo, state_new)
            ok = canary_ok
            new_synd, new_cksums, new_digest = synd_l, cksums_l, digest_l
            if meta_only:
                pass          # the paper's "free" metadata-only transaction
            elif patch:
                idx = jnp.asarray(dirty_idx)
                old_pages = parity_mod.gather_pages(row_old, idx, bw)
                new_pages = parity_mod.gather_pages(row_new, idx, bw)
                if mode.has_cksums:
                    if verify_old:
                        sdelta_p, fresh, bad = kops.fused_verify_commit_s(
                            old_pages, new_pages, cksums_l[idx], coeffs)
                        ok = _zone_clean(ok, bad, ax)
                    else:
                        sdelta_p, fresh = kops.fused_commit_s(
                            old_pages, new_pages, coeffs)
                    new_cksums = ck.set_blocks(cksums_l, fresh, idx)
                    new_digest = ck.combine(new_cksums, bw)
                else:
                    sdelta_p, fresh, old_ck = kops.fused_commit_old_terms_s(
                        old_pages, new_pages, coeffs)
                    new_digest = ck.update_digest(digest_l, old_ck, fresh,
                                                  idx, lo.n_blocks, bw)
                if mode.has_parity:
                    new_synd = parity_mod.patch_syndrome_delta(
                        synd_l, sdelta_p, idx, lo, ax)
            else:
                pages_new = parity_mod.page_view(row_new, bw)
                dig_new = None
                if verify_old and mode.has_cksums:
                    # old must be swept for verify anyway: the fused kernel
                    # shares that read with all r syndrome deltas, and the
                    # stack consumes them (S ^ rs(sdelta) == rs-stack(new))
                    pages_old = parity_mod.page_view(row_old, bw)
                    if scb is None:
                        sdelta, fresh, bad = kops.fused_verify_commit_s(
                            pages_old, pages_new, cksums_l, coeffs)
                    else:
                        sdelta, fresh, bad, dig_new = (
                            kops.fused_verify_commit_s_stream(
                                pages_old, pages_new, cksums_l, coeffs,
                                chunk_blocks=scb))
                    ok = _zone_clean(ok, bad, ax)
                    if mode.has_parity:
                        new_synd = parity_mod.apply_sdelta(
                            synd_l, sdelta.reshape(r, -1), ax, chunks=cc)
                else:
                    # without verify the old row is not read at all: a
                    # delta here would cost a write+read of a row-sized
                    # buffer for nothing — reduce-scatter the new row
                    if scb is None:
                        fresh = kops.fletcher_blocks(pages_new)
                    else:
                        fresh, dig_new = kops.fletcher_stream(
                            pages_new, chunk_blocks=scb)
                    if mode.has_parity:
                        new_synd = parity_mod.build_syndromes(row_new, r,
                                                              ax, chunks=cc)
                if mode.has_cksums:
                    new_cksums = fresh
                # streamed sweeps fold the digest into the loop carry
                # (bit-identical to the combine over the term table)
                new_digest = (ck.combine(fresh, bw) if dig_new is None
                              else dig_new)
            outs = {"ok": ok,
                    "row": self._pack(jnp.where(ok, row_new, row_old)),
                    "digest": self._pack(jnp.where(ok, new_digest,
                                                   digest_l))}
            if mode.has_parity:
                outs["synd"] = self._pack(jnp.where(ok, new_synd, synd_l))
            if mode.has_cksums:
                outs["cksums"] = self._pack(
                    jnp.where(ok, new_cksums, cksums_l))
            return outs

        out_specs = {"ok": P(), "row": self._zone_spec,
                     "digest": self._zone_spec}
        if mode.has_parity:
            out_specs["synd"] = self._zone_spec
        if mode.has_cksums:
            out_specs["cksums"] = self._zone_spec
        protect = self._smap(
            _protect,
            in_specs=(self.state_specs, self._zone_spec, self._zone_spec,
                      self._zone_spec, self._zone_spec,
                      self.state_specs, P()),
            out_specs=out_specs)

        def commit(prot: ProtectedState, state_new: PyTree, *,
                   data_cursor=0, rng_key=None, canary_ok=True):
            step = prot.step + U32(1)
            canary_ok = jnp.asarray(canary_ok, bool)
            log = prot.log
            digest_for_log = jnp.zeros((2,), U32)
            new_row = prot.row
            if mode.has_parity or mode.has_cksums:
                outs = protect(prot.state, prot.row, prot.synd,
                               prot.cksums, prot.digest,
                               state_new, canary_ok)
                ok = outs["ok"]
                new_row = outs["row"]
                new_synd = outs.get("synd", prot.synd)
                new_cksums = outs.get("cksums", prot.cksums)
                new_digest = outs["digest"]
                digest_for_log = new_digest.reshape(-1, 2)[0]
            else:
                ok = canary_ok
                new_synd, new_cksums, new_digest = (prot.synd,
                                                    prot.cksums,
                                                    prot.digest)
            # paper ordering: log record (replicated) persists before object
            # writes; the commit mark follows the protected update.
            if mode.has_log:
                if rng_key is None:
                    rng_key = jax.random.PRNGKey(0)
                log = redolog.append(prot.log, step, data_cursor, rng_key,
                                     digest_for_log)
                log = tree_select(ok, redolog.commit_mark(log, step), log)
            new_state = tree_select(ok, state_new, prot.state)
            replica = prot.replica
            if mode.has_replica:
                replica = tree_select(ok, jax.tree.map(jnp.copy, state_new),
                                      prot.replica)
            return ProtectedState(
                state=new_state, synd=new_synd, cksums=new_cksums,
                digest=new_digest, replica=replica, log=log,
                step=jnp.where(ok, step, prot.step), row=new_row), ok

        return commit

    def commit(self, prot, state_new, *, dirty_pages=None, verify_old=False,
               donate=False, **kw):
        """Cached-jit commit entry point.

        Distinct dirty-page sets (and the verify flag) key distinct
        compiled commits — a previous version folded `_dirty_key` into the
        cache key but always built the no-dirty-pages commit, silently
        sharing one stale program across different footprints.

        `donate=True` donates `prot` into its successor (row, parity,
        cksums, digest, log and state reuse their buffers in place —
        allocation-free steady state); the caller must then drop the old
        `prot` and keep only the returned one.
        """
        return self.commit_program(
            dirty_pages=dirty_pages, verify_old=verify_old,
            donate=donate)(prot, state_new, **kw)

    def commit_program(self, *, dirty_pages=None, verify_old=False,
                       donate=False):
        """The cached compiled commit for one (dirty set, verify, donate)
        key — what `commit` dispatches and what the Pool facade routes
        through (benchmarks lower it to assert facade == direct bytes)."""
        key = ("commit",
               tuple(int(p) for p in dirty_pages)
               if dirty_pages is not None else None,
               bool(verify_old), bool(donate))
        if key not in self._jit_cache:
            # the canary verdict is host-known before dispatch: static,
            # so the all-clear program folds its abort select-chains away
            # (an abort compiles the cheap no-op variant once)
            self._jit_cache[key] = jax.jit(
                self.make_commit(dirty_pages=dirty_pages,
                                 verify_old=verify_old),
                donate_argnums=(0,) if donate else (),
                static_argnames=("canary_ok",))
        return self._jit_cache[key]

    # -- scrub -------------------------------------------------------------------

    def make_scrub(self):
        """One fused scrub program: a single flatten of the live state
        feeds the checksum verify, the parity invariant check AND a
        row-cache divergence check (`row == flatten(state)` — nearly free
        with the row already in hand, and it catches a cache gone stale
        before a commit would trust it as the old operand).  All outputs
        land in one dict so the Scrubber fetches them with a single
        device_get."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode

        def _scrub(state, row_cache, synd, cksums):
            row = layout_mod.flatten_row(lo, state)
            out = {}
            if mode.has_cksums:
                bad = ck.verify_blocks(row, self._unpack(cksums),
                                       lo.block_words)
                out["bad_pages"] = self._pack(bad)
            if mode.has_parity:
                # every syndrome invariant from one overlapped collective
                out["synd_ok"] = parity_mod.verify_syndromes(
                    row, self._unpack(synd), ax)
            if mode.has_parity or mode.has_cksums:
                same = jnp.all(row == self._unpack(row_cache))
                out["row_cache_ok"] = (
                    lax.pmin(same.astype(jnp.int32), self.axis_names) > 0)
            return out

        out_specs = {}
        if mode.has_cksums:
            out_specs["bad_pages"] = self._zone_spec
        if mode.has_parity:
            out_specs["synd_ok"] = P()
        if mode.has_parity or mode.has_cksums:
            out_specs["row_cache_ok"] = P()
        fn = self._smap(_scrub, in_specs=(self.state_specs, self._zone_spec,
                                          self._zone_spec, self._zone_spec),
                        out_specs=out_specs)

        def scrub(prot: ProtectedState):
            return fn(prot.state, prot.row, prot.synd, prot.cksums)

        return scrub

    def scrub(self, prot):
        if "scrub" not in self._jit_cache:
            self._jit_cache["scrub"] = jax.jit(self.make_scrub())
        return self._jit_cache["scrub"](prot)

    def make_local_scrub(self):
        """Rank-local pre-check: no full-row collective anywhere.

        The global scrub's dominant cost is the syndrome reduce-scatter
        (r full-row weighted collectives).  This program verifies the
        same three surfaces with zone traffic of O(r·G) *words*:

          * this rank's state blocks against the checksum table — pure
            local compute, catches scribbles exactly like the global
            scrub does — reduced on device to a replicated mismatch
            *count* (the pre-check only decides suspect-or-not; block
            locations are the escalated global scrub's job);
          * the cached row against the live state — local compare;
          * this rank's syndrome segments against everyone's rows via a
            *folded* syndrome: the stacked-plane kernel weights the row
            into all r planes from one read (kernels/ops.syndrome_scale
            — the same device clmul the commit sweeps use, never host
            GF math), each rank XOR-folds per (syndrome, owner-segment)
            into an (r, G) word matrix, one tiny XOR all-reduce
            combines them (fold commutes with the XOR sum across
            ranks), and each owner compares the fold of its stored
            segments.  A fold catches any single corruption; only
            colliding corruptions that cancel in the fold escape to the
            global scrub — which is why this is the cheap pre-check,
            not a replacement.

        Every output is a replicated scalar (bad_count / synd_ok /
        row_cache_ok), so `Scrubber.precheck` fetches ONE device_get of
        a verdict — no row-sized or table-sized host transfer.
        """
        lo, ax = self.layout, self.data_axis
        mode, r, g = self.mode, self.redundancy, self.group_size

        def _local(state, row_cache, synd, cksums):
            row = layout_mod.flatten_row(lo, state)
            out = {}
            if mode.has_cksums:
                bad = ck.verify_blocks(row, self._unpack(cksums),
                                       lo.block_words)
                out["bad_count"] = lax.psum(
                    jnp.sum(bad.astype(jnp.uint32)), self.axis_names)
            if mode.has_parity:
                synd_l = self._unpack(synd)
                coeffs = (gf.rank_syndrome_coeffs(g, r, ax)
                          if r > 1 else None)
                weighted = kops.syndrome_scale(row, coeffs)
                segs = weighted.reshape(r, g, -1)
                folds = coll.xor_fold(segs, axis=2)          # (r, G)
                want = coll.xor_all_reduce(folds, ax)        # (r, G)
                me = lax.axis_index(ax)
                mine = coll.xor_fold(synd_l, axis=1)         # (r,)
                ok = mine == want[:, me]
                out["synd_ok"] = (
                    lax.pmin(ok.astype(jnp.int32), ax) > 0)
            if mode.has_parity or mode.has_cksums:
                same = jnp.all(row == self._unpack(row_cache))
                out["row_cache_ok"] = (
                    lax.pmin(same.astype(jnp.int32), self.axis_names) > 0)
            return out

        out_specs = {}
        if mode.has_cksums:
            out_specs["bad_count"] = P()
        if mode.has_parity:
            out_specs["synd_ok"] = P()
        if mode.has_parity or mode.has_cksums:
            out_specs["row_cache_ok"] = P()
        fn = self._smap(_local, in_specs=(self.state_specs, self._zone_spec,
                                          self._zone_spec, self._zone_spec),
                        out_specs=out_specs)

        def local_scrub(prot: ProtectedState):
            return fn(prot.state, prot.row, prot.synd, prot.cksums)

        return local_scrub

    def local_scrub(self, prot):
        if "local_scrub" not in self._jit_cache:
            self._jit_cache["local_scrub"] = jax.jit(self.make_local_scrub())
        return self._jit_cache["local_scrub"](prot)

    # -- recovery ------------------------------------------------------------------

    def make_recover_rank(self):
        """Online reconstruction of one lost data-rank's entire row."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode

        def _recover(state, synd, cksums, lost):
            # flatten the live (damaged) state — the row cache is rebuilt,
            # never trusted, across recovery
            row = layout_mod.flatten_row(lo, state)
            rebuilt = parity_mod.reconstruct_row(
                row, self._unpack(synd)[0], lost, ax)
            me = lax.axis_index(ax)
            row_out = jnp.where(me == lost, rebuilt, row)
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums),
                                       lo.block_words)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        out_specs = {"state": self.state_specs, "ok": P(),
                     "row": self._zone_spec}
        fn = self._smap(_recover,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec, P()),
                        out_specs=out_specs)

        def recover(prot: ProtectedState, lost_rank):
            out = fn(prot.state, prot.synd, prot.cksums,
                     jnp.asarray(lost_rank, jnp.int32))
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return recover

    def recover_rank(self, prot, lost_rank):
        if "recover" not in self._jit_cache:
            self._jit_cache["recover"] = jax.jit(self.make_recover_rank())
        return self._jit_cache["recover"](prot, lost_rank)

    def make_recover_e(self, lost_ranks):
        """Online reconstruction of e <= r lost data-ranks' rows.

        The erasure set is static (recovery is rare; one compiled
        program per set) so the Vandermonde inverse folds in as exact
        host integers.  Also the losses-with-outstanding-scribble path:
        name the scribbled rank as an extra loss.
        """
        lo, ax = self.layout, self.data_axis
        mode = self.mode
        ranks = tuple(sorted(int(a) for a in lost_ranks))
        e = len(ranks)
        assert len(set(ranks)) == e, (
            f"erasure recovery needs distinct ranks, got {ranks}")
        if e > self.redundancy:
            raise RuntimeError(
                f"syndrome budget exhausted: {e} simultaneous losses "
                f"(ranks {list(ranks)}) exceed redundancy="
                f"{self.redundancy} — a zone solves at most r losses "
                "online (raise ProtectConfig.redundancy, or restore "
                "from checkpoint and re-protect)")

        def _recover(state, synd, cksums):
            # flatten the live (damaged) state — the row cache is rebuilt,
            # never trusted, across recovery
            row = layout_mod.flatten_row(lo, state)
            rebuilt = parity_mod.reconstruct_e(
                row, self._unpack(synd), ranks, ax)
            me = lax.axis_index(ax)
            row_out = row
            for a, row_a in zip(ranks, rebuilt):
                row_out = jnp.where(me == a, row_a, row_out)
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums),
                                       lo.block_words)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        out_specs = {"state": self.state_specs, "ok": P(),
                     "row": self._zone_spec}
        fn = self._smap(_recover,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec),
                        out_specs=out_specs)

        def recover(prot: ProtectedState):
            out = fn(prot.state, prot.synd, prot.cksums)
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return recover

    def recover_e(self, prot, lost_ranks):
        ranks = tuple(sorted(int(a) for a in lost_ranks))
        key = ("recover_e", ranks)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(self.make_recover_e(ranks))
        return self._jit_cache[key](prot)

    def recover_two(self, prot, lost_a, lost_b):
        """Back-compat alias: the e=2 erasure recovery."""
        a, b = sorted((int(lost_a), int(lost_b)))
        assert a != b, "double-loss recovery needs two distinct ranks"
        return self.recover_e(prot, (a, b))

    def make_repair_pages(self, n_pages: int):
        """Targeted scribble repair: fix `n_pages` (rank, page) locations."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode
        bw = lo.block_words
        pages_per_seg = lo.seg_words // bw

        def _repair(state, synd, cksums, bad_rank, bad_page):
            row = layout_mod.flatten_row(lo, state)
            pages = parity_mod.page_view(row, bw)
            me = lax.axis_index(ax)
            mine_bad = (bad_rank == me)                      # (k,)
            contents = pages[bad_page]                       # (k, bw)
            contrib = jnp.where(mine_bad[:, None], 0, contents)
            others = coll.xor_all_reduce(contrib, ax)        # (k, bw)
            # broadcast each bad page's parity (the stack's S_0 plane)
            # from its owner via the XOR trick
            owner = bad_page // pages_per_seg
            local_idx = bad_page % pages_per_seg
            seg_pages = self._unpack(synd)[0].reshape(pages_per_seg, bw)
            par_contrib = jnp.where((owner == me)[:, None],
                                    seg_pages[local_idx], 0)
            par_pages = coll.xor_all_reduce(par_contrib, ax)  # (k, bw)
            fixed = others ^ par_pages
            new_pages = jnp.where(mine_bad[:, None], fixed, contents)
            row_out = pages.at[bad_page].set(new_pages).reshape(-1)
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums), bw)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        fn = self._smap(_repair,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec, P(), P()),
                        out_specs={"state": self.state_specs, "ok": P(),
                                   "row": self._zone_spec})

        def repair(prot: ProtectedState, bad_rank, bad_page):
            bad_rank = jnp.asarray(bad_rank, jnp.int32).reshape(n_pages)
            bad_page = jnp.asarray(bad_page, jnp.int32).reshape(n_pages)
            out = fn(prot.state, prot.synd, prot.cksums, bad_rank, bad_page)
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return repair

    def repair_pages(self, prot, bad_rank, bad_page):
        n = int(np.asarray(bad_rank).size)
        key = ("repair", n)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(self.make_repair_pages(n))
        return self._jit_cache[key](prot, bad_rank, bad_page)

    # -- introspection ---------------------------------------------------------

    def overhead_report(self) -> dict:
        rep = self.layout.overhead_report()
        rep["mode"] = self.mode.value
        rep["group_size"] = self.group_size
        r = self.redundancy if self.mode.has_parity else 0
        rep["redundancy"] = r
        # every syndrome is one seg_words row per rank — same bytes as P —
        # so the stack's storage tax is exactly r x the parity fraction
        rep["syndrome_rows"] = r
        rep["syndrome_bytes_per_rank"] = r * rep["parity_bytes_per_rank"]
        rep["syndrome_fraction"] = r * rep["parity_fraction"]
        rep["syndrome_r_over_p"] = float(r) if r else 0.0
        if self.mode.has_replica:
            rep["protection_fraction"] = 1.0
        else:
            frac = rep["syndrome_fraction"]
            if self.mode.has_cksums:
                frac += rep["checksum_fraction"]
            rep["protection_fraction"] = frac
        return rep
