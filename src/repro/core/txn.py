"""Fault-tolerant transactions over distributed state (Pangolin §3.4).

The `Protector` wraps a sharded state pytree (params, optimizer moments, KV
caches, ...) with Pangolin's protection stack and exposes the transactional
API:

    prot   = protector.init(state)                      # build parity+checksums
    prot', ok = protector.commit(prot, new_state, ...)  # transactional update
    report = protector.scrub(prot)                      # periodic verification
    prot'  = protector.recover_rank(prot, lost)         # online media recovery
    prot'  = protector.repair_pages(prot, rank, pages)  # online scribble repair

Commit pipeline (paper order: redo log -> objects -> parity, idempotent):
  1. redo record appended + commit-marked (replicated),
  2. canary verified (abort without touching state on mismatch),
  3. object checksums refreshed (incremental where dirty pages are known),
  4. parity updated via the hybrid patch/bulk scheme,
  5. the new state replaces the old (functional swap).

Protection-mode ladder mirrors the paper's evaluation (Table 2):
  NONE   ~ Pangolin baseline (micro-buffering + canary only)
  ML     ~ + metadata/redo-log replication
  MLP    ~ + XOR parity (media-error recovery; compare w/ REPLICA)
  MLPC   ~ + object checksums (scribble detection)
  REPLICA~ libpmemobj's replicated mode (2x storage, the paper's baseline)
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import checksum as ck
from repro.core import gf
from repro.core import layout as layout_mod
from repro.core import parity as parity_mod
from repro.core import redolog
from repro.dist import collectives as coll
from repro.kernels import ops as kops

PyTree = Any
U32 = jnp.uint32


class Mode(enum.Enum):
    NONE = "none"          # micro-buffering + canary only (pgl baseline)
    ML = "ml"              # + redo-log/metadata replication
    MLP = "mlp"            # + parity
    MLPC = "mlpc"          # + checksums
    REPLICA = "replica"    # full replica (Pmemobj-R analogue)
    # dual-parity levels (beyond paper): a second, GF(2^32) Reed-Solomon
    # syndrome Q alongside XOR parity P — any TWO simultaneous rank
    # losses in a zone reconstruct (core/gf.py, parity.reconstruct_two)
    MLP2 = "mlp2"          # + Q syndrome (no checksums)
    MLPC2 = "mlpc2"        # + Q syndrome + checksums

    @property
    def has_parity(self) -> bool:
        return self in (Mode.MLP, Mode.MLPC, Mode.MLP2, Mode.MLPC2)

    @property
    def has_cksums(self) -> bool:
        return self in (Mode.MLPC, Mode.MLPC2)

    @property
    def has_qparity(self) -> bool:
        return self in (Mode.MLP2, Mode.MLPC2)

    @property
    def has_log(self) -> bool:
        return self in (Mode.ML, Mode.MLP, Mode.MLPC, Mode.MLP2,
                        Mode.MLPC2)

    @property
    def has_replica(self) -> bool:
        return self is Mode.REPLICA

    @property
    def redundancy(self) -> int:
        """Simultaneous rank losses a zone survives online."""
        return 2 if self.has_qparity else (1 if self.has_parity else 0)


def resolve_mode(mode, redundancy: int = 1) -> Mode:
    """Map (base mode, ProtectConfig.redundancy) onto the Mode ladder.

    redundancy=1 returns the base mode unchanged; redundancy=2 promotes a
    parity mode to its dual-parity level (mlp -> mlp2, mlpc -> mlpc2).
    """
    m = mode if isinstance(mode, Mode) else Mode(mode)
    r = int(redundancy)
    if r == 1:
        return m
    if r == 2:
        if m is Mode.MLP:
            return Mode.MLP2
        if m is Mode.MLPC:
            return Mode.MLPC2
        if m.has_qparity:
            return m
        raise ValueError(
            f"redundancy=2 needs a parity mode (mlp or mlpc), got "
            f"'{m.value}' — the Q syndrome extends parity, it cannot "
            "replace it")
    raise ValueError(f"redundancy must be 1 or 2, got {redundancy}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ProtectedState:
    state: PyTree
    parity: Optional[jax.Array]      # (*mesh_dims, seg_words) u32
    cksums: Optional[jax.Array]      # (*mesh_dims, n_blocks, 2) u32
    digest: Optional[jax.Array]      # (*mesh_dims, 2) u32 whole-row digest
    replica: Optional[PyTree]
    log: Optional[redolog.RedoLog]
    step: jax.Array                  # scalar u32, replicated
    # Cached flattened word row, (*mesh_dims, row_words) u32.  Invariant:
    # row == flatten_row(layout, state) whenever protection is active, so
    # commits diff rows directly instead of re-flattening the whole state
    # every step.  Rebuilt (never trusted) by recovery and repair.
    row: Optional[jax.Array] = None
    # Q syndrome segment, (*mesh_dims, seg_words) u32 — dual-parity modes
    # only (Mode.has_qparity).  Q = XOR_i g^i·row_i over GF(2^32); with P
    # it solves any two simultaneous rank losses (core/gf.py).
    qparity: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.state, self.parity, self.cksums, self.digest,
                 self.replica, self.log, self.step, self.row,
                 self.qparity), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def tree_select(pred, on_true: PyTree, on_false: PyTree) -> PyTree:
    return jax.tree.map(lambda t, f: jnp.where(pred, t, f), on_true, on_false)


def _zone_clean(ok, bad, axis_name):
    """AND `no block is bad` into ok, agreed across the zone (pmin)."""
    ok = jnp.logical_and(ok, jnp.logical_not(jnp.any(bad)))
    return lax.pmin(ok.astype(jnp.int32), axis_name) > 0


def _spec_leaf(x):
    return isinstance(x, P)


class Protector:
    """Builds jitted, shard_map'd protection operations for one state layout."""

    def __init__(self, mesh: Mesh, abstract_state: PyTree, state_specs: PyTree,
                 *, data_axis: str = "data", mode: Mode = Mode.MLPC,
                 block_words: int = layout_mod.PAGE_WORDS,
                 hybrid_threshold: float = 0.5,
                 log_capacity: int = 64):
        self.mesh = mesh
        self.mode = mode
        self.data_axis = data_axis
        self.axis_names = tuple(mesh.axis_names)
        self.n_axes = len(self.axis_names)
        self.group_size = mesh.shape[data_axis]
        self.hybrid_threshold = hybrid_threshold
        self.log_capacity = log_capacity
        self.state_specs = state_specs

        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_specs, is_leaf=_spec_leaf)
        self.layout = layout_mod.build_layout(
            abstract_state, self.group_size, shardings,
            block_words=block_words)

        self._zone_spec = P(*self.axis_names)
        self._mesh_dims = tuple(mesh.shape[a] for a in self.axis_names)
        self._jit_cache: dict = {}

    # -- sharding helpers -----------------------------------------------------

    def parity_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self._zone_spec)

    def abstract_protected(self, abstract_state: PyTree) -> ProtectedState:
        """ShapeDtypeStruct ProtectedState (dry-run: no allocation)."""
        lo, mode = self.layout, self.mode
        zdims = self._mesh_dims

        def sds(shape, dtype=U32):
            return jax.ShapeDtypeStruct(shape, dtype)

        parity = sds(zdims + (lo.seg_words,)) if mode.has_parity else None
        qparity = sds(zdims + (lo.seg_words,)) if mode.has_qparity else None
        cksums = sds(zdims + (lo.n_blocks, 2)) if mode.has_cksums else None
        dig = (sds(zdims + (2,))
               if (mode.has_parity or mode.has_cksums) else None)
        row = (sds(zdims + (lo.row_words,))
               if (mode.has_parity or mode.has_cksums) else None)
        replica = (jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), abstract_state)
            if mode.has_replica else None)
        log = (jax.eval_shape(lambda: redolog.make(self.log_capacity))
               if mode.has_log else None)
        return ProtectedState(state=abstract_state, parity=parity,
                              cksums=cksums, digest=dig, replica=replica,
                              log=log, step=sds((), U32), row=row,
                              qparity=qparity)

    def protected_specs(self) -> ProtectedState:
        """PartitionSpec tree matching ProtectedState."""
        mode = self.mode
        z = self._zone_spec
        log = (jax.tree.map(lambda _: P(),
                            jax.eval_shape(lambda: redolog.make(
                                self.log_capacity)))
               if mode.has_log else None)
        return ProtectedState(
            state=self.state_specs,
            parity=z if mode.has_parity else None,
            cksums=z if mode.has_cksums else None,
            digest=z if (mode.has_parity or mode.has_cksums) else None,
            replica=self.state_specs if mode.has_replica else None,
            log=log, step=P(),
            row=z if (mode.has_parity or mode.has_cksums) else None,
            qparity=z if mode.has_qparity else None)

    def _pack(self, x: jax.Array) -> jax.Array:
        """Local per-rank value -> shard_map output layout (leading 1s)."""
        return x.reshape((1,) * self.n_axes + x.shape)

    def _unpack(self, x: jax.Array) -> jax.Array:
        return x.reshape(x.shape[self.n_axes:])

    def _smap(self, f, in_specs, out_specs):
        return shard_map(f, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    # -- init ------------------------------------------------------------------

    def init(self, state: PyTree, *, jit: bool = True) -> ProtectedState:
        lo, ax = self.layout, self.data_axis
        mode = self.mode

        def _init(state):
            row = layout_mod.flatten_row(lo, state)
            outs = {}
            if mode.has_parity:
                outs["parity"] = self._pack(parity_mod.build_parity(row, ax))
            if mode.has_qparity:
                outs["qparity"] = self._pack(
                    parity_mod.build_qparity(row, ax))
            if mode.has_cksums:
                cks = ck.block_checksums(row, lo.block_words)
                outs["cksums"] = self._pack(cks)
                outs["digest"] = self._pack(ck.combine(cks, lo.block_words))
            elif mode.has_parity:
                outs["digest"] = self._pack(ck.digest(row, lo.block_words))
            if mode.has_parity or mode.has_cksums:
                outs["row"] = self._pack(row)
            return outs

        out_specs = {}
        if mode.has_parity:
            out_specs["parity"] = self._zone_spec
        if mode.has_qparity:
            out_specs["qparity"] = self._zone_spec
        if mode.has_cksums:
            out_specs["cksums"] = self._zone_spec
        if mode.has_parity or mode.has_cksums:
            out_specs["digest"] = self._zone_spec
            out_specs["row"] = self._zone_spec
        fn = self._smap(_init, in_specs=(self.state_specs,),
                        out_specs=out_specs)
        if jit:
            fn = jax.jit(fn)
        outs = fn(state)
        replica = jax.tree.map(jnp.copy, state) if mode.has_replica else None
        log = redolog.make(self.log_capacity) if mode.has_log else None
        return ProtectedState(
            state=state, parity=outs.get("parity"), cksums=outs.get("cksums"),
            digest=outs.get("digest"), replica=replica, log=log,
            step=jnp.zeros((), U32), row=outs.get("row"),
            qparity=outs.get("qparity"))

    # -- commit ------------------------------------------------------------------

    def make_commit(self, dirty_pages: Optional[Sequence[int]] = None,
                    verify_old: bool = False):
        """Build the jitted commit function (single-sweep engine).

        `dirty_pages`: static page-index list when the update's footprint is
        known (decode-time KV appends); None = whole state dirty (train).
        `verify_old`: verify the old row's checksums before committing (the
        paper's verify-at-micro-buffer-open), abort on mismatch.

        The engine touches HBM once per operand.  The cached row
        (`ProtectedState.row`) stands in for the old state, so the old
        pytree is never re-flattened; the digest folds from per-block
        Fletcher terms instead of re-reading the row.  Per path:

          bulk, no verify   — old is not read at all: one fused checksum
            sweep over new + the parity reduce-scatter of new.
          bulk, verify      — old must be swept once anyway, so the fused
            kernel emits verify + parity delta + new checksums from one
            pass over (old, new) and parity consumes the delta
            (parity ^ rs(delta) == rs(new) under the XOR invariant).
          patch (dirty set) — the new row is word-spliced from the cache
            (no full re-flatten) and one fused sweep over the dirty pages
            yields [verify +] delta + checksums; the delta feeds the
            owner-scatter parity patch.  Cost ∝ modified range.

        With `verify_old` the old row is re-flattened from the live state
        (a scribble lives in the state; a clean cache would launder it);
        verification covers the full row on the bulk path and the opened
        (dirty) pages on the patch path.
        """
        lo, ax, mode = self.layout, self.data_axis, self.mode
        thresh = self.hybrid_threshold
        bw = lo.block_words
        # static path choice, the paper's atomic-XOR/plain-XOR crossover
        meta_only = dirty_pages is not None and len(dirty_pages) == 0
        patch = (dirty_pages is not None and not meta_only
                 and len(dirty_pages) / lo.n_blocks < thresh)
        dirty_leaves = (layout_mod.leaves_for_pages(lo, dirty_pages)
                        if (meta_only or patch) else None)
        dirty_idx = (np.asarray(list(dirty_pages), np.int32)
                     if patch else None)

        def _protect(state_old, row_cache, parity, qparity, cksums, digest,
                     state_new, canary_ok):
            parity_l = self._unpack(parity) if parity is not None else None
            qparity_l = (self._unpack(qparity)
                         if qparity is not None else None)
            cksums_l = self._unpack(cksums) if cksums is not None else None
            digest_l = self._unpack(digest)
            # this rank's Q Vandermonde coefficient g^me (dual parity)
            coeff = (gf.rank_coeff(self.group_size, ax)
                     if mode.has_qparity else None)
            row_old = (layout_mod.flatten_row(lo, state_old) if verify_old
                       else self._unpack(row_cache))
            if meta_only or patch:
                row_new = layout_mod.update_row(lo, row_old, state_new,
                                                dirty_leaves)
            else:
                row_new = layout_mod.flatten_row(lo, state_new)
            ok = canary_ok
            new_parity, new_cksums, new_digest = parity_l, cksums_l, digest_l
            new_qparity = qparity_l
            if meta_only:
                pass          # the paper's "free" metadata-only transaction
            elif patch:
                idx = jnp.asarray(dirty_idx)
                old_pages = parity_mod.gather_pages(row_old, idx, bw)
                new_pages = parity_mod.gather_pages(row_new, idx, bw)
                qdelta_p = None
                if mode.has_cksums:
                    if verify_old:
                        if mode.has_qparity:
                            delta_p, qdelta_p, fresh, bad = \
                                kops.fused_verify_commit_pq(
                                    old_pages, new_pages, cksums_l[idx],
                                    coeff)
                        else:
                            delta_p, fresh, bad = kops.fused_verify_commit(
                                old_pages, new_pages, cksums_l[idx])
                        ok = _zone_clean(ok, bad, ax)
                    elif mode.has_qparity:
                        delta_p, qdelta_p, fresh = kops.fused_commit_pq(
                            old_pages, new_pages, coeff)
                    else:
                        delta_p, fresh = kops.fused_commit(old_pages,
                                                           new_pages)
                    new_cksums = ck.set_blocks(cksums_l, fresh, idx)
                    new_digest = ck.combine(new_cksums, bw)
                else:
                    if mode.has_qparity:
                        delta_p, qdelta_p, fresh, old_ck = \
                            kops.fused_commit_old_terms_pq(
                                old_pages, new_pages, coeff)
                    else:
                        delta_p, fresh, old_ck = kops.fused_commit_old_terms(
                            old_pages, new_pages)
                    new_digest = ck.update_digest(digest_l, old_ck, fresh,
                                                  idx, lo.n_blocks, bw)
                if mode.has_parity:
                    new_parity = parity_mod.patch_parity_delta(
                        parity_l, delta_p, idx, lo, ax)
                if mode.has_qparity:
                    new_qparity = parity_mod.patch_qparity_delta(
                        qparity_l, qdelta_p, idx, lo, ax)
            else:
                pages_new = parity_mod.page_view(row_new, bw)
                if verify_old and mode.has_cksums:
                    # old must be swept for verify anyway: the fused kernel
                    # shares that read with the parity delta, and parity
                    # consumes the delta (parity ^ rs(delta) == rs(new))
                    pages_old = parity_mod.page_view(row_old, bw)
                    if mode.has_qparity:
                        delta, qdelta, fresh, bad = \
                            kops.fused_verify_commit_pq(
                                pages_old, pages_new, cksums_l, coeff)
                        new_qparity = parity_mod.apply_qdelta(
                            qparity_l, qdelta.reshape(-1), ax)
                    else:
                        delta, fresh, bad = kops.fused_verify_commit(
                            pages_old, pages_new, cksums_l)
                    ok = _zone_clean(ok, bad, ax)
                    if mode.has_parity:
                        new_parity = parity_mod.apply_delta(
                            parity_l, delta.reshape(-1), ax)
                else:
                    # without verify the old row is not read at all: a
                    # delta here would cost a write+read of a row-sized
                    # buffer for nothing — reduce-scatter the new row
                    fresh = kops.fletcher_blocks(pages_new)
                    if mode.has_parity:
                        new_parity = parity_mod.build_parity(row_new, ax)
                    if mode.has_qparity:
                        new_qparity = parity_mod.build_qparity(row_new, ax)
                if mode.has_cksums:
                    new_cksums = fresh
                new_digest = ck.combine(fresh, bw)
            outs = {"ok": ok,
                    "row": self._pack(jnp.where(ok, row_new, row_old)),
                    "digest": self._pack(jnp.where(ok, new_digest,
                                                   digest_l))}
            if mode.has_parity:
                outs["parity"] = self._pack(
                    jnp.where(ok, new_parity, parity_l))
            if mode.has_qparity:
                outs["qparity"] = self._pack(
                    jnp.where(ok, new_qparity, qparity_l))
            if mode.has_cksums:
                outs["cksums"] = self._pack(
                    jnp.where(ok, new_cksums, cksums_l))
            return outs

        out_specs = {"ok": P(), "row": self._zone_spec,
                     "digest": self._zone_spec}
        if mode.has_parity:
            out_specs["parity"] = self._zone_spec
        if mode.has_qparity:
            out_specs["qparity"] = self._zone_spec
        if mode.has_cksums:
            out_specs["cksums"] = self._zone_spec
        protect = self._smap(
            _protect,
            in_specs=(self.state_specs, self._zone_spec, self._zone_spec,
                      self._zone_spec, self._zone_spec, self._zone_spec,
                      self.state_specs, P()),
            out_specs=out_specs)

        def commit(prot: ProtectedState, state_new: PyTree, *,
                   data_cursor=0, rng_key=None, canary_ok=True):
            step = prot.step + U32(1)
            canary_ok = jnp.asarray(canary_ok, bool)
            log = prot.log
            digest_for_log = jnp.zeros((2,), U32)
            new_row = prot.row
            new_qparity = prot.qparity
            if mode.has_parity or mode.has_cksums:
                outs = protect(prot.state, prot.row, prot.parity,
                               prot.qparity, prot.cksums, prot.digest,
                               state_new, canary_ok)
                ok = outs["ok"]
                new_row = outs["row"]
                new_parity = outs.get("parity", prot.parity)
                new_qparity = outs.get("qparity", prot.qparity)
                new_cksums = outs.get("cksums", prot.cksums)
                new_digest = outs["digest"]
                digest_for_log = new_digest.reshape(-1, 2)[0]
            else:
                ok = canary_ok
                new_parity, new_cksums, new_digest = (prot.parity,
                                                      prot.cksums,
                                                      prot.digest)
            # paper ordering: log record (replicated) persists before object
            # writes; the commit mark follows the protected update.
            if mode.has_log:
                if rng_key is None:
                    rng_key = jax.random.PRNGKey(0)
                log = redolog.append(prot.log, step, data_cursor, rng_key,
                                     digest_for_log)
                log = tree_select(ok, redolog.commit_mark(log, step), log)
            new_state = tree_select(ok, state_new, prot.state)
            replica = prot.replica
            if mode.has_replica:
                replica = tree_select(ok, jax.tree.map(jnp.copy, state_new),
                                      prot.replica)
            return ProtectedState(
                state=new_state, parity=new_parity, cksums=new_cksums,
                digest=new_digest, replica=replica, log=log,
                step=jnp.where(ok, step, prot.step), row=new_row,
                qparity=new_qparity), ok

        return commit

    def commit(self, prot, state_new, *, dirty_pages=None, verify_old=False,
               donate=False, **kw):
        """Cached-jit commit entry point.

        Distinct dirty-page sets (and the verify flag) key distinct
        compiled commits — a previous version folded `_dirty_key` into the
        cache key but always built the no-dirty-pages commit, silently
        sharing one stale program across different footprints.

        `donate=True` donates `prot` into its successor (row, parity,
        cksums, digest, log and state reuse their buffers in place —
        allocation-free steady state); the caller must then drop the old
        `prot` and keep only the returned one.
        """
        return self.commit_program(
            dirty_pages=dirty_pages, verify_old=verify_old,
            donate=donate)(prot, state_new, **kw)

    def commit_program(self, *, dirty_pages=None, verify_old=False,
                       donate=False):
        """The cached compiled commit for one (dirty set, verify, donate)
        key — what `commit` dispatches and what the Pool facade routes
        through (benchmarks lower it to assert facade == direct bytes)."""
        key = ("commit",
               tuple(int(p) for p in dirty_pages)
               if dirty_pages is not None else None,
               bool(verify_old), bool(donate))
        if key not in self._jit_cache:
            # the canary verdict is host-known before dispatch: static,
            # so the all-clear program folds its abort select-chains away
            # (an abort compiles the cheap no-op variant once)
            self._jit_cache[key] = jax.jit(
                self.make_commit(dirty_pages=dirty_pages,
                                 verify_old=verify_old),
                donate_argnums=(0,) if donate else (),
                static_argnames=("canary_ok",))
        return self._jit_cache[key]

    # -- scrub -------------------------------------------------------------------

    def make_scrub(self):
        """One fused scrub program: a single flatten of the live state
        feeds the checksum verify, the parity invariant check AND a
        row-cache divergence check (`row == flatten(state)` — nearly free
        with the row already in hand, and it catches a cache gone stale
        before a commit would trust it as the old operand).  All outputs
        land in one dict so the Scrubber fetches them with a single
        device_get."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode

        def _scrub(state, row_cache, parity, qparity, cksums):
            row = layout_mod.flatten_row(lo, state)
            out = {}
            if mode.has_cksums:
                bad = ck.verify_blocks(row, self._unpack(cksums),
                                       lo.block_words)
                out["bad_pages"] = self._pack(bad)
            if mode.has_parity:
                out["parity_ok"] = parity_mod.verify_parity(
                    row, self._unpack(parity), ax)
            if mode.has_qparity:
                out["qparity_ok"] = parity_mod.verify_qparity(
                    row, self._unpack(qparity), ax)
            if mode.has_parity or mode.has_cksums:
                same = jnp.all(row == self._unpack(row_cache))
                out["row_cache_ok"] = (
                    lax.pmin(same.astype(jnp.int32), self.axis_names) > 0)
            return out

        out_specs = {}
        if mode.has_cksums:
            out_specs["bad_pages"] = self._zone_spec
        if mode.has_parity:
            out_specs["parity_ok"] = P()
        if mode.has_qparity:
            out_specs["qparity_ok"] = P()
        if mode.has_parity or mode.has_cksums:
            out_specs["row_cache_ok"] = P()
        fn = self._smap(_scrub, in_specs=(self.state_specs, self._zone_spec,
                                          self._zone_spec, self._zone_spec,
                                          self._zone_spec),
                        out_specs=out_specs)

        def scrub(prot: ProtectedState):
            return fn(prot.state, prot.row, prot.parity, prot.qparity,
                      prot.cksums)

        return scrub

    def scrub(self, prot):
        if "scrub" not in self._jit_cache:
            self._jit_cache["scrub"] = jax.jit(self.make_scrub())
        return self._jit_cache["scrub"](prot)

    # -- recovery ------------------------------------------------------------------

    def make_recover_rank(self):
        """Online reconstruction of one lost data-rank's entire row."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode

        def _recover(state, parity, cksums, lost):
            # flatten the live (damaged) state — the row cache is rebuilt,
            # never trusted, across recovery
            row = layout_mod.flatten_row(lo, state)
            rebuilt = parity_mod.reconstruct_row(
                row, self._unpack(parity), lost, ax)
            me = lax.axis_index(ax)
            row_out = jnp.where(me == lost, rebuilt, row)
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums),
                                       lo.block_words)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        out_specs = {"state": self.state_specs, "ok": P(),
                     "row": self._zone_spec}
        fn = self._smap(_recover,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec, P()),
                        out_specs=out_specs)

        def recover(prot: ProtectedState, lost_rank):
            out = fn(prot.state, prot.parity, prot.cksums,
                     jnp.asarray(lost_rank, jnp.int32))
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return recover

    def recover_rank(self, prot, lost_rank):
        if "recover" not in self._jit_cache:
            self._jit_cache["recover"] = jax.jit(self.make_recover_rank())
        return self._jit_cache["recover"](prot, lost_rank)

    def make_recover_two(self, lost_a: int, lost_b: int):
        """Online reconstruction of TWO lost data-ranks' rows from P + Q.

        The pair is static (recovery is rare; one compiled program per
        pair) so the Vandermonde constants fold in as exact host
        integers.  Also the rank-loss-with-outstanding-scribble path:
        name the scribbled rank as the second loss.
        """
        lo, ax = self.layout, self.data_axis
        mode = self.mode
        assert mode.has_qparity, (
            f"mode {mode.value} has no Q syndrome; double loss is "
            "unrecoverable online (redundancy=2 adds it)")

        def _recover(state, parity, qparity, cksums):
            # flatten the live (damaged) state — the row cache is rebuilt,
            # never trusted, across recovery
            row = layout_mod.flatten_row(lo, state)
            row_a, row_b = parity_mod.reconstruct_two(
                row, self._unpack(parity), self._unpack(qparity),
                lost_a, lost_b, ax)
            me = lax.axis_index(ax)
            row_out = jnp.where(me == lost_a, row_a,
                                jnp.where(me == lost_b, row_b, row))
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums),
                                       lo.block_words)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        out_specs = {"state": self.state_specs, "ok": P(),
                     "row": self._zone_spec}
        fn = self._smap(_recover,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec, self._zone_spec),
                        out_specs=out_specs)

        def recover(prot: ProtectedState):
            out = fn(prot.state, prot.parity, prot.qparity, prot.cksums)
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return recover

    def recover_two(self, prot, lost_a, lost_b):
        a, b = sorted((int(lost_a), int(lost_b)))
        assert a != b, "double-loss recovery needs two distinct ranks"
        key = ("recover2", a, b)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(self.make_recover_two(a, b))
        return self._jit_cache[key](prot)

    def make_repair_pages(self, n_pages: int):
        """Targeted scribble repair: fix `n_pages` (rank, page) locations."""
        lo, ax = self.layout, self.data_axis
        mode = self.mode
        bw = lo.block_words
        pages_per_seg = lo.seg_words // bw

        def _repair(state, parity, cksums, bad_rank, bad_page):
            row = layout_mod.flatten_row(lo, state)
            pages = parity_mod.page_view(row, bw)
            me = lax.axis_index(ax)
            mine_bad = (bad_rank == me)                      # (k,)
            contents = pages[bad_page]                       # (k, bw)
            contrib = jnp.where(mine_bad[:, None], 0, contents)
            others = coll.xor_all_reduce(contrib, ax)        # (k, bw)
            # broadcast each bad page's parity from its owner via XOR trick
            owner = bad_page // pages_per_seg
            local_idx = bad_page % pages_per_seg
            seg_pages = parity.reshape(pages_per_seg, bw) if parity.ndim == 1 \
                else self._unpack(parity).reshape(pages_per_seg, bw)
            par_contrib = jnp.where((owner == me)[:, None],
                                    seg_pages[local_idx], 0)
            par_pages = coll.xor_all_reduce(par_contrib, ax)  # (k, bw)
            fixed = others ^ par_pages
            new_pages = jnp.where(mine_bad[:, None], fixed, contents)
            row_out = pages.at[bad_page].set(new_pages).reshape(-1)
            out = {"state": layout_mod.unflatten_row(lo, row_out),
                   "row": self._pack(row_out)}
            if mode.has_cksums:
                bad = ck.verify_blocks(row_out, self._unpack(cksums), bw)
                any_bad = lax.pmax(jnp.any(bad).astype(jnp.int32), ax)
                out["ok"] = any_bad == 0
            else:
                out["ok"] = jnp.asarray(True)
            return out

        fn = self._smap(_repair,
                        in_specs=(self.state_specs, self._zone_spec,
                                  self._zone_spec, P(), P()),
                        out_specs={"state": self.state_specs, "ok": P(),
                                   "row": self._zone_spec})

        def repair(prot: ProtectedState, bad_rank, bad_page):
            bad_rank = jnp.asarray(bad_rank, jnp.int32).reshape(n_pages)
            bad_page = jnp.asarray(bad_page, jnp.int32).reshape(n_pages)
            out = fn(prot.state, prot.parity, prot.cksums, bad_rank, bad_page)
            return dataclasses.replace(prot, state=out["state"],
                                       row=out["row"]), out["ok"]

        return repair

    def repair_pages(self, prot, bad_rank, bad_page):
        n = int(np.asarray(bad_rank).size)
        key = ("repair", n)
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(self.make_repair_pages(n))
        return self._jit_cache[key](prot, bad_rank, bad_page)

    # -- introspection ---------------------------------------------------------

    def overhead_report(self) -> dict:
        rep = self.layout.overhead_report()
        rep["mode"] = self.mode.value
        rep["group_size"] = self.group_size
        rep["redundancy"] = self.mode.redundancy
        # Q is one more seg_words row per rank — same bytes as P, so the
        # dual-parity storage tax is exactly 2x the parity fraction
        rep["qparity_bytes_per_rank"] = (rep["parity_bytes_per_rank"]
                                         if self.mode.has_qparity else 0)
        rep["qparity_fraction"] = (rep["parity_fraction"]
                                   if self.mode.has_qparity else 0.0)
        if self.mode.has_replica:
            rep["protection_fraction"] = 1.0
        else:
            frac = 0.0
            if self.mode.has_parity:
                frac += rep["parity_fraction"]
            if self.mode.has_qparity:
                frac += rep["qparity_fraction"]
            if self.mode.has_cksums:
                frac += rep["checksum_fraction"]
            rep["protection_fraction"] = frac
        return rep
