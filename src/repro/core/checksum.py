"""Fletcher-64 block checksums over uint32 words.

Pangolin uses Adler32 because it supports *incremental* updates: the cost of
refreshing an object's checksum is proportional to the modified range, not
the object size (§3.5).  Adler's byte-serial mod-65521 loop is hostile to the
TPU VPU, so we keep the two properties the paper actually exploits —

  1. incremental updatability (cost ∝ modified range), and
  2. a block-combine rule (parallel computation across blocks)

— with a Fletcher-style pair over 32-bit lanes and natural mod-2^32
wraparound:

    A(w) = sum_i w_i                      (mod 2^32)
    B(w) = sum_i (n - i) * w_i            (mod 2^32)   [sum of prefix sums]

Combine for concat(x |n|, y |m|):   A = Ax + Ay,  B = Bx + m*Ax + By.
Range update w[s:e] old->new:       A += sum d,   B += sum (n-s-i) * d_i,
where d = new - old (wraparound).  Detection class matches Adler/Fletcher:
all 1-2 word errors and bursts within a block; random corruption escapes
with p ~= 2^-64.

The row is chunked into fixed-size blocks ("page columns" of the paper's
layout); checksums are stored per block so verification and incremental
refresh parallelize, and a whole-row digest is available via `combine`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import utils

U32 = jnp.uint32
# 4 KB pages = 1024 words: the paper's page-column unit.
DEFAULT_BLOCK_WORDS = 1024


def _weights(n: int) -> jax.Array:
    # (n, n-1, ..., 1) as uint32
    return (n - jnp.arange(n, dtype=U32))


def block_checksums(row: jax.Array, block_words: int = DEFAULT_BLOCK_WORDS
                    ) -> jax.Array:
    """Per-block (A, B) checksums of a 1-D uint32 row.

    Returns (n_blocks, 2) uint32.  `row` length must divide into blocks
    (pad with zeros first; zero words are checksum-neutral for A and B... not
    for B's positional weight, so padding must be consistent between compute
    and verify — callers always pad the row once, at layout time).

    Dispatches to the Pallas Fletcher kernel on TPU (kernels/fletcher.py);
    the jnp path below is the oracle it is tested against.
    """
    assert row.dtype == U32, row.dtype
    assert row.shape[0] % block_words == 0, (row.shape, block_words)
    blocks = row.reshape(-1, block_words)
    from repro.kernels import ops as kops  # local import: kernels<-core only
    return kops.fletcher_blocks(blocks)


def combine(cksums: jax.Array, block_words: int = DEFAULT_BLOCK_WORDS
            ) -> jax.Array:
    """Fold per-block checksums into one (A, B) digest for the whole row."""
    n_blocks = cksums.shape[0]
    a_blocks = cksums[:, 0]
    b_blocks = cksums[:, 1]
    a = jnp.sum(a_blocks, dtype=U32)
    # words after block i: (n_blocks - 1 - i) * block_words
    after = ((n_blocks - 1 - jnp.arange(n_blocks, dtype=U32))
             * U32(block_words))
    b = jnp.sum(b_blocks + after * a_blocks, dtype=U32)
    return jnp.stack([a, b])


def verify_blocks(row: jax.Array, cksums: jax.Array,
                  block_words: int = DEFAULT_BLOCK_WORDS) -> jax.Array:
    """Recompute and compare; returns per-block mismatch mask (True = bad)."""
    fresh = block_checksums(row, block_words)
    return jnp.any(fresh != cksums, axis=1)


def set_blocks(cksums: jax.Array, fresh: jax.Array,
               block_idx: jax.Array) -> jax.Array:
    """Scatter precomputed per-block terms into the checksum table.

    The fused commit sweep emits fresh (k, 2) Fletcher terms for the dirty
    blocks as a by-product of its delta pass; this applies them without
    re-reading the block contents.
    """
    return cksums.at[block_idx].set(fresh)


def update_blocks(cksums: jax.Array, new_blocks: jax.Array,
                  block_idx: jax.Array,
                  block_words: int = DEFAULT_BLOCK_WORDS) -> jax.Array:
    """Incremental refresh: recompute checksums only for the given blocks.

    `new_blocks`: (k, block_words) uint32 contents; `block_idx`: (k,) int32.
    Cost ∝ modified blocks — the paper's Adler32 range-update property at
    block granularity.
    """
    w = _weights(block_words)
    a = jnp.sum(new_blocks, axis=1, dtype=U32)
    b = jnp.sum(new_blocks * w[None, :], axis=1, dtype=U32)
    fresh = jnp.stack([a, b], axis=1)
    return set_blocks(cksums, fresh, block_idx)


def update_range(cksum: jax.Array, old: jax.Array, new: jax.Array,
                 start, n_words: int) -> jax.Array:
    """Word-granular incremental update within a single block.

    `cksum`: (2,) for a block of `n_words` words; `old`/`new`: the modified
    range contents; `start`: word offset of the range within the block.
    """
    d = new - old  # uint32 wraparound == mod 2^32 subtraction
    da = jnp.sum(d, dtype=U32)
    idx = jnp.asarray(start, U32) + jnp.arange(d.shape[0], dtype=U32)
    db = jnp.sum((U32(n_words) - idx) * d, dtype=U32)
    return jnp.stack([cksum[0] + da, cksum[1] + db])


def update_digest(dig: jax.Array, old_ck: jax.Array, new_ck: jax.Array,
                  block_idx: jax.Array, n_blocks: int,
                  block_words: int = DEFAULT_BLOCK_WORDS) -> jax.Array:
    """Incremental whole-row digest from per-block term changes.

    `dig`: (2,) current digest; `old_ck`/`new_ck`: (k, 2) Fletcher terms of
    the dirty blocks before/after; `block_idx`: (k,) their positions.  The
    combine rule is linear in the per-block terms, so the digest shifts by
    the term deltas weighted by each block's tail length — cost ∝ dirty
    blocks, and bit-identical (mod-2^32 arithmetic is exact) to a full
    recompute.  This is what lets parity-only (MLP) commits keep a row
    digest without a second sweep over the new row.
    """
    da_blocks = new_ck[:, 0] - old_ck[:, 0]
    db_blocks = new_ck[:, 1] - old_ck[:, 1]
    after = ((U32(n_blocks) - U32(1) - block_idx.astype(U32))
             * U32(block_words))
    da = jnp.sum(da_blocks, dtype=U32)
    db = jnp.sum(db_blocks + after * da_blocks, dtype=U32)
    return jnp.stack([dig[0] + da, dig[1] + db])


def update_digest_words(dig: jax.Array, old_w: jax.Array, new_w: jax.Array,
                        row_offsets: jax.Array, row_words: int) -> jax.Array:
    """Word-granular incremental whole-row digest.

    Unfolding `combine` over `block_checksums` shows the row digest is
    linear in word position: A = sum_j w_j, B = sum_j (row_words - j) * w_j
    (word j in block b at offset i has combine weight
    (bw - i) + (n_blocks - 1 - b) * bw == row_words - j).  So a commit
    that changes only the words at `row_offsets` shifts the digest by
    the word deltas alone — one sweep over the *modified words*, no
    pages, no row, and bit-identical (mod-2^32 exact) to a full
    recompute.  Unmodified (or out-of-bounds fill-gathered) entries have
    delta zero and may appear any number of times; modified words must
    appear exactly once.
    """
    d = new_w - old_w                       # u32 wraparound == mod 2^32
    da = jnp.sum(d, dtype=U32)
    w = U32(row_words) - row_offsets.astype(U32)
    db = jnp.sum(w * d, dtype=U32)
    return jnp.stack([dig[0] + da, dig[1] + db])


def digest(row: jax.Array, block_words: int = DEFAULT_BLOCK_WORDS
           ) -> jax.Array:
    """(A, B) digest of a full row."""
    return combine(block_checksums(row, block_words), block_words)
