"""Zone layout: a sharded state pytree viewed as Pangolin's 2-D zone.

Pangolin organizes a zone's chunks as rows x columns; objects place anywhere
within rows, the last row is parity, and "page columns" (4 KB-wide aligned
columns) are the unit of recovery (§3.1).

Mapping: for each (pod, model) coordinate, the G ranks along the **data**
axis form one zone.  Each rank's local shards of every state leaf, bitcast
to uint32 words and concatenated, form that rank's "chunk row".  Leaves are
the "objects": they place at arbitrary offsets in the row, independent of
page boundaries, exactly as the paper allows.  The parity row is XOR of the
G rows, reduce-scattered so each rank stores 1/G of it — storage overhead is
1/G of the pool (the paper's "100 chunk rows => ~1%" dial; G is the mesh's
data-axis size here, and grows with the deployment).

The layout is computed once from abstract shapes + shardings (no device
data) and is identical on every rank of a zone by SPMD construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.core import checksum as cksum_mod

PyTree = Any

PAGE_WORDS = 1024  # 4 KB pages, as in the paper's recovery granularity.


@dataclasses.dataclass(frozen=True)
class ZoneLayout:
    """Static placement of a state pytree inside the per-rank word row."""
    treedef: Any
    slots: tuple                # tuple[utils.LeafSlot]
    row_words: int              # padded row length (multiple of G * PAGE_WORDS)
    group_size: int             # G — ranks per zone (data-axis size)
    block_words: int            # checksum block == page column width

    @property
    def n_blocks(self) -> int:
        return self.row_words // self.block_words

    @property
    def seg_words(self) -> int:
        """Per-rank parity segment length."""
        return self.row_words // self.group_size

    @property
    def payload_words(self) -> int:
        return sum(s.n_words for s in self.slots)

    # -- storage accounting (the paper's §4.2) --------------------------------
    def overhead_report(self) -> dict:
        state_bytes = self.payload_words * 4
        parity_bytes = self.seg_words * 4          # per rank; 1/G of row
        cksum_bytes = self.n_blocks * 8
        return dict(
            state_bytes_per_rank=state_bytes,
            parity_bytes_per_rank=parity_bytes,
            checksum_bytes_per_rank=cksum_bytes,
            parity_fraction=parity_bytes / max(state_bytes, 1),
            checksum_fraction=cksum_bytes / max(state_bytes, 1),
            replication_fraction=1.0,              # the Pmemobj-R comparison
        )


def _local_shape(leaf, sharding) -> tuple:
    if sharding is None:
        return tuple(leaf.shape)
    return tuple(sharding.shard_shape(tuple(leaf.shape)))


def build_layout(state: PyTree, group_size: int,
                 shardings: PyTree | None = None,
                 block_words: int = PAGE_WORDS) -> ZoneLayout:
    """Compute the zone layout from abstract state.

    `state`: pytree of arrays or ShapeDtypeStructs (global shapes).
    `shardings`: matching pytree of NamedShardings (or None for local/CPU
    use, in which case shapes are taken as-is).
    """
    leaves, treedef = jax.tree.flatten(state)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    assert len(shard_leaves) == len(leaves)
    slots = []
    offset = 0
    for leaf, sh in zip(leaves, shard_leaves):
        lshape = _local_shape(leaf, sh)
        n_words = utils.num_words(lshape, leaf.dtype)
        slots.append(utils.LeafSlot(offset=offset, n_words=n_words,
                                    shape=lshape, dtype=jnp.dtype(leaf.dtype)))
        offset += n_words
    row_words = utils.round_up(max(offset, 1), group_size * block_words)
    return ZoneLayout(treedef=treedef, slots=tuple(slots),
                      row_words=row_words, group_size=group_size,
                      block_words=block_words)


def flatten_row(layout: ZoneLayout, local_state: PyTree) -> jax.Array:
    """Bitcast + concatenate local shards into the padded word row."""
    leaves = jax.tree.leaves(local_state)
    assert len(leaves) == len(layout.slots)
    parts = []
    for leaf, slot in zip(leaves, layout.slots):
        w = utils.to_words(leaf)
        assert w.shape[0] == slot.n_words, (w.shape, slot)
        parts.append(w)
    row = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.uint32)
    return utils.pad_to(row, layout.row_words)


def unflatten_row(layout: ZoneLayout, row: jax.Array) -> PyTree:
    """Inverse of :func:`flatten_row` — bit-exact."""
    leaves = []
    for slot in layout.slots:
        w = jax.lax.dynamic_slice_in_dim(row, slot.offset, slot.n_words)
        leaves.append(utils.from_words(w, slot.shape, slot.dtype))
    return jax.tree.unflatten(layout.treedef, leaves)


def update_row(layout: ZoneLayout, row: jax.Array, new_state: PyTree,
               dirty_leaf_idx: Sequence[int]) -> jax.Array:
    """Splice only the dirty leaves' words into a cached row.

    The commit hot path keeps the previous flattened row alongside the
    pytree (ProtectedState.row); when the update's footprint is known,
    re-flattening the entire state per commit is replaced by word-splicing
    just the changed leaves — cost ∝ modified range, like the paper's
    incremental checksum updates.  `row` must equal flatten_row(old state)
    and leaves outside `dirty_leaf_idx` must be unchanged.
    """
    leaves = jax.tree.leaves(new_state)
    assert len(leaves) == len(layout.slots)
    for i in dirty_leaf_idx:
        slot = layout.slots[i]
        w = utils.to_words(leaves[i])
        assert w.shape[0] == slot.n_words, (w.shape, slot)
        row = jax.lax.dynamic_update_slice_in_dim(row, w, slot.offset, 0)
    return row


def leaves_for_pages(layout: ZoneLayout, pages: Sequence[int]) -> list:
    """Leaf indices whose slots overlap any of the given page columns."""
    wanted = {int(p) for p in pages}
    out = []
    for i, slot in enumerate(layout.slots):
        first = slot.offset // layout.block_words
        last = (slot.offset + max(slot.n_words, 1) - 1) // layout.block_words
        if any(first <= p <= last for p in wanted):   # O(k), not O(row pages)
            out.append(i)
    return out


def leaf_pages(layout: ZoneLayout, leaf_index: int) -> np.ndarray:
    """Page-column indices overlapping a given leaf (for targeted patches)."""
    slot = layout.slots[leaf_index]
    first = slot.offset // layout.block_words
    last = (slot.offset + slot.n_words - 1) // layout.block_words
    return np.arange(first, last + 1)


def range_pages(layout: ZoneLayout, offset: int, n_words: int) -> np.ndarray:
    first = offset // layout.block_words
    last = (offset + max(n_words, 1) - 1) // layout.block_words
    return np.arange(first, last + 1)


# ---------------------------------------------------------------------------
# decode-step dirty pages (the serving hot path's footprint)
# ---------------------------------------------------------------------------
#
# A decode step writes one "time slot" of every cache leaf: for a leaf of
# local shape s with its sequence axis at dim d (identified as an axis of
# length `time_size`), position p touches, for every combination of the
# axes before d, a contiguous run of prod(s[d+1:]) elements starting at
# element offset p * prod(s[d+1:]).  Leaves with no axis of that length
# (recurrent hidden state, conv windows) are rewritten wholly every step
# and count as fully dirty.  All byte math is done on the slot's placement
# inside the word row, so runs that straddle page-column boundaries are
# attributed to both pages.  The result is SPMD-uniform (the layout is
# identical on every zone rank by construction), which the parity patch
# path requires.


def _slot_time_runs(slot, time_size: int):
    """(outer, stride_bytes, run_bytes) descriptors for each candidate
    time axis of the slot; [] when the slot has no axis of that length.

    If several axes match `time_size` the union over all of them is taken
    — a conservative superset that stays correct whichever axis is the
    real sequence axis.
    """
    esize = jnp.dtype(slot.dtype).itemsize
    runs = []
    for d, sz in enumerate(slot.shape):
        if sz != time_size:
            continue
        inner = int(np.prod(slot.shape[d + 1:], dtype=np.int64)) if \
            slot.shape[d + 1:] else 1
        outer = int(np.prod(slot.shape[:d], dtype=np.int64)) if \
            slot.shape[:d] else 1
        runs.append((outer, sz * inner * esize, inner * esize))
    return runs


def time_slice_pages(layout: ZoneLayout, time_size: int,
                     pos: int) -> np.ndarray:
    """Page columns touched by writing time slot `pos` of every leaf.

    Ring-buffer caches wrap (`pos % time_size`); leaves without a
    `time_size` axis contribute all of their pages.  Returns sorted
    unique page indices (np.int32).
    """
    page_bytes = layout.block_words * 4
    p = int(pos) % time_size
    pages = []
    for slot in layout.slots:
        base = slot.offset * 4
        runs = _slot_time_runs(slot, time_size)
        if not runs:
            pages.append(range_pages(layout, slot.offset, slot.n_words))
            continue
        for outer, stride_b, run_b in runs:
            starts = base + np.arange(outer, dtype=np.int64) * stride_b \
                + p * run_b
            first = starts // page_bytes
            last = (starts + max(run_b, 1) - 1) // page_bytes
            span = int((last - first).max()) + 1 if outer else 1
            cand = first[:, None] + np.arange(span)[None, :]
            pages.append(cand[cand <= last[:, None]])
    out = np.unique(np.concatenate(pages)) if pages else np.zeros(0, np.int64)
    return out.astype(np.int32)


def time_slice_words(layout: ZoneLayout, time_size: int,
                     pos: int) -> list:
    """Per-leaf *word* indices touched by writing time slot `pos`.

    Returns one entry per slot: an int32 array of word indices local to
    the slot's word range, or None meaning "whole leaf dirty" (no
    `time_size` axis, an ambiguous shape with several candidate axes, or
    a degenerate time_size < 2).

    The array's SHAPE is position-independent, so one compiled program
    serves every decode position.  For word-aligned runs the indices are
    exact and duplicate-free; for unaligned (sub-word dtype) runs each
    run is widened to a fixed span that may overhang into the *next*
    time slot's words — never into words this step modifies — and may
    step past the slot's end.  Consumers must therefore gather with
    fill-out-of-bounds semantics (OOB -> identical old/new values) and
    may rely on every *modified* word appearing exactly once (the
    incremental digest is a sum, so duplicates of modified words would
    double-count; duplicates of unmodified words are delta-zero).
    """
    if time_size < 2:
        return [None] * len(layout.slots)
    p = int(pos) % time_size
    out = []
    for slot in layout.slots:
        runs = _slot_time_runs(slot, time_size)
        if len(runs) != 1:
            # no time axis, or several candidates whose run unions could
            # overlap (and so double-count): whole leaf
            out.append(None)
            continue
        outer, stride_b, run_b = runs[0]
        starts = np.arange(outer, dtype=np.int64) * stride_b + p * run_b
        if run_b % 4 == 0 and stride_b % 4 == 0:
            span = run_b // 4                  # aligned: exact, every pos
        else:
            span = run_b // 4 + 2              # overhang absorbed by fill
        first = starts // 4
        out.append((first[:, None]
                    + np.arange(span, dtype=np.int64)[None, :]
                    ).reshape(-1).astype(np.int32))
    return out


def time_slice_page_capacity(layout: ZoneLayout, time_size: int) -> int:
    """Upper bound on len(time_slice_pages(...)) over all positions.

    Analytic, position-free: each run can straddle at most
    run_bytes // page_bytes + 2 page columns.  Clamped to n_blocks.
    """
    page_bytes = layout.block_words * 4
    total = 0
    for slot in layout.slots:
        runs = _slot_time_runs(slot, time_size)
        if not runs:
            total += len(range_pages(layout, slot.offset, slot.n_words))
            continue
        for outer, _, run_b in runs:
            total += outer * (run_b // page_bytes + 2)
    return min(total, layout.n_blocks)
