"""Zone layout: a sharded state pytree viewed as Pangolin's 2-D zone.

Pangolin organizes a zone's chunks as rows x columns; objects place anywhere
within rows, the last row is parity, and "page columns" (4 KB-wide aligned
columns) are the unit of recovery (§3.1).

Mapping: for each (pod, model) coordinate, the G ranks along the **data**
axis form one zone.  Each rank's local shards of every state leaf, bitcast
to uint32 words and concatenated, form that rank's "chunk row".  Leaves are
the "objects": they place at arbitrary offsets in the row, independent of
page boundaries, exactly as the paper allows.  The parity row is XOR of the
G rows, reduce-scattered so each rank stores 1/G of it — storage overhead is
1/G of the pool (the paper's "100 chunk rows => ~1%" dial; G is the mesh's
data-axis size here, and grows with the deployment).

The layout is computed once from abstract shapes + shardings (no device
data) and is identical on every rank of a zone by SPMD construction.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.core import checksum as cksum_mod

PyTree = Any

PAGE_WORDS = 1024  # 4 KB pages, as in the paper's recovery granularity.


@dataclasses.dataclass(frozen=True)
class ZoneLayout:
    """Static placement of a state pytree inside the per-rank word row."""
    treedef: Any
    slots: tuple                # tuple[utils.LeafSlot]
    row_words: int              # padded row length (multiple of G * PAGE_WORDS)
    group_size: int             # G — ranks per zone (data-axis size)
    block_words: int            # checksum block == page column width

    @property
    def n_blocks(self) -> int:
        return self.row_words // self.block_words

    @property
    def seg_words(self) -> int:
        """Per-rank parity segment length."""
        return self.row_words // self.group_size

    @property
    def payload_words(self) -> int:
        return sum(s.n_words for s in self.slots)

    # -- storage accounting (the paper's §4.2) --------------------------------
    def overhead_report(self) -> dict:
        state_bytes = self.payload_words * 4
        parity_bytes = self.seg_words * 4          # per rank; 1/G of row
        cksum_bytes = self.n_blocks * 8
        return dict(
            state_bytes_per_rank=state_bytes,
            parity_bytes_per_rank=parity_bytes,
            checksum_bytes_per_rank=cksum_bytes,
            parity_fraction=parity_bytes / max(state_bytes, 1),
            checksum_fraction=cksum_bytes / max(state_bytes, 1),
            replication_fraction=1.0,              # the Pmemobj-R comparison
        )


def _local_shape(leaf, sharding) -> tuple:
    if sharding is None:
        return tuple(leaf.shape)
    return tuple(sharding.shard_shape(tuple(leaf.shape)))


def build_layout(state: PyTree, group_size: int,
                 shardings: PyTree | None = None,
                 block_words: int = PAGE_WORDS) -> ZoneLayout:
    """Compute the zone layout from abstract state.

    `state`: pytree of arrays or ShapeDtypeStructs (global shapes).
    `shardings`: matching pytree of NamedShardings (or None for local/CPU
    use, in which case shapes are taken as-is).
    """
    leaves, treedef = jax.tree.flatten(state)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    assert len(shard_leaves) == len(leaves)
    slots = []
    offset = 0
    for leaf, sh in zip(leaves, shard_leaves):
        lshape = _local_shape(leaf, sh)
        n_words = utils.num_words(lshape, leaf.dtype)
        slots.append(utils.LeafSlot(offset=offset, n_words=n_words,
                                    shape=lshape, dtype=jnp.dtype(leaf.dtype)))
        offset += n_words
    row_words = utils.round_up(max(offset, 1), group_size * block_words)
    return ZoneLayout(treedef=treedef, slots=tuple(slots),
                      row_words=row_words, group_size=group_size,
                      block_words=block_words)


def flatten_row(layout: ZoneLayout, local_state: PyTree) -> jax.Array:
    """Bitcast + concatenate local shards into the padded word row."""
    leaves = jax.tree.leaves(local_state)
    assert len(leaves) == len(layout.slots)
    parts = []
    for leaf, slot in zip(leaves, layout.slots):
        w = utils.to_words(leaf)
        assert w.shape[0] == slot.n_words, (w.shape, slot)
        parts.append(w)
    row = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.uint32)
    return utils.pad_to(row, layout.row_words)


def unflatten_row(layout: ZoneLayout, row: jax.Array) -> PyTree:
    """Inverse of :func:`flatten_row` — bit-exact."""
    leaves = []
    for slot in layout.slots:
        w = jax.lax.dynamic_slice_in_dim(row, slot.offset, slot.n_words)
        leaves.append(utils.from_words(w, slot.shape, slot.dtype))
    return jax.tree.unflatten(layout.treedef, leaves)


def update_row(layout: ZoneLayout, row: jax.Array, new_state: PyTree,
               dirty_leaf_idx: Sequence[int]) -> jax.Array:
    """Splice only the dirty leaves' words into a cached row.

    The commit hot path keeps the previous flattened row alongside the
    pytree (ProtectedState.row); when the update's footprint is known,
    re-flattening the entire state per commit is replaced by word-splicing
    just the changed leaves — cost ∝ modified range, like the paper's
    incremental checksum updates.  `row` must equal flatten_row(old state)
    and leaves outside `dirty_leaf_idx` must be unchanged.
    """
    leaves = jax.tree.leaves(new_state)
    assert len(leaves) == len(layout.slots)
    for i in dirty_leaf_idx:
        slot = layout.slots[i]
        w = utils.to_words(leaves[i])
        assert w.shape[0] == slot.n_words, (w.shape, slot)
        row = jax.lax.dynamic_update_slice_in_dim(row, w, slot.offset, 0)
    return row


def leaves_for_pages(layout: ZoneLayout, pages: Sequence[int]) -> list:
    """Leaf indices whose slots overlap any of the given page columns."""
    wanted = {int(p) for p in pages}
    out = []
    for i, slot in enumerate(layout.slots):
        first = slot.offset // layout.block_words
        last = (slot.offset + max(slot.n_words, 1) - 1) // layout.block_words
        if any(first <= p <= last for p in wanted):   # O(k), not O(row pages)
            out.append(i)
    return out


def leaf_pages(layout: ZoneLayout, leaf_index: int) -> np.ndarray:
    """Page-column indices overlapping a given leaf (for targeted patches)."""
    slot = layout.slots[leaf_index]
    first = slot.offset // layout.block_words
    last = (slot.offset + slot.n_words - 1) // layout.block_words
    return np.arange(first, last + 1)


def range_pages(layout: ZoneLayout, offset: int, n_words: int) -> np.ndarray:
    first = offset // layout.block_words
    last = (offset + max(n_words, 1) - 1) // layout.block_words
    return np.arange(first, last + 1)
