"""Async commit pipeline: CommitTicket futures + the N-deep CommitRing.

Pangolin's micro-buffered transactions already keep redundancy work off
the application's critical path *per commit*; this module removes the
remaining host serialization *across* commits (FliT, arXiv:2108.04202:
persistent-object throughput hinges on many cheap in-flight operations).
`Pool.commit_async` dispatches a commit and returns a `CommitTicket` —
a future over the commit program's device verdict — instead of the raw
device bool.  Tickets queue in a `CommitRing` of
`ProtectConfig.pipeline_depth` slots: commit t+k dispatches before
commit t resolves, and verdicts resolve OUT OF DISPATCH ORDER — `poll`
resolves whichever device scalars have landed, not the oldest first —
so one slow commit never head-of-line-blocks the verdicts behind it.

Nothing here touches protection math: a ticket is bookkeeping around a
device scalar the commit program already produced, so a pipeline
drained at any boundary is bit-identical to resolving every commit
synchronously (tests/test_pipeline.py asserts this across engines,
redundancy levels and depths).  The ring is plain host state — no jit,
no collective — and publishes through callbacks the Pool wires
(in-flight depth gauge, resolve-latency histogram with trace-span
exemplars).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional

import jax


def _scalar_ready(ok: Any) -> bool:
    """Non-blocking readiness of a device scalar (host values are
    always ready; jax.Array exposes is_ready())."""
    fn = getattr(ok, "is_ready", None)
    return True if fn is None else bool(fn())


class CommitTicket:
    """One in-flight commit: the verdict future `commit_async` returns.

    Carries the unfetched device verdict (`ok`), the dispatch/resolve
    wall-clock timestamps, the trace span id of the dispatch event, and
    optional `extras` (e.g. per-tenant verdict scalars for a tenancy
    wave).  `result()` fetches the verdict — blocking unless the scalar
    already landed — and fires the pool's resolve callback exactly
    once; `ready()` polls without blocking.  `void()` resolves the
    ticket deterministically WITHOUT trusting the device value (the
    recovery path's option for tickets whose commit a re-arm
    superseded).
    """

    __slots__ = ("seq", "ok", "dispatched_at", "resolved_at", "span_id",
                 "extras", "staged", "voided", "_verdict", "_on_resolve")

    def __init__(self, seq: int, ok: Any, *,
                 dispatched_at: Optional[float] = None,
                 span_id: Optional[int] = None,
                 extras: Optional[dict] = None,
                 staged: bool = False,
                 on_resolve: Optional[Callable[["CommitTicket"], None]]
                 = None):
        self.seq = int(seq)
        self.ok = ok
        self.dispatched_at = (time.perf_counter() if dispatched_at is None
                              else float(dispatched_at))
        self.resolved_at: Optional[float] = None
        self.span_id = span_id
        self.extras = extras
        # staged = the verdict includes a device-side canary the host
        # could not know at dispatch (Pool defers abort bookkeeping to
        # resolution for these)
        self.staged = bool(staged)
        self.voided = False
        self._verdict: Optional[bool] = None
        self._on_resolve = on_resolve

    # -- state -----------------------------------------------------------------

    @property
    def resolved(self) -> bool:
        return self.resolved_at is not None

    @property
    def resolve_latency_ms(self) -> Optional[float]:
        """Dispatch-to-resolve wall (None while in flight)."""
        if self.resolved_at is None:
            return None
        return (self.resolved_at - self.dispatched_at) * 1e3

    def ready(self) -> bool:
        """True iff `result()` would not block (resolved, or the device
        scalar has landed)."""
        return self.resolved or _scalar_ready(self.ok)

    # -- resolution ------------------------------------------------------------

    def result(self, block: bool = True) -> Optional[bool]:
        """The commit verdict.  Returns None when `block=False` and the
        device scalar has not landed yet; otherwise fetches (blocking at
        most once — resolution is idempotent) and returns the bool."""
        if self.resolved:
            return self._verdict
        if not block and not _scalar_ready(self.ok):
            return None
        self._finish(bool(jax.device_get(self.ok)))
        return self._verdict

    def void(self, verdict: bool = False) -> bool:
        """Resolve without consulting the device (deterministic verdict
        for a superseded commit); no-op if already resolved."""
        if not self.resolved:
            self.voided = True
            self._finish(bool(verdict))
        return bool(self._verdict)

    def _finish(self, verdict: bool) -> None:
        self._verdict = verdict
        self.resolved_at = time.perf_counter()
        if self._on_resolve is not None:
            cb, self._on_resolve = self._on_resolve, None
            cb(self)

    def __repr__(self) -> str:  # debugging aid, not a stable format
        state = ("voided" if self.voided else
                 repr(self._verdict) if self.resolved else "in-flight")
        return f"CommitTicket(seq={self.seq}, {state})"


class CommitRing:
    """The N-deep in-flight window (`ProtectConfig.pipeline_depth`).

    `submit` enqueues a fresh ticket, first force-resolving the OLDEST
    one when the ring is full (back-pressure: the pipeline never holds
    more than `depth` unresolved commits).  `poll` resolves every
    ticket whose scalar has landed — out of dispatch order — and
    `drain` resolves all of them (dispatch order, the deterministic
    boundary recovery/flush/scrub use).  `on_depth` fires with the new
    in-flight count whenever it changes (the Pool's depth gauge).
    """

    def __init__(self, depth: int = 1, *,
                 on_depth: Optional[Callable[[int], None]] = None):
        assert depth >= 1, f"pipeline depth must be >= 1, got {depth}"
        self.depth = int(depth)
        self._inflight: List[CommitTicket] = []
        self._on_depth = on_depth

    def __len__(self) -> int:
        return len(self._inflight)

    @property
    def in_flight(self) -> List[CommitTicket]:
        """The unresolved tickets, oldest first (a copy)."""
        return list(self._inflight)

    def _note_depth(self) -> None:
        if self._on_depth is not None:
            self._on_depth(len(self._inflight))

    def submit(self, ticket: CommitTicket) -> CommitTicket:
        """Enqueue; force-resolves the oldest ticket when full."""
        while len(self._inflight) >= self.depth:
            self._inflight.pop(0).result()
        self._inflight.append(ticket)
        self._note_depth()
        return ticket

    def poll(self) -> List[CommitTicket]:
        """Resolve every ticket whose device scalar already landed —
        out of dispatch order — and return them (possibly empty)."""
        done = [t for t in self._inflight if t.ready()]
        if done:
            self._inflight = [t for t in self._inflight
                              if not t.ready()]
            for t in done:
                t.result()
            self._note_depth()
        return done

    def drain(self) -> List[CommitTicket]:
        """Resolve ALL in-flight tickets (dispatch order) — the
        deterministic boundary before a flush/scrub/recovery."""
        done, self._inflight = self._inflight, []
        for t in done:
            t.result()
        self._note_depth()
        return done

    def void_all(self, verdict: bool = False) -> List[CommitTicket]:
        """Void every in-flight ticket (see CommitTicket.void) — for
        boundaries where the device verdicts were superseded (re-arm
        after a budget-exhausted storm)."""
        done, self._inflight = self._inflight, []
        for t in done:
            t.void(verdict)
        self._note_depth()
        return done
