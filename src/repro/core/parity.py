"""XOR parity over zone rows (Pangolin §3.1, §3.5).

All functions run *inside* a shard_map over the full mesh and operate on the
local word row; `axis_name` is the zone (data) axis of size G.

Three update paths, mirroring the paper's hybrid scheme:

  * build      — full XOR reduce-scatter of the rows (initialization, and
                 the "writer lock / plain XOR" path for large updates).
  * patch      — incremental: Delta = old XOR new on the *dirty pages only*,
                 XOR-reduced and applied to the owners' parity segments.
                 XOR's commutativity makes concurrent patches order-free —
                 the paper's atomic-XOR insight, realized as a collective.
  * hybrid     — picks patch vs build from the dirty fraction, the analogue
                 of the paper's 512 B threshold.

Reconstruction (§3.6): lost row r = XOR of surviving rows XOR parity,
computed online by all survivors.

Beyond the paper, the same three paths generalize to the Reed-Solomon
syndrome stack S_0..S_{r-1} (S_k = XOR_i g^(k·i)·row_i over GF(2^32),
core/gf.py): `build_syndromes` / `apply_sdelta` / `patch_syndrome_delta`
are the stack forms of build / bulk-delta / patch, `verify_syndromes`
the per-syndrome invariant, and `reconstruct_e` solves any e <= r
simultaneous rank losses through the e x e Vandermonde inverse.  S_0 IS
the parity above — the single-parity functions are kept for the
r=1-specialized paths (single-loss reconstruction, page repair).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import gf
from repro.core.layout import ZoneLayout
from repro.dist import collectives as coll


# ---------------------------------------------------------------------------
# bulk path
# ---------------------------------------------------------------------------

def build_parity(row: jax.Array, axis_name: str) -> jax.Array:
    """Full parity build: XOR-reduce-scatter rows; rank keeps its segment."""
    return coll.xor_reduce_scatter(row, axis_name)


def apply_delta(parity_seg: jax.Array, delta_row: jax.Array,
                axis_name: str) -> jax.Array:
    """Bulk delta path: parity ^= XOR-reduce-scatter(old ^ new).

    Algebraically identical to `build_parity(row_new)` whenever the XOR
    invariant held before the commit (rs distributes over XOR), but it
    consumes the delta the fused commit kernel already produced — so the
    commit never re-reads the rows to rebuild parity.
    """
    return parity_seg ^ coll.xor_reduce_scatter(delta_row, axis_name)


# ---------------------------------------------------------------------------
# incremental patch path
# ---------------------------------------------------------------------------

def page_view(row: jax.Array, block_words: int) -> jax.Array:
    return row.reshape(-1, block_words)


def gather_pages(row: jax.Array, page_idx: jax.Array,
                 block_words: int) -> jax.Array:
    """(k, block_words) dirty page contents."""
    return page_view(row, block_words)[page_idx]


def patch_parity(parity_seg: jax.Array, old_pages: jax.Array,
                 new_pages: jax.Array, page_idx: jax.Array,
                 layout: ZoneLayout, axis_name: str) -> jax.Array:
    """Apply an incremental parity patch for the dirty pages.

    old_pages/new_pages: (k, block_words) contents of the dirty pages on this
    rank (page set must be SPMD-uniform across the zone); page_idx: (k,)
    global page indices within the row.  Communicates only k pages (XOR
    all-reduce), then each owner XORs the patch into its parity segment.
    """
    from repro.kernels import ops as kops
    delta = kops.xor_delta(old_pages, new_pages)         # (k, bw)
    return patch_parity_delta(parity_seg, delta, page_idx, layout, axis_name)


def patch_parity_delta(parity_seg: jax.Array, delta_pages: jax.Array,
                       page_idx: jax.Array, layout: ZoneLayout,
                       axis_name: str) -> jax.Array:
    """`patch_parity` for callers that already hold the delta.

    The fused commit sweep emits delta pages as a by-product of its single
    pass over (old, new); this entry point applies them without re-reading
    either operand.  The r=1 view of `patch_syndrome_delta`, so the
    owner-scatter semantics live in exactly one place.
    """
    return patch_syndrome_delta(parity_seg[None], delta_pages[None],
                                page_idx, layout, axis_name)[0]


# ---------------------------------------------------------------------------
# syndrome stack: generalized Reed-Solomon S_0..S_{r-1} (beyond paper)
# ---------------------------------------------------------------------------

def build_syndromes(row: jax.Array, r: int, axis_name: str, *,
                    chunks: int = 1) -> jax.Array:
    """Full stack build: (r, seg) — one overlapped collective for all r.

    S_k = XOR_i g^(k·i)·row_i; S_0 is classic XOR parity, so
    `build_syndromes(row, 1, ax)[0] == build_parity(row, ax)` bit-exactly
    (and lowers to the same program).  `chunks > 1` pipelines the GF
    weighting against the all-to-all per segment slice (bit-identical;
    see collectives.syndrome_reduce_scatter).
    """
    return coll.syndrome_reduce_scatter(row, r, axis_name, chunks=chunks)


def apply_sdelta(synd: jax.Array, sdelta_rows: jax.Array,
                 axis_name: str, *, chunks: int = 1) -> jax.Array:
    """Bulk stack delta: synd ^= reduce-scatter of pre-weighted deltas.

    `sdelta_rows` is the (r, n) stack the fused commit sweep emits —
    row k already weighted by g^(k·me) — so the combine is the plain XOR
    collective (GF addition IS XOR), batched across syndromes.
    `chunks > 1` splits the transfer so large-pool commits pipeline.
    """
    return coll.syndrome_apply_delta(synd, sdelta_rows, axis_name,
                                     chunks=chunks)


def patch_syndrome_delta(synd: jax.Array, sdelta_pages: jax.Array,
                         page_idx: jax.Array, layout: ZoneLayout,
                         axis_name: str) -> jax.Array:
    """Incremental stack patch for pre-weighted dirty-page deltas.

    `synd`: (r, seg_words) stack; `sdelta_pages`: (r, k, bw) — syndrome
    k's deltas weighted by g^(k·me).  Every syndrome is linear over XOR
    once the rank scaled its delta, so ONE batched XOR all-reduce
    combines all r patch sets and the owner-scatter routing (computed
    once from `page_idx`) applies across the stack.
    """
    bw = layout.block_words
    r = synd.shape[0]
    patch = coll.xor_all_reduce(sdelta_pages, axis_name)     # (r, k, bw)
    # Page p lives in the segment of rank p // pages_per_seg.
    pages_per_seg = layout.seg_words // bw
    me = lax.axis_index(axis_name)
    owner = page_idx // pages_per_seg
    local_page = page_idx % pages_per_seg
    mine = (owner == me)
    seg_pages = synd.reshape(r, pages_per_seg, bw)
    # Scatter-XOR with O(k) work per syndrome: page indices within one
    # commit are unique, so gather -> xor -> scatter-set is exact;
    # non-owned rows route to the out-of-range sentinel and are dropped
    # by the scatter itself (an earlier version concatenated a dummy row
    # and sliced it back off, which copied the whole segment per patch).
    # This is the "atomic XOR" application — commutativity already did
    # the cross-rank combining in the all-reduce above.
    scatter_idx = jnp.where(mine, local_page, pages_per_seg)
    cur = seg_pages[:, jnp.minimum(scatter_idx, pages_per_seg - 1)]
    out = seg_pages.at[:, scatter_idx].set(cur ^ patch, mode="drop")
    return out.reshape(r, -1)


def verify_syndromes(row: jax.Array, synd: jax.Array,
                     axis_name: str) -> jax.Array:
    """Zone invariant per syndrome: returns (r,) bool, zone-agreed.

    Entry k is True iff XOR_i g^(k·i)·row_i equals the stored S_k on
    every rank (entry 0 is the classic parity invariant).
    """
    r = synd.shape[0]
    fresh = coll.syndrome_reduce_scatter(row, r, axis_name)
    ok_local = jnp.all(fresh == synd, axis=-1)               # (r,)
    return lax.pmin(ok_local.astype(jnp.int32), axis_name) > 0


def reconstruct_e(row: jax.Array, synd: jax.Array, lost_ranks,
                  axis_name: str) -> tuple:
    """Rebuild e <= r lost ranks' rows online from the syndrome stack.

    `lost_ranks` are *static* distinct rank indices (recovery is rare;
    one compiled program per erasure set).  Survivors contribute their
    rows to the first e syndromes; the lost ranks contribute zeros, so

        S_k ^ s_k = XOR_j g^(k·a_j) · X_j        k = 0..e-1

    which `gf.solve_e` inverts with exact host-integer constants.  Every
    rank returns all e reconstructed rows in `lost_ranks` order (the
    lost ranks replace their state; survivors may verify or discard).
    Also covers e-1 losses with an outstanding scribbled rank: name the
    scribbled rank as the extra loss and all come back to intended
    values.
    """
    ranks = tuple(int(a) for a in lost_ranks)
    e = len(ranks)
    assert e >= 1 and len(set(ranks)) == e, ranks
    assert e <= synd.shape[0], (
        f"{e} erasures need {e} syndromes; stack holds {synd.shape[0]}")
    me = lax.axis_index(axis_name)
    lost = functools.reduce(jnp.logical_or,
                            [me == a for a in ranks])
    contrib = jnp.where(lost, jnp.zeros_like(row), row)
    survivors = coll.syndrome_reduce_scatter(contrib, e, axis_name)
    segs = gf.solve_e(synd[:e] ^ survivors, ranks)
    return tuple(coll.all_gather_row(s, axis_name) for s in segs)


# ---------------------------------------------------------------------------
# hybrid (paper §3.5)
# ---------------------------------------------------------------------------

def hybrid_update(row_old: jax.Array, row_new: jax.Array,
                  parity_seg: jax.Array, layout: ZoneLayout,
                  axis_name: str, dirty_page_idx=None,
                  threshold_fraction: float = 0.5) -> jax.Array:
    """Choose the patch or bulk path by dirty fraction (static decision).

    `dirty_page_idx` is a static list/array of dirty page indices, or None
    for "everything changed".  The threshold plays the role of the paper's
    512 B atomic-XOR/plain-XOR crossover.
    """
    n_pages = layout.n_blocks
    if dirty_page_idx is not None and len(dirty_page_idx) == 0:
        # metadata-only transaction (the paper's "free"): parity unchanged
        return parity_seg
    if dirty_page_idx is None or len(dirty_page_idx) / n_pages >= threshold_fraction:
        return build_parity(row_new, axis_name)
    idx = jnp.asarray(dirty_page_idx, jnp.int32)
    old_pages = gather_pages(row_old, idx, layout.block_words)
    new_pages = gather_pages(row_new, idx, layout.block_words)
    return patch_parity(parity_seg, old_pages, new_pages, idx, layout,
                        axis_name)


# ---------------------------------------------------------------------------
# reconstruction (paper §3.6)
# ---------------------------------------------------------------------------

def reconstruct_row(row: jax.Array, parity_seg: jax.Array,
                    lost_rank, axis_name: str) -> jax.Array:
    """Rebuild the lost rank's row online; survivors contribute their rows.

    Every rank returns the same reconstructed row (the lost rank replaces its
    state from it; survivors can discard it or use it for verification).
    """
    me = lax.axis_index(axis_name)
    contrib = jnp.where(me == lost_rank, jnp.zeros_like(row), row)
    # XOR of surviving rows, scattered by segment...
    survivor_seg = coll.xor_reduce_scatter(contrib, axis_name)
    # ... XOR parity segment = lost row's segment, held by each owner.
    # But parity segments are owned per rank; segment i of the lost row is
    # survivor_seg_i XOR parity_seg_i on rank i.
    lost_seg = survivor_seg ^ parity_seg
    return coll.all_gather_row(lost_seg, axis_name)


def verify_parity(row: jax.Array, parity_seg: jax.Array,
                  axis_name: str) -> jax.Array:
    """Zone-wide invariant: XOR of all rows equals parity. Returns bool."""
    fresh = coll.xor_reduce_scatter(row, axis_name)
    ok_local = jnp.all(fresh == parity_seg)
    # AND across the zone == min over {0,1}
    return lax.pmin(ok_local.astype(jnp.int32), axis_name) > 0
