"""XOR parity over zone rows (Pangolin §3.1, §3.5).

All functions run *inside* a shard_map over the full mesh and operate on the
local word row; `axis_name` is the zone (data) axis of size G.

Three update paths, mirroring the paper's hybrid scheme:

  * build      — full XOR reduce-scatter of the rows (initialization, and
                 the "writer lock / plain XOR" path for large updates).
  * patch      — incremental: Delta = old XOR new on the *dirty pages only*,
                 XOR-reduced and applied to the owners' parity segments.
                 XOR's commutativity makes concurrent patches order-free —
                 the paper's atomic-XOR insight, realized as a collective.
  * hybrid     — picks patch vs build from the dirty fraction, the analogue
                 of the paper's 512 B threshold.

Reconstruction (§3.6): lost row r = XOR of surviving rows XOR parity,
computed online by all survivors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import gf
from repro.core.layout import ZoneLayout
from repro.dist import collectives as coll


# ---------------------------------------------------------------------------
# bulk path
# ---------------------------------------------------------------------------

def build_parity(row: jax.Array, axis_name: str) -> jax.Array:
    """Full parity build: XOR-reduce-scatter rows; rank keeps its segment."""
    return coll.xor_reduce_scatter(row, axis_name)


def apply_delta(parity_seg: jax.Array, delta_row: jax.Array,
                axis_name: str) -> jax.Array:
    """Bulk delta path: parity ^= XOR-reduce-scatter(old ^ new).

    Algebraically identical to `build_parity(row_new)` whenever the XOR
    invariant held before the commit (rs distributes over XOR), but it
    consumes the delta the fused commit kernel already produced — so the
    commit never re-reads the rows to rebuild parity.
    """
    return parity_seg ^ coll.xor_reduce_scatter(delta_row, axis_name)


# ---------------------------------------------------------------------------
# incremental patch path
# ---------------------------------------------------------------------------

def page_view(row: jax.Array, block_words: int) -> jax.Array:
    return row.reshape(-1, block_words)


def gather_pages(row: jax.Array, page_idx: jax.Array,
                 block_words: int) -> jax.Array:
    """(k, block_words) dirty page contents."""
    return page_view(row, block_words)[page_idx]


def patch_parity(parity_seg: jax.Array, old_pages: jax.Array,
                 new_pages: jax.Array, page_idx: jax.Array,
                 layout: ZoneLayout, axis_name: str) -> jax.Array:
    """Apply an incremental parity patch for the dirty pages.

    old_pages/new_pages: (k, block_words) contents of the dirty pages on this
    rank (page set must be SPMD-uniform across the zone); page_idx: (k,)
    global page indices within the row.  Communicates only k pages (XOR
    all-reduce), then each owner XORs the patch into its parity segment.
    """
    from repro.kernels import ops as kops
    delta = kops.xor_delta(old_pages, new_pages)         # (k, bw)
    return patch_parity_delta(parity_seg, delta, page_idx, layout, axis_name)


def patch_parity_delta(parity_seg: jax.Array, delta_pages: jax.Array,
                       page_idx: jax.Array, layout: ZoneLayout,
                       axis_name: str) -> jax.Array:
    """`patch_parity` for callers that already hold the delta.

    The fused commit sweep emits delta pages as a by-product of its single
    pass over (old, new); this entry point applies them without re-reading
    either operand.
    """
    bw = layout.block_words
    patch = coll.xor_all_reduce(delta_pages, axis_name)  # (k, bw) on all ranks
    # Page p lives in parity segment of rank p // pages_per_seg.
    pages_per_seg = layout.seg_words // bw
    me = lax.axis_index(axis_name)
    owner = page_idx // pages_per_seg
    local_page = page_idx % pages_per_seg
    mine = (owner == me)
    seg_pages = parity_seg.reshape(pages_per_seg, bw)
    # Scatter-XOR with O(k) work: page indices within one commit are unique,
    # so gather -> xor -> scatter-set is exact; non-owned rows route to the
    # out-of-range sentinel and are dropped by the scatter itself (an
    # earlier version concatenated a dummy row and sliced it back off,
    # which copied the whole parity segment per patch).  This is the
    # "atomic XOR" application — commutativity already did the cross-rank
    # combining in the all-reduce above.
    scatter_idx = jnp.where(mine, local_page, pages_per_seg)
    cur = seg_pages[jnp.minimum(scatter_idx, pages_per_seg - 1)]
    out = seg_pages.at[scatter_idx].set(cur ^ patch, mode="drop")
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# dual parity: the GF(2^32) Q syndrome (beyond paper — two-rank erasure)
# ---------------------------------------------------------------------------

def build_qparity(row: jax.Array, axis_name: str) -> jax.Array:
    """Full Q build: GF-weighted XOR reduce-scatter (rank i adds g^i·row_i)."""
    return coll.gf_reduce_scatter(row, axis_name)


def apply_qdelta(qparity_seg: jax.Array, qdelta_row: jax.Array,
                 axis_name: str) -> jax.Array:
    """Bulk Q delta path: qparity ^= XOR-reduce-scatter(g^me · delta).

    `qdelta_row` is the *pre-weighted* delta (the fused PQ sweep emits
    g^me·(old^new) directly), so the combine is the plain XOR collective —
    GF addition is XOR, and the weighting already happened in VMEM.
    """
    return qparity_seg ^ coll.xor_reduce_scatter(qdelta_row, axis_name)


def patch_qparity_delta(qparity_seg: jax.Array, qdelta_pages: jax.Array,
                        page_idx: jax.Array, layout: ZoneLayout,
                        axis_name: str) -> jax.Array:
    """Incremental Q patch for pre-weighted dirty-page deltas.

    Identical algebra to the P patch — Q is linear over XOR once each
    rank has scaled its delta by g^i — so the owner-scatter machinery is
    shared verbatim.  `qdelta_pages`: (k, bw) g^me-weighted deltas.
    """
    return patch_parity_delta(qparity_seg, qdelta_pages, page_idx, layout,
                              axis_name)


def verify_qparity(row: jax.Array, qparity_seg: jax.Array,
                   axis_name: str) -> jax.Array:
    """Zone invariant: GF-weighted XOR of all rows equals Q.  Returns bool."""
    fresh = coll.gf_reduce_scatter(row, axis_name)
    ok_local = jnp.all(fresh == qparity_seg)
    return lax.pmin(ok_local.astype(jnp.int32), axis_name) > 0


def reconstruct_two(row: jax.Array, parity_seg: jax.Array,
                    qparity_seg: jax.Array, lost_a: int, lost_b: int,
                    axis_name: str) -> tuple:
    """Rebuild TWO lost ranks' rows online from P + Q (2x2 Vandermonde).

    `lost_a` / `lost_b` are *static* distinct rank indices (recovery is
    rare; one compiled program per pair).  Survivors contribute their rows
    to both syndromes; the lost ranks contribute zeros, so

        P ^ S_p = A ^ B,     Q ^ S_q = g^a·A ^ g^b·B

    which `gf.solve_two` inverts with exact host-integer constants.  Every
    rank returns both reconstructed rows (the lost ranks replace their
    state; survivors may verify or discard).  Also covers a rank loss with
    an outstanding scribbled rank: name the scribbled rank as the second
    loss and both come back to intended values.
    """
    lost_a, lost_b = int(lost_a), int(lost_b)
    me = lax.axis_index(axis_name)
    lost = (me == lost_a) | (me == lost_b)
    contrib = jnp.where(lost, jnp.zeros_like(row), row)
    s_p = coll.xor_reduce_scatter(contrib, axis_name)
    s_q = coll.gf_reduce_scatter(contrib, axis_name)
    a_seg, b_seg = gf.solve_two(parity_seg ^ s_p, qparity_seg ^ s_q,
                                lost_a, lost_b)
    return (coll.all_gather_row(a_seg, axis_name),
            coll.all_gather_row(b_seg, axis_name))


# ---------------------------------------------------------------------------
# hybrid (paper §3.5)
# ---------------------------------------------------------------------------

def hybrid_update(row_old: jax.Array, row_new: jax.Array,
                  parity_seg: jax.Array, layout: ZoneLayout,
                  axis_name: str, dirty_page_idx=None,
                  threshold_fraction: float = 0.5) -> jax.Array:
    """Choose the patch or bulk path by dirty fraction (static decision).

    `dirty_page_idx` is a static list/array of dirty page indices, or None
    for "everything changed".  The threshold plays the role of the paper's
    512 B atomic-XOR/plain-XOR crossover.
    """
    n_pages = layout.n_blocks
    if dirty_page_idx is not None and len(dirty_page_idx) == 0:
        # metadata-only transaction (the paper's "free"): parity unchanged
        return parity_seg
    if dirty_page_idx is None or len(dirty_page_idx) / n_pages >= threshold_fraction:
        return build_parity(row_new, axis_name)
    idx = jnp.asarray(dirty_page_idx, jnp.int32)
    old_pages = gather_pages(row_old, idx, layout.block_words)
    new_pages = gather_pages(row_new, idx, layout.block_words)
    return patch_parity(parity_seg, old_pages, new_pages, idx, layout,
                        axis_name)


# ---------------------------------------------------------------------------
# reconstruction (paper §3.6)
# ---------------------------------------------------------------------------

def reconstruct_row(row: jax.Array, parity_seg: jax.Array,
                    lost_rank, axis_name: str) -> jax.Array:
    """Rebuild the lost rank's row online; survivors contribute their rows.

    Every rank returns the same reconstructed row (the lost rank replaces its
    state from it; survivors can discard it or use it for verification).
    """
    me = lax.axis_index(axis_name)
    contrib = jnp.where(me == lost_rank, jnp.zeros_like(row), row)
    # XOR of surviving rows, scattered by segment...
    survivor_seg = coll.xor_reduce_scatter(contrib, axis_name)
    # ... XOR parity segment = lost row's segment, held by each owner.
    # But parity segments are owned per rank; segment i of the lost row is
    # survivor_seg_i XOR parity_seg_i on rank i.
    lost_seg = survivor_seg ^ parity_seg
    return coll.all_gather_row(lost_seg, axis_name)


def verify_parity(row: jax.Array, parity_seg: jax.Array,
                  axis_name: str) -> jax.Array:
    """Zone-wide invariant: XOR of all rows equals parity. Returns bool."""
    fresh = coll.xor_reduce_scatter(row, axis_name)
    ok_local = jnp.all(fresh == parity_seg)
    # AND across the zone == min over {0,1}
    return lax.pmin(ok_local.astype(jnp.int32), axis_name) > 0
