"""Version-compatibility shims for the pinned container toolchain.

The codebase targets the current jax API (`jax.shard_map` with a
`check_vma` flag).  The container pins jax 0.4.x, where shard_map still
lives in `jax.experimental.shard_map` and the flag is named `check_rep`.
Everything routes through this one wrapper so call sites stay written
against the modern API and the shim is deleted wholesale when the pin
moves.
"""
from __future__ import annotations

import jax

# jax.tree.*_with_path landed after 0.4.x; alias the tree_util spellings so
# call sites can use the modern namespace on either version.
if not hasattr(jax.tree, "leaves_with_path"):
    jax.tree.leaves_with_path = jax.tree_util.tree_leaves_with_path
    jax.tree.map_with_path = jax.tree_util.tree_map_with_path
    jax.tree.flatten_with_path = jax.tree_util.tree_flatten_with_path

# lax.axis_size(name) is the modern spelling of the static axis-size query;
# psum of a literal folds to the same static value on 0.4.x.
if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        return jax.lax.psum(1, axis_name)
    jax.lax.axis_size = _axis_size

try:  # jax >= 0.6: public API, replication checking via check_vma
    from jax import shard_map as _shard_map_new

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)

except ImportError:  # jax 0.4.x: experimental API, flag named check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)
