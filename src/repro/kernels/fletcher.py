"""Pallas TPU kernel: per-block Fletcher-64 checksum terms.

This is the TPU analogue of Pangolin's ISA-L SIMD checksum loop (§3.5): a
memory-bound sweep that reads each word once and produces two 32-bit
accumulator lanes per 4 KB page block.  Tiling: TILE_BLOCKS pages per grid
step, each (TILE_BLOCKS, block_words) u32 tile staged in VMEM;
block_words = 1024 = 8 x 128 keeps the lane dimension MXU/VPU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

U32 = jnp.uint32
TILE_BLOCKS = 8  # pages per grid step: 8 x 1024 x 4 B = 32 KB VMEM per input tile


def _fletcher_kernel(x_ref, out_ref):
    x = x_ref[...]                                   # (tb, bw) u32
    bw = x.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(x, axis=-1, dtype=U32)
    b = jnp.sum(x * w, axis=-1, dtype=U32)
    out_ref[...] = jnp.stack([a, b], axis=-1)        # (tb, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fletcher_blocks(blocks: jax.Array, *, interpret: bool = False
                    ) -> jax.Array:
    """blocks: (n_blocks, block_words) u32 -> (n_blocks, 2) u32."""
    n, bw = blocks.shape
    tb = min(TILE_BLOCKS, n)
    assert n % tb == 0, (n, tb)
    return pl.pallas_call(
        _fletcher_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tb, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2), U32),
        interpret=interpret,
    )(blocks)


def _stream_fletcher_kernel(x_hbm, ck_hbm, dig_smem, *, n, cb):
    from repro.kernels import commit_fused as _cf

    bw = x_hbm.shape[1]

    def scoped(xbuf, sems):
        def process(tiles, start, size, carry):
            terms, da, db = _cf._chunk_fletcher(tiles[0], start, n)
            ck_hbm[pl.ds(start, size)] = terms
            return carry[0] + da, carry[1] + db

        a, b = _cf._stream_loop(n, cb, [x_hbm], [xbuf], sems, process,
                                (U32(0), U32(0)))
        dig_smem[0] = a
        dig_smem[1] = b

    pl.run_scoped(scoped,
                  pltpu.VMEM((2, cb, bw), U32),
                  pltpu.SemaphoreType.DMA((2, 1)))


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fletcher_stream(blocks: jax.Array, *, chunk_blocks: int = 8,
                    interpret: bool = False):
    """Streamed sweep: (per-block terms, combined (A, B) row digest).

    Double-buffered HBM->VMEM chunks (see commit_fused's streamed family);
    the digest rides the loop carry, so the flat path's separate
    `checksum.combine` pass over the term table disappears.
    """
    from repro.kernels import commit_fused as _cf

    n, bw = blocks.shape
    cb = _cf._clamp_cb(chunk_blocks, n)
    return pl.pallas_call(
        functools.partial(_stream_fletcher_kernel, n=n, cb=cb),
        in_specs=[_cf._ANY()],
        out_specs=[_cf._ANY(), _cf._SMEM()],
        out_shape=[jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((2,), U32)],
        interpret=interpret,
    )(blocks)
