"""Shared tiling policy for the protection kernels.

Every kernel in this package tiles a (n, m) u32 buffer along the leading
axis; the grid must divide n exactly, so the tile height is the largest
divisor of n no bigger than the kernel's VMEM-budget cap.
"""
from __future__ import annotations


def largest_divisor_tile(n: int, cap: int) -> int:
    """Largest tile height <= cap that divides n exactly."""
    t = min(cap, n)
    while n % t:
        t -= 1
    return t
