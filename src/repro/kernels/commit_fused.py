"""Pallas TPU kernels: fused commit sweeps (beyond-paper optimization).

Pangolin's commit makes separate passes over the modified ranges: verify
the old data's checksums at micro-buffer open, compute the checksum of the
new data, compute the parity patch old ^ new (§3.4-3.5).  All of them are
memory-bound, so on TPU the win is to touch HBM once per operand:

  * `fused_commit`         streams (old, new) tiles through VMEM once and
    emits the parity delta plus the new per-page Fletcher terms.
  * `fused_verify_commit`  additionally folds the verify-at-open into the
    same sweep: the old tile — already in VMEM for the delta — also
    produces its Fletcher terms, compared against the stored checksums.

HBM traffic per page (r = read, w = write, bad = 2-word compare):

  unfused verify+commit = r old (verify) + r old + r new (delta)
                          + r new (checksum) + w delta         = 4 reads
  fused                 = r old + r new + w delta              = 2 reads

=> half the read traffic on the commit hot path; with the Protector's row
cache eliminating the old-state re-flatten as well, the whole MLPC commit
is one sweep over each operand (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import largest_divisor_tile

U32 = jnp.uint32
TILE_BLOCKS = 8
# Streamed variants: pages per double-buffered VMEM chunk.  Each operand
# stages 2 x chunk x block_words words, so the default 8-page chunk costs
# 64 KB of VMEM per u32 operand at bw=1024 — small enough that the 3-operand
# accumulate sweep still fits comfortably alongside the output tiles.
STREAM_CHUNK_BLOCKS = 8


def _pick_tb(n: int) -> int:
    """Largest tile height <= TILE_BLOCKS that divides the block count."""
    return largest_divisor_tile(n, TILE_BLOCKS)


def _fused_kernel(old_ref, new_ref, delta_ref, ck_ref):
    old = old_ref[...]
    new = new_ref[...]
    delta_ref[...] = old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


def _fused_verify_kernel(old_ref, new_ref, stored_ref, delta_ref, ck_ref,
                         mism_ref):
    old = old_ref[...]
    new = new_ref[...]
    delta_ref[...] = old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    # old tile is in VMEM for the delta anyway: its Fletcher terms are free
    a_old = jnp.sum(old, axis=-1, dtype=U32)
    b_old = jnp.sum(old * w, axis=-1, dtype=U32)
    # XOR difference vs stored terms: all-zero == block verifies clean
    mism_ref[...] = jnp.stack([a_old, b_old], axis=-1) ^ stored_ref[...]
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit(old: jax.Array, new: jax.Array, *, interpret: bool = False):
    """old/new: (n_blocks, block_words) u32 -> (delta, cksums)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _fused_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(old, new)


def _verify_call(old: jax.Array, new: jax.Array, stored: jax.Array,
                 interpret: bool):
    """Shared sweep: (delta, new terms, old terms XOR stored)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _fused_verify_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(old, new, stored)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_commit(old: jax.Array, new: jax.Array, stored: jax.Array,
                        *, interpret: bool = False):
    """Single sweep: verify old vs `stored` + delta + new checksums.

    old/new: (n_blocks, block_words) u32; stored: (n_blocks, 2) u32 Fletcher
    terms the old blocks must still match.  Returns (delta, new_cksums,
    bad) with bad: (n_blocks,) bool, True where the old block fails
    verification (the paper's verify-at-micro-buffer-open).
    """
    delta, ck, mism = _verify_call(old, new, stored, interpret)
    return delta, ck, jnp.any(mism != 0, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_old_terms(old: jax.Array, new: jax.Array, *,
                           interpret: bool = False):
    """Single sweep: (delta, new checksums, old checksums).

    The verify kernel's mismatch output is `old_terms XOR stored`; with
    stored = 0 it is the raw old terms — so the parity-only (MLP) patch
    path gets the old-page Fletcher terms its incremental digest needs
    from the same pass that produced the delta, not a second sweep.
    """
    zeros = jnp.zeros((old.shape[0], 2), U32)
    return _verify_call(old, new, zeros, interpret)


def _accum_kernel(acc_ref, old_ref, new_ref, acc_out_ref, old_ck_ref,
                  new_ck_ref):
    acc = acc_ref[...]
    old = old_ref[...]
    new = new_ref[...]
    # XOR deltas telescope: acc ^ (old ^ new) after W steps equals
    # row_epoch_start ^ row_now, the exact delta the epoch flush applies.
    acc_out_ref[...] = acc ^ old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    # both tiles are in VMEM for the accumulate: their Fletcher terms are
    # free, and they are exactly what the incremental row digest needs
    a_old = jnp.sum(old, axis=-1, dtype=U32)
    b_old = jnp.sum(old * w, axis=-1, dtype=U32)
    old_ck_ref[...] = jnp.stack([a_old, b_old], axis=-1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    new_ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_accum_commit(acc: jax.Array, old: jax.Array, new: jax.Array, *,
                       interpret: bool = False):
    """Delta-accumulate variant for the deferred-epoch engine.

    One sweep over (acc, old, new), each (n_blocks, block_words) u32,
    emits the running epoch delta `acc ^ old ^ new` plus the old and new
    per-block Fletcher terms.  In-window commits use it to fold the
    step's XOR delta into the epoch accumulator and keep the row digest
    current (from the term deltas) without touching parity or the
    checksum table — those consume the accumulator once per epoch, so
    the flush is still one sweep per operand.
    """
    assert acc.shape == old.shape == new.shape, (acc.shape, old.shape,
                                                 new.shape)
    assert acc.dtype == old.dtype == new.dtype == U32
    n, bw = old.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _accum_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(acc, old, new)


# ---------------------------------------------------------------------------
# blockwise double-buffered streaming variants
# ---------------------------------------------------------------------------
# The flat kernels above hand whole-row tiles to the Pallas grid, which is
# fine while n_blocks * block_words fits the automatic pipelining budget but
# leaves the copy/compute overlap to the compiler.  The `*_stream` family
# below owns the pipeline explicitly: operands live in ANY (HBM) memory, the
# kernel streams them through a 2-deep VMEM ring with manual async copies —
# chunk i+1's DMA is issued before chunk i's compute begins — and the
# Fletcher digest of the whole row rides along as a loop-carried (A, B)
# accumulator, so one sweep emits the delta, the per-block terms AND the
# combined row digest (the flat path needs a separate `checksum.combine`
# pass over the terms).  The ragged tail (n % chunk) is a statically-sized
# epilogue chunk: DMA slice extents must be static, so the loop covers the
# n // chunk full chunks and the remainder is one extra literal-size copy.


def _stream_loop(n, cb, in_refs, bufs, sems, process, carry0):
    """Double-buffered DMA stream over row-major (n, ...) HBM operands.

    `bufs[j]` is a (2, cb, ...) VMEM ring for `in_refs[j]`; `sems` is a
    (2, len(in_refs)) DMA semaphore grid.  `process(tiles, start, size,
    carry)` sees the chunk's VMEM tiles and returns the updated carry.
    """
    nfull, tail = n // cb, n % cb

    def copies(slot, start, size):
        return [pltpu.make_async_copy(ref.at[pl.ds(start, size)],
                                      buf.at[slot, pl.ds(0, size)],
                                      sems.at[slot, j])
                for j, (ref, buf) in enumerate(zip(in_refs, bufs))]

    def start_chunk(slot, start, size):
        for c in copies(slot, start, size):
            c.start()

    def wait_chunk(slot, start, size):
        for c in copies(slot, start, size):
            c.wait()

    carry = carry0
    if nfull:
        start_chunk(0, 0, cb)

        def body(ci, carry):
            slot = jax.lax.rem(ci, 2)

            @pl.when(ci + 1 < nfull)
            def _prefetch():
                start_chunk(1 - slot, (ci + 1) * cb, cb)

            wait_chunk(slot, ci * cb, cb)
            tiles = [buf[slot, pl.ds(0, cb)] for buf in bufs]
            return process(tiles, ci * cb, cb, carry)

        carry = jax.lax.fori_loop(0, nfull, body, carry)
    if tail:
        start_chunk(0, nfull * cb, tail)
        wait_chunk(0, nfull * cb, tail)
        tiles = [buf[0, pl.ds(0, tail)] for buf in bufs]
        carry = process(tiles, nfull * cb, tail, carry)
    return carry


def _chunk_fletcher(x, start, n):
    """Per-block Fletcher terms of a chunk + its global digest contribution.

    The combine rule (core/checksum.py) weights block p's A term by the
    words after it, (n - 1 - p) * bw; positions are global, so a running
    (sum dA, sum dB) carry over chunks lands bit-identical to
    `checksum.combine` over the full term table.
    """
    bw = x.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(x, axis=-1, keepdims=True, dtype=U32)
    b = jnp.sum(x * w, axis=-1, keepdims=True, dtype=U32)
    pos = U32(start) + jax.lax.broadcasted_iota(U32, (x.shape[0], 1), 0)
    after = (U32(n - 1) - pos) * U32(bw)
    dig_a = jnp.sum(a, dtype=U32)
    dig_b = jnp.sum(b + after * a, dtype=U32)
    return jnp.concatenate([a, b], axis=-1), dig_a, dig_b


def _stream_commit_kernel(old_hbm, new_hbm, delta_hbm, ck_hbm, dig_smem, *,
                          n, cb):
    bw = old_hbm.shape[1]

    def scoped(obuf, nbuf, sems):
        def process(tiles, start, size, carry):
            o, nw = tiles
            delta_hbm[pl.ds(start, size)] = o ^ nw
            terms, da, db = _chunk_fletcher(nw, start, n)
            ck_hbm[pl.ds(start, size)] = terms
            return carry[0] + da, carry[1] + db

        a, b = _stream_loop(n, cb, [old_hbm, new_hbm], [obuf, nbuf], sems,
                            process, (U32(0), U32(0)))
        dig_smem[0] = a
        dig_smem[1] = b

    pl.run_scoped(scoped,
                  obuf=pltpu.VMEM((2, cb, bw), U32),
                  nbuf=pltpu.VMEM((2, cb, bw), U32),
                  sems=pltpu.SemaphoreType.DMA((2, 2)))


def _stream_verify_kernel(old_hbm, new_hbm, stored_hbm, delta_hbm, ck_hbm,
                          mism_hbm, dig_smem, *, n, cb):
    bw = old_hbm.shape[1]

    def scoped(obuf, nbuf, stbuf, sems):
        def process(tiles, start, size, carry):
            o, nw, st = tiles
            delta_hbm[pl.ds(start, size)] = o ^ nw
            oterms, _, _ = _chunk_fletcher(o, start, n)
            mism_hbm[pl.ds(start, size)] = oterms ^ st
            terms, da, db = _chunk_fletcher(nw, start, n)
            ck_hbm[pl.ds(start, size)] = terms
            return carry[0] + da, carry[1] + db

        a, b = _stream_loop(n, cb, [old_hbm, new_hbm, stored_hbm],
                            [obuf, nbuf, stbuf], sems, process,
                            (U32(0), U32(0)))
        dig_smem[0] = a
        dig_smem[1] = b

    pl.run_scoped(scoped,
                  obuf=pltpu.VMEM((2, cb, bw), U32),
                  nbuf=pltpu.VMEM((2, cb, bw), U32),
                  stbuf=pltpu.VMEM((2, cb, 2), U32),
                  sems=pltpu.SemaphoreType.DMA((2, 3)))


def _stream_accum_kernel(acc_hbm, old_hbm, new_hbm, acc_out_hbm, old_ck_hbm,
                         new_ck_hbm, dig_smem, *, n, cb):
    bw = old_hbm.shape[1]

    def scoped(abuf, obuf, nbuf, sems):
        def process(tiles, start, size, carry):
            ac, o, nw = tiles
            acc_out_hbm[pl.ds(start, size)] = ac ^ o ^ nw
            oterms, _, _ = _chunk_fletcher(o, start, n)
            old_ck_hbm[pl.ds(start, size)] = oterms
            terms, da, db = _chunk_fletcher(nw, start, n)
            new_ck_hbm[pl.ds(start, size)] = terms
            return carry[0] + da, carry[1] + db

        a, b = _stream_loop(n, cb, [acc_hbm, old_hbm, new_hbm],
                            [abuf, obuf, nbuf], sems, process,
                            (U32(0), U32(0)))
        dig_smem[0] = a
        dig_smem[1] = b

    pl.run_scoped(scoped,
                  abuf=pltpu.VMEM((2, cb, bw), U32),
                  obuf=pltpu.VMEM((2, cb, bw), U32),
                  nbuf=pltpu.VMEM((2, cb, bw), U32),
                  sems=pltpu.SemaphoreType.DMA((2, 3)))


def _clamp_cb(chunk_blocks: int, n: int) -> int:
    return max(1, min(int(chunk_blocks), n))


_ANY = functools.partial(pl.BlockSpec, memory_space=pltpu.ANY)
_SMEM = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_commit_stream(old: jax.Array, new: jax.Array, *,
                        chunk_blocks: int = STREAM_CHUNK_BLOCKS,
                        interpret: bool = False):
    """Streamed fused_commit: (delta, cksums, (A, B) row digest)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    cb = _clamp_cb(chunk_blocks, n)
    return pl.pallas_call(
        functools.partial(_stream_commit_kernel, n=n, cb=cb),
        in_specs=[_ANY(), _ANY()],
        out_specs=[_ANY(), _ANY(), _SMEM()],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((2,), U32)],
        interpret=interpret,
    )(old, new)


def _verify_stream_call(old, new, stored, chunk_blocks, interpret):
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
    cb = _clamp_cb(chunk_blocks, n)
    return pl.pallas_call(
        functools.partial(_stream_verify_kernel, n=n, cb=cb),
        in_specs=[_ANY(), _ANY(), _ANY()],
        out_specs=[_ANY(), _ANY(), _ANY(), _SMEM()],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((2,), U32)],
        interpret=interpret,
    )(old, new, stored)


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_verify_commit_stream(old: jax.Array, new: jax.Array,
                               stored: jax.Array, *,
                               chunk_blocks: int = STREAM_CHUNK_BLOCKS,
                               interpret: bool = False):
    """Streamed fused_verify_commit: (delta, cksums, bad, digest)."""
    delta, ck, mism, dig = _verify_stream_call(old, new, stored,
                                               chunk_blocks, interpret)
    return delta, ck, jnp.any(mism != 0, axis=-1), dig


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_commit_old_terms_stream(old: jax.Array, new: jax.Array, *,
                                  chunk_blocks: int = STREAM_CHUNK_BLOCKS,
                                  interpret: bool = False):
    """Streamed fused_commit_old_terms: (delta, new ck, old ck, digest)."""
    zeros = jnp.zeros((old.shape[0], 2), U32)
    return _verify_stream_call(old, new, zeros, chunk_blocks, interpret)


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_accum_commit_stream(acc: jax.Array, old: jax.Array,
                              new: jax.Array, *,
                              chunk_blocks: int = STREAM_CHUNK_BLOCKS,
                              interpret: bool = False):
    """Streamed fused_accum_commit: (acc', old ck, new ck, new digest)."""
    assert acc.shape == old.shape == new.shape, (acc.shape, old.shape,
                                                 new.shape)
    assert acc.dtype == old.dtype == new.dtype == U32
    n, bw = old.shape
    cb = _clamp_cb(chunk_blocks, n)
    return pl.pallas_call(
        functools.partial(_stream_accum_kernel, n=n, cb=cb),
        in_specs=[_ANY(), _ANY(), _ANY()],
        out_specs=[_ANY(), _ANY(), _ANY(), _SMEM()],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((2,), U32)],
        interpret=interpret,
    )(acc, old, new)
