"""Pallas TPU kernels: fused commit sweeps (beyond-paper optimization).

Pangolin's commit makes separate passes over the modified ranges: verify
the old data's checksums at micro-buffer open, compute the checksum of the
new data, compute the parity patch old ^ new (§3.4-3.5).  All of them are
memory-bound, so on TPU the win is to touch HBM once per operand:

  * `fused_commit`         streams (old, new) tiles through VMEM once and
    emits the parity delta plus the new per-page Fletcher terms.
  * `fused_verify_commit`  additionally folds the verify-at-open into the
    same sweep: the old tile — already in VMEM for the delta — also
    produces its Fletcher terms, compared against the stored checksums.

HBM traffic per page (r = read, w = write, bad = 2-word compare):

  unfused verify+commit = r old (verify) + r old + r new (delta)
                          + r new (checksum) + w delta         = 4 reads
  fused                 = r old + r new + w delta              = 2 reads

=> half the read traffic on the commit hot path; with the Protector's row
cache eliminating the old-state re-flatten as well, the whole MLPC commit
is one sweep over each operand (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import largest_divisor_tile

U32 = jnp.uint32
TILE_BLOCKS = 8


def _pick_tb(n: int) -> int:
    """Largest tile height <= TILE_BLOCKS that divides the block count."""
    return largest_divisor_tile(n, TILE_BLOCKS)


def _fused_kernel(old_ref, new_ref, delta_ref, ck_ref):
    old = old_ref[...]
    new = new_ref[...]
    delta_ref[...] = old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


def _fused_verify_kernel(old_ref, new_ref, stored_ref, delta_ref, ck_ref,
                         mism_ref):
    old = old_ref[...]
    new = new_ref[...]
    delta_ref[...] = old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    # old tile is in VMEM for the delta anyway: its Fletcher terms are free
    a_old = jnp.sum(old, axis=-1, dtype=U32)
    b_old = jnp.sum(old * w, axis=-1, dtype=U32)
    # XOR difference vs stored terms: all-zero == block verifies clean
    mism_ref[...] = jnp.stack([a_old, b_old], axis=-1) ^ stored_ref[...]
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit(old: jax.Array, new: jax.Array, *, interpret: bool = False):
    """old/new: (n_blocks, block_words) u32 -> (delta, cksums)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _fused_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(old, new)


def _verify_call(old: jax.Array, new: jax.Array, stored: jax.Array,
                 interpret: bool):
    """Shared sweep: (delta, new terms, old terms XOR stored)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _fused_verify_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(old, new, stored)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_commit(old: jax.Array, new: jax.Array, stored: jax.Array,
                        *, interpret: bool = False):
    """Single sweep: verify old vs `stored` + delta + new checksums.

    old/new: (n_blocks, block_words) u32; stored: (n_blocks, 2) u32 Fletcher
    terms the old blocks must still match.  Returns (delta, new_cksums,
    bad) with bad: (n_blocks,) bool, True where the old block fails
    verification (the paper's verify-at-micro-buffer-open).
    """
    delta, ck, mism = _verify_call(old, new, stored, interpret)
    return delta, ck, jnp.any(mism != 0, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_old_terms(old: jax.Array, new: jax.Array, *,
                           interpret: bool = False):
    """Single sweep: (delta, new checksums, old checksums).

    The verify kernel's mismatch output is `old_terms XOR stored`; with
    stored = 0 it is the raw old terms — so the parity-only (MLP) patch
    path gets the old-page Fletcher terms its incremental digest needs
    from the same pass that produced the delta, not a second sweep.
    """
    zeros = jnp.zeros((old.shape[0], 2), U32)
    return _verify_call(old, new, zeros, interpret)


def _accum_kernel(acc_ref, old_ref, new_ref, acc_out_ref, old_ck_ref,
                  new_ck_ref):
    acc = acc_ref[...]
    old = old_ref[...]
    new = new_ref[...]
    # XOR deltas telescope: acc ^ (old ^ new) after W steps equals
    # row_epoch_start ^ row_now, the exact delta the epoch flush applies.
    acc_out_ref[...] = acc ^ old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    # both tiles are in VMEM for the accumulate: their Fletcher terms are
    # free, and they are exactly what the incremental row digest needs
    a_old = jnp.sum(old, axis=-1, dtype=U32)
    b_old = jnp.sum(old * w, axis=-1, dtype=U32)
    old_ck_ref[...] = jnp.stack([a_old, b_old], axis=-1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    new_ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_accum_commit(acc: jax.Array, old: jax.Array, new: jax.Array, *,
                       interpret: bool = False):
    """Delta-accumulate variant for the deferred-epoch engine.

    One sweep over (acc, old, new), each (n_blocks, block_words) u32,
    emits the running epoch delta `acc ^ old ^ new` plus the old and new
    per-block Fletcher terms.  In-window commits use it to fold the
    step's XOR delta into the epoch accumulator and keep the row digest
    current (from the term deltas) without touching parity or the
    checksum table — those consume the accumulator once per epoch, so
    the flush is still one sweep per operand.
    """
    assert acc.shape == old.shape == new.shape, (acc.shape, old.shape,
                                                 new.shape)
    assert acc.dtype == old.dtype == new.dtype == U32
    n, bw = old.shape
    tb = _pick_tb(n)
    return pl.pallas_call(
        _accum_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(acc, old, new)
