"""Pallas TPU kernel: fused commit sweep (beyond-paper optimization).

Pangolin's commit makes three passes over the modified ranges: compute the
checksum of the new data, compute the parity patch old ^ new, and write the
data back (§3.4-3.5).  All three are memory-bound, so on TPU the win is to
touch HBM once: this kernel streams (old, new) tiles through VMEM a single
time and emits both the parity delta and the per-page Fletcher terms.

HBM traffic per page:  unfused = read old + 2x read new + write delta
                       fused   = read old + 1x read new + write delta
=> 25% less traffic on the commit hot path (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32
TILE_BLOCKS = 8


def _fused_kernel(old_ref, new_ref, delta_ref, ck_ref):
    old = old_ref[...]
    new = new_ref[...]
    delta_ref[...] = old ^ new
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit(old: jax.Array, new: jax.Array, *, interpret: bool = False):
    """old/new: (n_blocks, block_words) u32 -> (delta, cksums)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    tb = min(TILE_BLOCKS, n)
    assert n % tb == 0, (n, tb)
    return pl.pallas_call(
        _fused_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(old, new)
