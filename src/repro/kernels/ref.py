"""Pure-jnp oracles for the protection kernels.

These define the semantics the Pallas kernels must match bit-for-bit; the
kernel tests sweep shapes/dtypes and assert exact equality against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32


def fletcher_blocks_ref(blocks: jax.Array) -> jax.Array:
    """Per-block Fletcher-64 terms.  blocks: (n, bw) u32 -> (n, 2) u32.

    A = sum_i w_i; B = sum_i (bw - i) * w_i, both mod 2^32 (wraparound).
    """
    assert blocks.dtype == U32
    bw = blocks.shape[-1]
    w = (U32(bw) - jnp.arange(bw, dtype=U32))[None, :]
    a = jnp.sum(blocks, axis=-1, dtype=U32)
    b = jnp.sum(blocks * w, axis=-1, dtype=U32)
    return jnp.stack([a, b], axis=-1)


def xor_delta_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Bitwise parity delta of two u32 buffers (any shape)."""
    assert a.dtype == U32 and b.dtype == U32
    return a ^ b


def xor_accum_ref(parity: jax.Array, patch: jax.Array) -> jax.Array:
    """Accumulate a patch into parity (the 'atomic XOR' application)."""
    return parity ^ patch


def fused_commit_ref(old: jax.Array, new: jax.Array):
    """Fused commit pass: (delta, new-block checksums) in one logical sweep.

    old/new: (n, bw) u32.  Returns (delta (n, bw), cksums (n, 2)).
    The unfused baseline reads `new` twice (once for delta, once for
    checksums); the fused kernel reads old+new exactly once.
    """
    return xor_delta_ref(old, new), fletcher_blocks_ref(new)


def fused_verify_commit_ref(old: jax.Array, new: jax.Array,
                            stored: jax.Array):
    """Verify + delta + new checksums, semantics of the fused sweep.

    old/new: (n, bw) u32; stored: (n, 2) u32.  Returns (delta, new cksums,
    bad (n,) bool) where bad marks old blocks whose recomputed Fletcher
    terms no longer match `stored` (verify-at-micro-buffer-open).
    """
    assert stored.shape == (old.shape[0], 2) and stored.dtype == U32
    bad = jnp.any(fletcher_blocks_ref(old) != stored, axis=-1)
    return xor_delta_ref(old, new), fletcher_blocks_ref(new), bad


def fused_commit_old_terms_ref(old: jax.Array, new: jax.Array):
    """(delta, new cksums, old cksums) — one logical sweep per operand."""
    return (xor_delta_ref(old, new), fletcher_blocks_ref(new),
            fletcher_blocks_ref(old))


def gf_scale_ref(x: jax.Array, coeff) -> jax.Array:
    """Element-wise GF(2^32) multiply by a scalar coefficient."""
    from repro.core import gf
    return gf.mul_const(x, coeff)


def sdelta_stack_ref(delta: jax.Array, coeffs: jax.Array) -> jax.Array:
    """The (r, *delta.shape) weighted-delta stack of the syndrome sweep.

    Plane k is coeffs[k]·delta in GF(2^32); plane 0 is the raw delta
    (coeffs[0] is g^0 = 1 by construction, so the multiply is skipped —
    semantics AND cost of the kernels' k=0 fast path).
    """
    r = coeffs.shape[0]
    return jnp.stack([delta] + [gf_scale_ref(delta, coeffs[k])
                                for k in range(1, r)])


def fused_commit_s_ref(old: jax.Array, new: jax.Array, coeffs):
    """Syndrome commit sweep: ((r, n, bw) sdeltas, new cksums).

    Syndrome k's delta is the GF(2^32)-weighted XOR delta — weighted by
    the committing rank's g^(k·me) so the zone collective can combine it
    with plain XOR (core/gf.py).
    """
    d = xor_delta_ref(old, new)
    return sdelta_stack_ref(d, coeffs), fletcher_blocks_ref(new)


def fused_verify_commit_s_ref(old: jax.Array, new: jax.Array,
                              stored: jax.Array, coeffs):
    """Verify + r sdeltas + new checksums, one logical sweep."""
    assert stored.shape == (old.shape[0], 2) and stored.dtype == U32
    bad = jnp.any(fletcher_blocks_ref(old) != stored, axis=-1)
    d = xor_delta_ref(old, new)
    return sdelta_stack_ref(d, coeffs), fletcher_blocks_ref(new), bad


def fused_commit_old_terms_s_ref(old: jax.Array, new: jax.Array, coeffs):
    """(sdeltas, new cksums, old cksums) — the stacked patch sweep."""
    d = xor_delta_ref(old, new)
    return (sdelta_stack_ref(d, coeffs), fletcher_blocks_ref(new),
            fletcher_blocks_ref(old))


def fused_accum_commit_ref(acc: jax.Array, old: jax.Array, new: jax.Array):
    """Delta-accumulate sweep of the deferred-epoch engine.

    acc/old/new: (n, bw) u32.  Returns (acc ^ old ^ new, old cksums,
    new cksums): the step's XOR delta folded into the epoch accumulator
    (deltas telescope, so after W steps acc == row_start ^ row_now) plus
    both term sets for the incremental row digest.
    """
    assert acc.shape == old.shape == new.shape
    assert acc.dtype == U32 and old.dtype == U32 and new.dtype == U32
    return (acc ^ old ^ new, fletcher_blocks_ref(old),
            fletcher_blocks_ref(new))


def digest_ref(cksums: jax.Array, block_words: int) -> jax.Array:
    """`checksum.combine` restated here so oracles stay dependency-free.

    Block p's A term counts (n - 1 - p) * block_words extra times in B —
    the words after it — which is exactly the per-chunk weighting the
    streamed kernels fold into their loop-carried (A, B) digest.
    """
    n = cksums.shape[0]
    a_blocks = cksums[:, 0]
    b_blocks = cksums[:, 1]
    a = jnp.sum(a_blocks, dtype=U32)
    after = ((n - 1 - jnp.arange(n, dtype=U32)) * U32(block_words))
    b = jnp.sum(b_blocks + after * a_blocks, dtype=U32)
    return jnp.stack([a, b])


# --- streamed-variant oracles: flat semantics + the riding row digest ----

def fletcher_stream_ref(blocks: jax.Array):
    ck = fletcher_blocks_ref(blocks)
    return ck, digest_ref(ck, blocks.shape[-1])


def fused_commit_stream_ref(old: jax.Array, new: jax.Array):
    delta, ck = fused_commit_ref(old, new)
    return delta, ck, digest_ref(ck, new.shape[-1])


def fused_verify_commit_stream_ref(old: jax.Array, new: jax.Array,
                                   stored: jax.Array):
    delta, ck, bad = fused_verify_commit_ref(old, new, stored)
    return delta, ck, bad, digest_ref(ck, new.shape[-1])


def fused_commit_old_terms_stream_ref(old: jax.Array, new: jax.Array):
    delta, ck, old_ck = fused_commit_old_terms_ref(old, new)
    return delta, ck, old_ck, digest_ref(ck, new.shape[-1])


def fused_accum_commit_stream_ref(acc: jax.Array, old: jax.Array,
                                  new: jax.Array):
    acc_out, old_ck, new_ck = fused_accum_commit_ref(acc, old, new)
    return acc_out, old_ck, new_ck, digest_ref(new_ck, new.shape[-1])


def fused_commit_s_stream_ref(old: jax.Array, new: jax.Array, coeffs):
    sdelta, ck = fused_commit_s_ref(old, new, coeffs)
    return sdelta, ck, digest_ref(ck, new.shape[-1])


def fused_verify_commit_s_stream_ref(old: jax.Array, new: jax.Array,
                                     stored: jax.Array, coeffs):
    sdelta, ck, bad = fused_verify_commit_s_ref(old, new, stored, coeffs)
    return sdelta, ck, bad, digest_ref(ck, new.shape[-1])
