"""Pallas TPU kernels: GF(2^32)-weighted syndrome sweeps (Reed-Solomon).

The syndrome stack is S_k = XOR_i g^(k·i)·row_i, k = 0..r-1, with
multiplication in GF(2^32) (core/gf.py), so a commit that already sweeps
(old, new) for the XOR delta can emit ALL r weighted deltas from the
same VMEM tiles: sdelta_k = g^(k·me) · (old ^ new), a 32-step branch-free
clmul per word per extra syndrome — pure VPU bit-ops, no extra HBM
reads.  The kernels here fuse that weighting with the existing
verify+checksum sweep (kernels/commit_fused.py):

  * `gf_scale`               — standalone element-wise y = coeff · x
    (epoch-flush syndrome patches for parity-only modes).
  * `fused_commit_s`         — one sweep over (old, new) emitting
    ((r, n, bw) weighted deltas, new Fletcher terms).
  * `fused_verify_commit_s`  — additionally folds verify-at-open over
    the old tile (terms XOR stored, all-zero == clean).
  * `fused_commit_old_terms_s` — the stored=0 specialization whose
    mismatch output is the raw old terms (MLP's incremental digest).

Syndrome 0's weight is g^0 = 1 by construction, so the k=0 plane is the
raw delta written without any clmul — r=1 costs exactly what the
single-parity fused sweep costs (and kernels/ops.py routes r=1 straight
to the commit_fused family, keeping the compiled program byte-identical
to the pre-stack engine).  HBM traffic per page is r-proportional only
in the unavoidable weighted-delta *writes* (r old + r new reads never
happen — one read each); the GF weighting itself is free, which is what
makes redundancy=r cost r-1 extra write streams rather than extra
passes.

The per-rank coefficient vector (g^(k·me))_k is a *traced* operand (one
axis_index table lookup), fed to the kernel as an (r, 1) u32 operand so
one compiled program serves every rank of the zone.  `kernels/ref.py`
carries the jnp oracles these must match bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import commit_fused as _cf
from repro.kernels.tiling import largest_divisor_tile as _pick_tile

U32 = jnp.uint32
TILE_BLOCKS = 8
TILE_ROWS = 512          # gf_scale tile height (matches xor_parity.py)


def _gf_mul_tile(x, coeff):
    """Branch-free 32-step clmul of a tile by a scalar coefficient."""
    poly = U32(0x400007)                      # gf.POLY, inlined for Mosaic
    acc = jnp.zeros_like(x)
    cur = x
    for i in range(32):
        bit = (coeff >> U32(i)) & U32(1)
        acc = acc ^ (bit * cur)
        cur = (cur << U32(1)) ^ ((cur >> U32(31)) * poly)
    return acc


# ---------------------------------------------------------------------------
# standalone scale
# ---------------------------------------------------------------------------

def _gf_scale_kernel(coeff_ref, x_ref, o_ref):
    o_ref[...] = _gf_mul_tile(x_ref[...], coeff_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf_scale(x: jax.Array, coeff: jax.Array, *, interpret: bool = False
             ) -> jax.Array:
    """Element-wise y = coeff · x in GF(2^32); coeff a (traced) u32 scalar."""
    assert x.dtype == U32, x.dtype
    shape = x.shape
    if x.ndim == 1:
        x = x.reshape(-1, 1024) if x.size % 1024 == 0 else x.reshape(1, -1)
    n, m = x.shape
    t = _pick_tile(n, TILE_ROWS)
    coeff = jnp.asarray(coeff, U32).reshape(1, 1)
    out = pl.pallas_call(
        _gf_scale_kernel,
        grid=(n // t,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((t, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((t, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), U32),
        interpret=interpret,
    )(coeff, x)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# fused r-syndrome commit sweeps
# ---------------------------------------------------------------------------

def _fletcher_terms(x):
    bw = x.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(x, axis=-1, dtype=U32)
    b = jnp.sum(x * w, axis=-1, dtype=U32)
    return jnp.stack([a, b], axis=-1)


def _make_s_kernel(r: int, verify: bool):
    """Kernel body: delta + r-1 weighted planes [+ verify] + checksums.

    The delta tile is computed once in VMEM; plane 0 writes it raw
    (g^0 = 1 statically), planes 1..r-1 each run one clmul over the
    same registers — no tile is re-read.
    """
    def kernel(coeff_ref, old_ref, new_ref, *refs):
        if verify:
            stored_ref, sdelta_ref, ck_ref, mism_ref = refs
        else:
            sdelta_ref, ck_ref = refs
        old = old_ref[...]
        new = new_ref[...]
        d = old ^ new
        sdelta_ref[0] = d
        for k in range(1, r):
            sdelta_ref[k] = _gf_mul_tile(d, coeff_ref[k, 0])
        if verify:
            mism_ref[...] = _fletcher_terms(old) ^ stored_ref[...]
        ck_ref[...] = _fletcher_terms(new)
    return kernel


def _s_call(old, new, stored, coeffs, r, interpret):
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    tb = _pick_tile(n, TILE_BLOCKS)
    coeffs = jnp.asarray(coeffs, U32).reshape(r, 1)
    verify = stored is not None
    in_specs = [pl.BlockSpec((r, 1), lambda i: (0, 0)),
                pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                pl.BlockSpec((tb, bw), lambda i: (i, 0))]
    operands = [coeffs, old, new]
    out_specs = [pl.BlockSpec((r, tb, bw), lambda i: (0, i, 0)),
                 pl.BlockSpec((tb, 2), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((r, n, bw), U32),
                 jax.ShapeDtypeStruct((n, 2), U32)]
    if verify:
        assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
        in_specs.append(pl.BlockSpec((tb, 2), lambda i: (i, 0)))
        operands.append(stored)
        out_specs.append(pl.BlockSpec((tb, 2), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((n, 2), U32))
    return pl.pallas_call(
        _make_s_kernel(r, verify),
        grid=(n // tb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_s(old: jax.Array, new: jax.Array, coeffs: jax.Array, *,
                   interpret: bool = False):
    """One sweep over (old, new): ((r, n, bw) sdeltas, new Fletcher terms)."""
    r = coeffs.shape[0]
    sdelta, ck = _s_call(old, new, None, coeffs, r, interpret)
    return sdelta, ck


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_commit_s(old: jax.Array, new: jax.Array, stored: jax.Array,
                          coeffs: jax.Array, *, interpret: bool = False):
    """Verify + r sdeltas + new checksums from one sweep.

    Returns (sdelta, new_cksums, bad) with bad True where the old
    block's recomputed Fletcher terms no longer match `stored`.
    """
    r = coeffs.shape[0]
    sdelta, ck, mism = _s_call(old, new, stored, coeffs, r, interpret)
    return sdelta, ck, jnp.any(mism != 0, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_old_terms_s(old: jax.Array, new: jax.Array,
                             coeffs: jax.Array, *, interpret: bool = False):
    """(sdelta, new cksums, old cksums) — the MLP-ladder patch sweep."""
    r = coeffs.shape[0]
    zeros = jnp.zeros((old.shape[0], 2), U32)
    return _s_call(old, new, zeros, coeffs, r, interpret)


# ---------------------------------------------------------------------------
# stacked-plane standalone scale
# ---------------------------------------------------------------------------

def _make_sdelta_stack_kernel(r: int):
    def kernel(coeff_ref, x_ref, o_ref):
        x = x_ref[...]
        o_ref[0] = x
        for k in range(1, r):
            o_ref[k] = _gf_mul_tile(x, coeff_ref[k, 0])
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def sdelta_stack(x: jax.Array, coeffs: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """(r, *x.shape) weighted stack from ONE read of x.

    The per-plane `gf_scale` loop reads the delta r-1 times (and the
    stack concat copies it again); here each VMEM tile is weighted into
    all r output planes while resident, so HBM traffic is 1 read +
    r writes regardless of redundancy.  Plane 0 is the raw delta
    (coeffs[0] = g^0 = 1, statically skipped).
    """
    assert x.dtype == U32, x.dtype
    shape = x.shape
    if x.ndim == 1:
        x = x.reshape(-1, 1024) if x.size % 1024 == 0 else x.reshape(1, -1)
    n, m = x.shape
    r = coeffs.shape[0]
    t = _pick_tile(n, TILE_ROWS)
    coeffs = jnp.asarray(coeffs, U32).reshape(r, 1)
    out = pl.pallas_call(
        _make_sdelta_stack_kernel(r),
        grid=(n // t,),
        in_specs=[pl.BlockSpec((r, 1), lambda i: (0, 0)),
                  pl.BlockSpec((t, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((r, t, m), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n, m), U32),
        interpret=interpret,
    )(coeffs, x)
    return out.reshape((r,) + shape)


# ---------------------------------------------------------------------------
# blockwise double-buffered streaming variants
# ---------------------------------------------------------------------------
# Same pipeline as commit_fused's streamed family (see the discussion
# there): operands stay in HBM, a 2-deep VMEM ring double-buffers the
# chunks, and the whole-row Fletcher digest rides the loop carry.  Each
# resident delta chunk is weighted into all r syndrome planes before the
# ring slot is recycled — one read of (old, new) regardless of redundancy.

def _make_stream_s_kernel(n, cb, r, verify):
    def kernel(coeff_smem, old_hbm, new_hbm, *refs):
        if verify:
            stored_hbm, sdelta_hbm, ck_hbm, mism_hbm, dig_smem = refs
        else:
            sdelta_hbm, ck_hbm, dig_smem = refs
        bw = old_hbm.shape[1]

        def scoped(*scratch):
            if verify:
                obuf, nbuf, stbuf, sems = scratch
                in_refs = [old_hbm, new_hbm, stored_hbm]
                bufs = [obuf, nbuf, stbuf]
            else:
                obuf, nbuf, sems = scratch
                in_refs = [old_hbm, new_hbm]
                bufs = [obuf, nbuf]

            def process(tiles, start, size, carry):
                o, nw = tiles[0], tiles[1]
                d = o ^ nw
                sdelta_hbm[0, pl.ds(start, size)] = d
                for k in range(1, r):
                    sdelta_hbm[k, pl.ds(start, size)] = _gf_mul_tile(
                        d, coeff_smem[k])
                if verify:
                    oterms, _, _ = _cf._chunk_fletcher(o, start, n)
                    mism_hbm[pl.ds(start, size)] = oterms ^ tiles[2]
                terms, da, db = _cf._chunk_fletcher(nw, start, n)
                ck_hbm[pl.ds(start, size)] = terms
                return carry[0] + da, carry[1] + db

            a, b = _cf._stream_loop(n, cb, in_refs, bufs, sems, process,
                                    (U32(0), U32(0)))
            dig_smem[0] = a
            dig_smem[1] = b

        scratch_shapes = [pltpu.VMEM((2, cb, bw), U32),
                          pltpu.VMEM((2, cb, bw), U32)]
        if verify:
            scratch_shapes.append(pltpu.VMEM((2, cb, 2), U32))
        scratch_shapes.append(
            pltpu.SemaphoreType.DMA((2, 3 if verify else 2)))
        pl.run_scoped(scoped, *scratch_shapes)
    return kernel


def _s_stream_call(old, new, stored, coeffs, r, chunk_blocks, interpret):
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    cb = _cf._clamp_cb(chunk_blocks, n)
    coeffs = jnp.asarray(coeffs, U32).reshape(r)
    verify = stored is not None
    in_specs = [_cf._SMEM(), _cf._ANY(), _cf._ANY()]
    operands = [coeffs, old, new]
    out_specs = [_cf._ANY(), _cf._ANY()]
    out_shape = [jax.ShapeDtypeStruct((r, n, bw), U32),
                 jax.ShapeDtypeStruct((n, 2), U32)]
    if verify:
        assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
        in_specs.append(_cf._ANY())
        operands.append(stored)
        out_specs.append(_cf._ANY())
        out_shape.append(jax.ShapeDtypeStruct((n, 2), U32))
    out_specs.append(_cf._SMEM())
    out_shape.append(jax.ShapeDtypeStruct((2,), U32))
    return pl.pallas_call(
        _make_stream_s_kernel(n, cb, r, verify),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_commit_s_stream(old: jax.Array, new: jax.Array,
                          coeffs: jax.Array, *,
                          chunk_blocks: int = _cf.STREAM_CHUNK_BLOCKS,
                          interpret: bool = False):
    """Streamed fused_commit_s: (sdeltas, new cksums, row digest)."""
    r = coeffs.shape[0]
    return _s_stream_call(old, new, None, coeffs, r, chunk_blocks, interpret)


@functools.partial(jax.jit, static_argnames=("chunk_blocks", "interpret"))
def fused_verify_commit_s_stream(old: jax.Array, new: jax.Array,
                                 stored: jax.Array, coeffs: jax.Array, *,
                                 chunk_blocks: int = _cf.STREAM_CHUNK_BLOCKS,
                                 interpret: bool = False):
    """Streamed fused_verify_commit_s: (sdeltas, cksums, bad, digest)."""
    r = coeffs.shape[0]
    sdelta, ck, mism, dig = _s_stream_call(old, new, stored, coeffs, r,
                                           chunk_blocks, interpret)
    return sdelta, ck, jnp.any(mism != 0, axis=-1), dig
