"""Pallas TPU kernels: GF(2^32)-weighted parity sweeps (dual-parity Q).

The Q syndrome is Q = XOR_i g^i·row_i with multiplication in GF(2^32)
(core/gf.py), so a commit that already sweeps (old, new) for the XOR delta
can emit the Q delta from the same VMEM tiles: qdelta = g^me · (old ^ new),
a 32-step branch-free clmul per word — pure VPU bit-ops, no extra HBM
traffic.  The kernels here fuse that weighting with the existing
verify+checksum sweep (kernels/commit_fused.py):

  * `gf_scale`                 — standalone element-wise y = coeff · x
    (epoch-flush Q patches for parity-only modes).
  * `fused_commit_pq`          — one sweep over (old, new) emitting
    (delta, qdelta, new Fletcher terms).
  * `fused_verify_commit_pq`   — additionally folds verify-at-open over
    the old tile (terms XOR stored, all-zero == clean).
  * `fused_commit_old_terms_pq`— the stored=0 specialization whose
    mismatch output is the raw old terms (MLP2's incremental digest).

HBM traffic per page is unchanged from the single-parity fused sweep
(r old + r new + w delta) plus the unavoidable w qdelta — the GF weighting
itself is free, which is what makes redundancy=2 cost one extra write
stream rather than a second pass.

The per-rank coefficient g^me is a *traced* scalar (axis_index lookup), fed
to the kernel as a (1, 1) u32 operand so one compiled program serves every
rank of the zone.  `kernels/ref.py` carries the jnp oracles these must
match bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import largest_divisor_tile as _pick_tile

U32 = jnp.uint32
TILE_BLOCKS = 8
TILE_ROWS = 512          # gf_scale tile height (matches xor_parity.py)


def _gf_mul_tile(x, coeff):
    """Branch-free 32-step clmul of a tile by a scalar coefficient."""
    poly = U32(0x400007)                      # gf.POLY, inlined for Mosaic
    acc = jnp.zeros_like(x)
    cur = x
    for i in range(32):
        bit = (coeff >> U32(i)) & U32(1)
        acc = acc ^ (bit * cur)
        cur = (cur << U32(1)) ^ ((cur >> U32(31)) * poly)
    return acc


# ---------------------------------------------------------------------------
# standalone scale
# ---------------------------------------------------------------------------

def _gf_scale_kernel(coeff_ref, x_ref, o_ref):
    o_ref[...] = _gf_mul_tile(x_ref[...], coeff_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf_scale(x: jax.Array, coeff: jax.Array, *, interpret: bool = False
             ) -> jax.Array:
    """Element-wise y = coeff · x in GF(2^32); coeff a (traced) u32 scalar."""
    assert x.dtype == U32, x.dtype
    shape = x.shape
    if x.ndim == 1:
        x = x.reshape(-1, 1024) if x.size % 1024 == 0 else x.reshape(1, -1)
    n, m = x.shape
    t = _pick_tile(n, TILE_ROWS)
    coeff = jnp.asarray(coeff, U32).reshape(1, 1)
    out = pl.pallas_call(
        _gf_scale_kernel,
        grid=(n // t,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((t, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((t, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), U32),
        interpret=interpret,
    )(coeff, x)
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# fused P+Q commit sweeps
# ---------------------------------------------------------------------------

def _pq_kernel(coeff_ref, old_ref, new_ref, delta_ref, qdelta_ref, ck_ref):
    old = old_ref[...]
    new = new_ref[...]
    d = old ^ new
    delta_ref[...] = d
    # the delta tile is already in VMEM: its GF weighting is free
    qdelta_ref[...] = _gf_mul_tile(d, coeff_ref[0, 0])
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


def _pq_verify_kernel(coeff_ref, old_ref, new_ref, stored_ref, delta_ref,
                      qdelta_ref, ck_ref, mism_ref):
    old = old_ref[...]
    new = new_ref[...]
    d = old ^ new
    delta_ref[...] = d
    qdelta_ref[...] = _gf_mul_tile(d, coeff_ref[0, 0])
    bw = new.shape[-1]
    w = U32(bw) - jax.lax.broadcasted_iota(U32, (1, bw), 1)
    a_old = jnp.sum(old, axis=-1, dtype=U32)
    b_old = jnp.sum(old * w, axis=-1, dtype=U32)
    mism_ref[...] = jnp.stack([a_old, b_old], axis=-1) ^ stored_ref[...]
    a = jnp.sum(new, axis=-1, dtype=U32)
    b = jnp.sum(new * w, axis=-1, dtype=U32)
    ck_ref[...] = jnp.stack([a, b], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_pq(old: jax.Array, new: jax.Array, coeff: jax.Array, *,
                    interpret: bool = False):
    """One sweep over (old, new): (delta, coeff·delta, new Fletcher terms)."""
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    tb = _pick_tile(n, TILE_BLOCKS)
    coeff = jnp.asarray(coeff, U32).reshape(1, 1)
    return pl.pallas_call(
        _pq_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(coeff, old, new)


def _pq_verify_call(old, new, stored, coeff, interpret):
    assert old.shape == new.shape and old.dtype == U32 == new.dtype
    n, bw = old.shape
    assert stored.shape == (n, 2) and stored.dtype == U32, stored.shape
    tb = _pick_tile(n, TILE_BLOCKS)
    coeff = jnp.asarray(coeff, U32).reshape(1, 1)
    return pl.pallas_call(
        _pq_verify_kernel,
        grid=(n // tb,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                  pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, bw), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0)),
                   pl.BlockSpec((tb, 2), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, bw), U32),
                   jax.ShapeDtypeStruct((n, 2), U32),
                   jax.ShapeDtypeStruct((n, 2), U32)],
        interpret=interpret,
    )(coeff, old, new, stored)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_verify_commit_pq(old: jax.Array, new: jax.Array, stored: jax.Array,
                           coeff: jax.Array, *, interpret: bool = False):
    """Verify + delta + qdelta + new checksums from one sweep.

    Returns (delta, qdelta, new_cksums, bad) with bad True where the old
    block's recomputed Fletcher terms no longer match `stored`.
    """
    delta, qdelta, ck, mism = _pq_verify_call(old, new, stored, coeff,
                                              interpret)
    return delta, qdelta, ck, jnp.any(mism != 0, axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_commit_old_terms_pq(old: jax.Array, new: jax.Array,
                              coeff: jax.Array, *, interpret: bool = False):
    """(delta, qdelta, new cksums, old cksums) — the MLP2 patch sweep."""
    zeros = jnp.zeros((old.shape[0], 2), U32)
    return _pq_verify_call(old, new, zeros, coeff, interpret)
