"""Jit'd dispatch wrappers for the protection kernels.

On TPU the Pallas kernels run natively; on CPU (this container, and the
512-device dry-run) the pure-jnp oracles run instead — identical bit-level
semantics, so tests and the dry-run exercise the same math the TPU kernels
implement.  `interpret=True` forces the Pallas path in interpret mode (used
by the kernel-vs-oracle tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import commit_fused as _fused
from repro.kernels import fletcher as _fletcher
from repro.kernels import gf_parity as _gf
from repro.kernels import ref as _ref
from repro.kernels import xor_parity as _xor


def _pallas_path(interpret: Optional[bool]) -> Optional[bool]:
    """Returns interpret flag for the Pallas call, or None for the jnp ref."""
    if interpret is not None:
        return interpret            # forced by caller (tests)
    if jax.default_backend() == "tpu":
        return False                # native Mosaic lowering
    return None                     # CPU: jnp oracle


def fletcher_blocks(blocks: jax.Array, *, interpret: Optional[bool] = None
                    ) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fletcher_blocks_ref(blocks)
    return _fletcher.fletcher_blocks(blocks, interpret=p)


def xor_delta(old: jax.Array, new: jax.Array, *,
              interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.xor_delta_ref(old, new)
    return _xor.xor_delta(old, new, interpret=p)


def xor_accum(parity: jax.Array, patch: jax.Array, *,
              interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.xor_accum_ref(parity, patch)
    return _xor.xor_accum(parity, patch, interpret=p)


def fused_commit(old: jax.Array, new: jax.Array, *,
                 interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_ref(old, new)
    return _fused.fused_commit(old, new, interpret=p)


def fused_verify_commit(old: jax.Array, new: jax.Array, stored: jax.Array,
                        *, interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_ref(old, new, stored)
    return _fused.fused_verify_commit(old, new, stored, interpret=p)


def fused_commit_old_terms(old: jax.Array, new: jax.Array, *,
                           interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_old_terms_ref(old, new)
    return _fused.fused_commit_old_terms(old, new, interpret=p)


def fused_accum_commit(acc: jax.Array, old: jax.Array, new: jax.Array, *,
                       interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_accum_commit_ref(acc, old, new)
    return _fused.fused_accum_commit(acc, old, new, interpret=p)


def gf_scale(x: jax.Array, coeff, *,
             interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.gf_scale_ref(x, coeff)
    return _gf.gf_scale(x, coeff, interpret=p)


def syndrome_scale(delta: jax.Array, coeffs, *,
                   interpret: Optional[bool] = None) -> jax.Array:
    """(r, *delta.shape) weighted-delta stack; coeffs None means r=1.

    Plane 0 is the raw delta (g^0 = 1, statically skipped); plane k a
    GF(2^32) scale — the standalone form of the weighting the fused
    syndrome sweeps do in VMEM, for callers that already hold a delta
    (the epoch flush's parity-only patch path).
    """
    if coeffs is None:
        return delta[None]
    p = _pallas_path(interpret)
    if p is None:
        return _ref.sdelta_stack_ref(delta, coeffs)
    return _gf.sdelta_stack(delta, coeffs, interpret=p)


# The fused syndrome sweeps take the rank's coefficient vector
# (g^(k·me))_{k<r} — or None for r=1, which routes to the single-parity
# commit_fused kernels so the r=1 program stays byte-identical to the
# pre-stack engine (the delta plane is reshaped, never recomputed).

def fused_commit_s(old: jax.Array, new: jax.Array, coeffs=None, *,
                   interpret: Optional[bool] = None):
    if coeffs is None:
        delta, ck = fused_commit(old, new, interpret=interpret)
        return delta[None], ck
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_s_ref(old, new, coeffs)
    return _gf.fused_commit_s(old, new, coeffs, interpret=p)


def fused_verify_commit_s(old: jax.Array, new: jax.Array, stored: jax.Array,
                          coeffs=None, *, interpret: Optional[bool] = None):
    if coeffs is None:
        delta, ck, bad = fused_verify_commit(old, new, stored,
                                             interpret=interpret)
        return delta[None], ck, bad
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_s_ref(old, new, stored, coeffs)
    return _gf.fused_verify_commit_s(old, new, stored, coeffs, interpret=p)


def fused_commit_old_terms_s(old: jax.Array, new: jax.Array, coeffs=None, *,
                             interpret: Optional[bool] = None):
    if coeffs is None:
        delta, new_ck, old_ck = fused_commit_old_terms(old, new,
                                                       interpret=interpret)
        return delta[None], new_ck, old_ck
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_old_terms_s_ref(old, new, coeffs)
    return _gf.fused_commit_old_terms_s(old, new, coeffs, interpret=p)


def stage_verdict(checks) -> jax.Array:
    """Fold per-buffer canary verdicts into ONE device scalar.

    The async commit pipeline's device-side canary staging: each guarded
    staging buffer yields a device bool (`microbuffer.check` /
    `check_nd`), and instead of `device_get`-ing every one on the host —
    a sync per buffer, serializing the pipeline — the checks fold to a
    single unfetched bool that rides straight into the staged commit
    program (`DeferredProtector.commit_staged`, `Pool.commit_async`).
    The fold is a scalar reduction over a handful of bools; there is no
    Pallas variant because there is nothing to tile — jnp is the kernel.
    An empty check list is vacuously clean (all-True).
    """
    if not checks:
        return jnp.ones((), jnp.bool_)
    flat = [jnp.asarray(c, jnp.bool_).reshape(-1) for c in checks]
    if len(flat) == 1 and flat[0].shape == (1,):
        return flat[0].reshape(())
    return jnp.all(jnp.concatenate(flat))


# ---------------------------------------------------------------------------
# tenant-batched dispatch (repro.tenancy cohorts)
# ---------------------------------------------------------------------------
# A cohort of T same-shape tenants commits through ONE kernel dispatch by
# folding the leading tenant axis into the block grid: every kernel here
# is per-block independent (each (block_words,) page produces its own
# delta / Fletcher pair / verify bit), so a (T, n_blocks, bw) stack
# reshaped to (T*n_blocks, bw) is bit-identical to T separate calls —
# the batched entries are pure reshape wrappers, no new kernel code.
# Outputs come back per-tenant: checksums (T, nb, 2), verify bits
# (T, nb), and the syndrome-delta stack as (T, r, n_local) rows ready
# for the tenant-folded `coll.syndrome_apply_delta` collective.

def _tb_split(x: jax.Array) -> tuple:
    assert x.ndim == 3, f"expected (T, n_blocks, block_words), got {x.shape}"
    t, nb, bw = x.shape
    return (t, nb), x.reshape(t * nb, bw)


def fletcher_blocks_tb(blocks: jax.Array, *,
                       interpret: Optional[bool] = None) -> jax.Array:
    (t, nb), flat = _tb_split(blocks)
    return fletcher_blocks(flat, interpret=interpret).reshape(t, nb, 2)


def fused_commit_s_tb(old: jax.Array, new: jax.Array, coeffs=None, *,
                      interpret: Optional[bool] = None):
    (t, nb), old_f = _tb_split(old)
    _, new_f = _tb_split(new)
    sdelta, ck = fused_commit_s(old_f, new_f, coeffs, interpret=interpret)
    r = sdelta.shape[0]
    return (sdelta.reshape(r, t, -1).swapaxes(0, 1),
            ck.reshape(t, nb, 2))


def fused_verify_commit_s_tb(old: jax.Array, new: jax.Array,
                             stored: jax.Array, coeffs=None, *,
                             interpret: Optional[bool] = None):
    (t, nb), old_f = _tb_split(old)
    _, new_f = _tb_split(new)
    sdelta, ck, bad = fused_verify_commit_s(
        old_f, new_f, stored.reshape(t * nb, -1), coeffs,
        interpret=interpret)
    r = sdelta.shape[0]
    return (sdelta.reshape(r, t, -1).swapaxes(0, 1),
            ck.reshape(t, nb, 2), bad.reshape(t, nb))


def fused_accum_commit_tb(acc: jax.Array, old: jax.Array, new: jax.Array,
                          *, interpret: Optional[bool] = None):
    (t, nb), acc_f = _tb_split(acc)
    _, old_f = _tb_split(old)
    _, new_f = _tb_split(new)
    acc_out, delta, ck = fused_accum_commit(acc_f, old_f, new_f,
                                            interpret=interpret)
    return (acc_out.reshape(t, nb, -1), delta.reshape(t, nb, -1),
            ck.reshape(t, nb, 2))


def syndrome_scale_tb(delta: jax.Array, coeffs, *,
                      interpret: Optional[bool] = None) -> jax.Array:
    """(T, n) per-tenant deltas -> (T, r, n) weighted stacks."""
    t, n = delta.shape
    stack = syndrome_scale(delta.reshape(-1), coeffs, interpret=interpret)
    return stack.reshape(stack.shape[0], t, n).swapaxes(0, 1)


# ---------------------------------------------------------------------------
# blockwise double-buffered streaming dispatch
# ---------------------------------------------------------------------------
# The streamed variants return the flat outputs PLUS the combined (A, B)
# row digest that rode the kernel's loop carry — the CPU oracle recovers
# it with `digest_ref` over the term table, so both paths agree bit-for-bit
# with `checksum.combine(ck, block_words)`.

def stream_chunk_blocks(n_blocks: int, block_words: int, *,
                        threshold_words: int,
                        chunk_words: int):
    """The engines' flat-vs-streamed policy, in one place.

    Returns the streamed chunk height (pages per double-buffered VMEM
    chunk), or None when the row is small enough that the flat
    whole-grid kernels win (their automatic pipelining has no
    per-chunk DMA bookkeeping).  threshold_words <= 0 disables
    streaming outright.
    """
    if threshold_words <= 0 or n_blocks * block_words < threshold_words:
        return None
    return max(1, min(int(chunk_words) // int(block_words), n_blocks))


def fletcher_stream(blocks: jax.Array, *, chunk_blocks: int = 8,
                    interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fletcher_stream_ref(blocks)
    return _fletcher.fletcher_stream(blocks, chunk_blocks=chunk_blocks,
                                     interpret=p)


def fused_commit_stream(old: jax.Array, new: jax.Array, *,
                        chunk_blocks: int = 8,
                        interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_stream_ref(old, new)
    return _fused.fused_commit_stream(old, new, chunk_blocks=chunk_blocks,
                                      interpret=p)


def fused_verify_commit_stream(old: jax.Array, new: jax.Array,
                               stored: jax.Array, *, chunk_blocks: int = 8,
                               interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_stream_ref(old, new, stored)
    return _fused.fused_verify_commit_stream(old, new, stored,
                                             chunk_blocks=chunk_blocks,
                                             interpret=p)


def fused_commit_old_terms_stream(old: jax.Array, new: jax.Array, *,
                                  chunk_blocks: int = 8,
                                  interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_old_terms_stream_ref(old, new)
    return _fused.fused_commit_old_terms_stream(old, new,
                                                chunk_blocks=chunk_blocks,
                                                interpret=p)


def fused_accum_commit_stream(acc: jax.Array, old: jax.Array,
                              new: jax.Array, *, chunk_blocks: int = 8,
                              interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_accum_commit_stream_ref(acc, old, new)
    return _fused.fused_accum_commit_stream(acc, old, new,
                                            chunk_blocks=chunk_blocks,
                                            interpret=p)


def fused_commit_s_stream(old: jax.Array, new: jax.Array, coeffs=None, *,
                          chunk_blocks: int = 8,
                          interpret: Optional[bool] = None):
    if coeffs is None:
        delta, ck, dig = fused_commit_stream(old, new,
                                             chunk_blocks=chunk_blocks,
                                             interpret=interpret)
        return delta[None], ck, dig
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_s_stream_ref(old, new, coeffs)
    return _gf.fused_commit_s_stream(old, new, coeffs,
                                     chunk_blocks=chunk_blocks, interpret=p)


def fused_verify_commit_s_stream(old: jax.Array, new: jax.Array,
                                 stored: jax.Array, coeffs=None, *,
                                 chunk_blocks: int = 8,
                                 interpret: Optional[bool] = None):
    if coeffs is None:
        delta, ck, bad, dig = fused_verify_commit_stream(
            old, new, stored, chunk_blocks=chunk_blocks, interpret=interpret)
        return delta[None], ck, bad, dig
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_s_stream_ref(old, new, stored,
                                                     coeffs)
    return _gf.fused_verify_commit_s_stream(old, new, stored, coeffs,
                                            chunk_blocks=chunk_blocks,
                                            interpret=p)
