"""Jit'd dispatch wrappers for the protection kernels.

On TPU the Pallas kernels run natively; on CPU (this container, and the
512-device dry-run) the pure-jnp oracles run instead — identical bit-level
semantics, so tests and the dry-run exercise the same math the TPU kernels
implement.  `interpret=True` forces the Pallas path in interpret mode (used
by the kernel-vs-oracle tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import commit_fused as _fused
from repro.kernels import fletcher as _fletcher
from repro.kernels import gf_parity as _gf
from repro.kernels import ref as _ref
from repro.kernels import xor_parity as _xor


def _pallas_path(interpret: Optional[bool]) -> Optional[bool]:
    """Returns interpret flag for the Pallas call, or None for the jnp ref."""
    if interpret is not None:
        return interpret            # forced by caller (tests)
    if jax.default_backend() == "tpu":
        return False                # native Mosaic lowering
    return None                     # CPU: jnp oracle


def fletcher_blocks(blocks: jax.Array, *, interpret: Optional[bool] = None
                    ) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fletcher_blocks_ref(blocks)
    return _fletcher.fletcher_blocks(blocks, interpret=p)


def xor_delta(old: jax.Array, new: jax.Array, *,
              interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.xor_delta_ref(old, new)
    return _xor.xor_delta(old, new, interpret=p)


def xor_accum(parity: jax.Array, patch: jax.Array, *,
              interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.xor_accum_ref(parity, patch)
    return _xor.xor_accum(parity, patch, interpret=p)


def fused_commit(old: jax.Array, new: jax.Array, *,
                 interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_ref(old, new)
    return _fused.fused_commit(old, new, interpret=p)


def fused_verify_commit(old: jax.Array, new: jax.Array, stored: jax.Array,
                        *, interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_ref(old, new, stored)
    return _fused.fused_verify_commit(old, new, stored, interpret=p)


def fused_commit_old_terms(old: jax.Array, new: jax.Array, *,
                           interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_old_terms_ref(old, new)
    return _fused.fused_commit_old_terms(old, new, interpret=p)


def fused_accum_commit(acc: jax.Array, old: jax.Array, new: jax.Array, *,
                       interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_accum_commit_ref(acc, old, new)
    return _fused.fused_accum_commit(acc, old, new, interpret=p)


def gf_scale(x: jax.Array, coeff, *,
             interpret: Optional[bool] = None) -> jax.Array:
    p = _pallas_path(interpret)
    if p is None:
        return _ref.gf_scale_ref(x, coeff)
    return _gf.gf_scale(x, coeff, interpret=p)


def fused_commit_pq(old: jax.Array, new: jax.Array, coeff, *,
                    interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_pq_ref(old, new, coeff)
    return _gf.fused_commit_pq(old, new, coeff, interpret=p)


def fused_verify_commit_pq(old: jax.Array, new: jax.Array, stored: jax.Array,
                           coeff, *, interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_verify_commit_pq_ref(old, new, stored, coeff)
    return _gf.fused_verify_commit_pq(old, new, stored, coeff, interpret=p)


def fused_commit_old_terms_pq(old: jax.Array, new: jax.Array, coeff, *,
                              interpret: Optional[bool] = None):
    p = _pallas_path(interpret)
    if p is None:
        return _ref.fused_commit_old_terms_pq_ref(old, new, coeff)
    return _gf.fused_commit_old_terms_pq(old, new, coeff, interpret=p)
