"""Pallas TPU kernels: XOR parity delta and accumulate.

The TPU analogue of Pangolin's ISA-L XOR loops: pure element-wise u32
bit-ops, VPU-bound, tiled through VMEM.  `xor_delta` computes the parity
patch Delta = old ^ new; `xor_accum` applies a patch to a parity buffer
(the "atomic XOR" application — order-free by commutativity, so the
collective that delivers patches needs no ordering either).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import largest_divisor_tile

U32 = jnp.uint32
# (rows, lanes) tile: 512 x 1024 x 4 B = 2 MB per operand; 3 operands = 6 MB
# of VMEM traffic per step, comfortably under the ~16 MB v5e VMEM budget.
TILE_ROWS = 512


def _xor2_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] ^ b_ref[...]


def _pick_tile(n: int) -> int:
    return largest_divisor_tile(n, TILE_ROWS)


def _xor2(a: jax.Array, b: jax.Array, interpret: bool) -> jax.Array:
    assert a.shape == b.shape and a.dtype == U32 == b.dtype
    shape = a.shape
    if a.ndim == 1:
        a = a.reshape(-1, 1024) if a.size % 1024 == 0 else a.reshape(1, -1)
        b = b.reshape(a.shape)
    n, m = a.shape
    t = _pick_tile(n)
    out = pl.pallas_call(
        _xor2_kernel,
        grid=(n // t,),
        in_specs=[pl.BlockSpec((t, m), lambda i: (i, 0)),
                  pl.BlockSpec((t, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((t, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), U32),
        interpret=interpret,
    )(a, b)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def xor_delta(old: jax.Array, new: jax.Array, *, interpret: bool = False
              ) -> jax.Array:
    return _xor2(old, new, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def xor_accum(parity: jax.Array, patch: jax.Array, *, interpret: bool = False
              ) -> jax.Array:
    return _xor2(parity, patch, interpret)
