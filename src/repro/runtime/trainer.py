"""Fault-tolerant training loop: Pangolin transactions around train steps.

Per step:  batch <- deterministic pipeline(cursor)
           micro-buffer   = train_step(state, batch)      (pure staging)
           commit         = canary check -> redo record -> protection ->
                            functional swap
           scrub every N commits; online recovery on failure events;
           async disk checkpoints as the backstop tier.

All protection plumbing lives in the `Pool` facade (repro/pool.py): the
trainer builds one cold pool from its `ProtectConfig` and routes every
commit / scrub / recovery through it.  The config's `window` selects the
engine (1 = synchronous single-sweep, W>1 = deferred epochs whose redo
log still persists per step and covers the window for crash replay);
`scrub_period` drives `pool.maybe_scrub()`; faults funnel through
`pool.recover(Fault...)`, which flushes any open window first.

`overlap_commit` keeps protection off the critical path: step t+1's
compute is dispatched before step t's commit (and, at an epoch boundary,
its flush) is awaited — the programs are independent, so the async
runtime overlaps the parity reduce-scatter with forward compute.  `run`
resolves commits one step behind; an explicit `step()` stays fully
synchronous.

Crash recovery (paper §3.6): restore the newest checkpoint, then replay
the redo log's marked records — the deterministic pipeline regenerates
each logged batch from its cursor, and the row digest verifies each
replayed step landed bit-identically (the deferred engine keeps the
digest current per step precisely so every log record stays
replay-verifiable mid-window).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig, ProtectConfig, TrainConfig
from repro.core import redolog
from repro.data.synthetic import batch_for
from repro.models import api
from repro.models.transformer import build_model
from repro.optim import build_optimizer
from repro.pool import Fault, Pool, PoolHost


class Trainer(PoolHost):
    def __init__(self, cfg: ModelConfig, train_cfg: TrainConfig,
                 protect_cfg: ProtectConfig, mesh, *,
                 seq_len: int = 128, global_batch: int = 8,
                 checkpoint_dir: Optional[str] = None, seed: int = 0,
                 metrics_dir: Optional[str] = None,
                 trace_dir: Optional[str] = None,
                 metrics_every: int = 25):
        self.cfg = cfg
        self.train_cfg = train_cfg
        self.protect_cfg = protect_cfg
        self.mesh = mesh
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.overlap_commit = bool(protect_cfg.overlap_commit)
        self.window = int(protect_cfg.window)
        # overlap_commit is the legacy one-behind pipeline; fold it into
        # the commit ring as an effective depth of 2 (dispatch t+1
        # before awaiting t) so `run` has exactly one pipelining
        # mechanism — the N-deep ring
        depth = int(protect_cfg.pipeline_depth)
        if self.overlap_commit and depth < 2:
            depth = 2
            protect_cfg = dataclasses.replace(protect_cfg,
                                              pipeline_depth=depth)
        self.pipeline_depth = depth
        self.protect_cfg = protect_cfg

        self.model = build_model(cfg, mesh)
        self.optimizer = build_optimizer(train_cfg, cfg)
        self.stream = batch_for(cfg, seq_len, global_batch, seed)

        abstract_state = api.abstract_train_state(self.model, self.optimizer)
        state_specs = api.train_state_specs(self.model, self.optimizer, mesh)
        # telemetry surfaces (repro.obs): --trace-dir gives the pool a
        # file-backed tracer; --metrics-dir makes the step loop publish
        # the registry + stats snapshot every `metrics_every` resolved
        # steps (publication is host-side; see pool.stats())
        self.metrics_dir = metrics_dir
        self.metrics_every = max(1, int(metrics_every))
        tracer = None
        if trace_dir:
            os.makedirs(trace_dir, exist_ok=True)
            tracer = obs.Tracer(
                os.path.join(trace_dir, "trainer.trace.jsonl"))
        # one cold pool: engine selection, scrub pressure loop and
        # window-meta replication all wired from the ProtectConfig
        self.pool = Pool(mesh, abstract_state, state_specs, protect_cfg,
                         on_freeze=self.freeze, on_resume=self.resume,
                         tracer=tracer)

        self._train_step = jax.jit(api.make_train_step(
            self.model, self.optimizer, train_cfg))
        self._batch_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), api.batch_specs(cfg, mesh),
            is_leaf=lambda x: isinstance(x, P))

        self.checkpoint_dir = checkpoint_dir
        self._ckpt_mgr = None
        if checkpoint_dir:
            from repro.checkpoint.manager import CheckpointManager
            self._ckpt_mgr = CheckpointManager(checkpoint_dir, mesh,
                                               state_specs)
        self.cursor = 0
        self.history: list = []
        self._frozen = False
        self._host_step = 0
        # chaos/observability: hooks fired after every resolved step with
        # the step's summary dict (schedule attachment, tracing)
        self._step_hooks: list = []
        # per-replica step-time dilation fed to the straggler policy when
        # ProtectConfig.straggler_threshold wires one into the pool; the
        # chaos runner (and tests) dilate entries to simulate a slow
        # replica without actually sleeping per rank
        self.replica_slowdown = np.ones(self.pool.protector.group_size)
        # verify-at-open (paper's default policy): checksums of the old
        # state verified inside every synchronous commit, abort on
        # mismatch — a window=1 engine feature
        self.verify_old = False

    # pool delegation (protector / scrubber / prot / flush) comes from
    # repro.pool.PoolHost

    # -- lifecycle ---------------------------------------------------------------

    def initialize(self, key=None) -> None:
        key = key if key is not None else jax.random.PRNGKey(self.seed)
        state = api.init_train_state(self.model, self.optimizer, key)
        state = jax.device_put(
            state, jax.tree.map(
                lambda s: NamedSharding(self.mesh, s),
                api.train_state_specs(self.model, self.optimizer, self.mesh),
                is_leaf=lambda x: isinstance(x, P)))
        self.pool.init(state)
        self._host_step = 0

    def freeze(self):
        """Paper's pool freeze: drain outstanding work before recovery."""
        self._frozen = True
        if self.prot is not None:
            jax.block_until_ready(jax.tree.leaves(self.prot.state)[0])

    def resume(self):
        self._frozen = False

    # -- stepping ----------------------------------------------------------------

    def _dispatch_step(self, *, canary_ok: bool = True) -> dict:
        """Dispatch compute + commit without any host synchronization.

        Returns the pending record `_resolve_step` finishes later; only
        values that survive buffer donation are captured (ok / metrics
        are fresh program outputs, never donated operands).
        """
        assert self.prot is not None and not self._frozen
        t0 = time.perf_counter()
        batch = self.stream.device_batch(self.cursor, self._batch_shardings)
        if self.pool.dropped_replicas:
            # straggler mitigation: zero the dropped replicas' examples
            # out of the loss (replica-major layout, data-axis sharded)
            mask = self.pool.straggler.loss_mask(self.global_batch)
            batch["loss_mask"] = jax.device_put(
                jnp.asarray(mask), NamedSharding(self.mesh, P("data")))
        rng = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.cursor)
        cursor_before = self.cursor
        new_state, metrics = self._train_step(self.prot.state, batch)
        ticket = self.pool.commit_async(new_state, data_cursor=self.cursor,
                                        rng_key=rng, canary_ok=canary_ok,
                                        verify_old=self.verify_old)
        self.cursor += 1          # optimistic; rolled back on late abort
        return {"ticket": ticket, "loss": metrics["loss"],
                "cursor_before": cursor_before, "t0": t0}

    def _resolve_step(self, pending: dict) -> dict:
        """Await a dispatched step's commit; bookkeeping + scrub cadence."""
        committed = bool(pending["ticket"].result())
        if committed:
            self._host_step += 1
        else:
            self.cursor = pending["cursor_before"]
        out = {"step": self._host_step,
               "loss": float(jax.device_get(pending["loss"])),
               "committed": committed}
        if self.pool.straggler is not None:
            # one wall-clock measurement per step, dilated per replica —
            # a real fleet reports each replica's own duration; here the
            # dilation vector stands in for the slow ranks
            dt = time.perf_counter() - pending["t0"]
            dropped = self.pool.observe_commit_times(
                dt * self.replica_slowdown)
            if not dropped.all():
                out["dropped_replicas"] = sorted(self.pool.dropped_replicas)
        self.history.append(out)
        report = self.pool.maybe_scrub()
        if report is not None:
            out["scrub"] = dataclasses.asdict(report)
        # step-loop publication: the loss/verdict were already fetched
        # above, so folding them into the registry costs no extra sync
        reg = self.pool.metrics
        reg.counter("trainer_steps_total").inc()
        if not committed:
            reg.counter("trainer_aborted_steps_total").inc()
        reg.gauge("trainer_loss").set(out["loss"])
        reg.histogram("trainer_step_wall_ms").observe(
            (time.perf_counter() - pending["t0"]) * 1e3)
        if (self.metrics_dir
                and self._host_step % self.metrics_every == 0):
            obs.write_metrics(reg, self.metrics_dir, prefix="trainer",
                              stats=self.pool.stats())
        for hook in list(self._step_hooks):
            hook(self, out)
        return out

    def add_step_hook(self, fn) -> None:
        """Register `fn(trainer, out_dict)`, fired after every resolved
        step — the chaos campaign's schedule attachment point."""
        self._step_hooks.append(fn)

    def step(self, *, canary_ok: bool = True) -> dict:
        return self._resolve_step(self._dispatch_step(canary_ok=canary_ok))

    def run(self, n_steps: int, checkpoint_every: int = 0) -> list:
        """The training loop on the commit ring: up to
        `pipeline_depth` steps stay dispatched-but-unresolved (compute
        t+k launches before commit t's verdict is fetched), so the
        async runtime overlaps parity reduce-scatters and flushes with
        forward compute across the whole ring, not just one step
        behind.  Depth 1 resolves every step inline (the synchronous
        loop); the trailing in-flight steps drain at the end, so a
        `run` boundary is always fully resolved.
        """
        def maybe_checkpoint():
            if (outs and checkpoint_every and self._ckpt_mgr
                    and outs[-1]["step"] % checkpoint_every == 0
                    and outs[-1]["committed"]):
                self.save_checkpoint()

        outs = []
        pending: list = []
        for _ in range(n_steps):
            if self.pipeline_depth > 1:
                pending.append(self._dispatch_step())
                if len(pending) >= self.pipeline_depth:
                    outs.append(self._resolve_step(pending.pop(0)))
            else:
                outs.append(self.step())
            maybe_checkpoint()
        while pending:
            # the trailing pipelined steps get the same checkpoint
            # cadence the synchronous path would give them
            outs.append(self._resolve_step(pending.pop(0)))
            maybe_checkpoint()
        return outs

    # -- fault handling -----------------------------------------------------------

    def on_failure(self, event) -> dict:
        """Online recovery entry point (the SIGBUS-handler analogue).

        A thin adapter now: `Pool.recover` owns the whole sequence —
        capture the survivors' replicated window metadata, flush any
        open window (the cached row is a separate buffer the failure's
        state corruption never touched, so the refreshed redundancy
        describes intended values), dispatch the right reconstruction,
        collapse the adaptive window, and bound the lost window from the
        replicated mask + digest.  (A full machine loss that also takes
        the cache and accumulator down falls back to checkpoint +
        redo-log replay — see EXPERIMENTS.md §Perf, window-loss
        semantics.)
        """
        assert self.prot is not None
        rep = self.pool.recover(Fault.from_event(event))
        if rep is None:
            # a recovery was already in flight; this fault was queued and
            # will drain right after it (async-safe re-entry)
            return {"queued": True}
        return dataclasses.asdict(rep)

    # -- checkpoint / crash recovery ------------------------------------------------

    def save_checkpoint(self, wait: bool = False) -> None:
        assert self._ckpt_mgr is not None and self.prot is not None
        self._ckpt_mgr.save(int(jax.device_get(self.prot.step)),
                            self.prot.state,
                            extra={"cursor": self.cursor,
                                   "log": jax.device_get(self.prot.log)
                                   if self.prot.log is not None else None})
        if wait:
            self._ckpt_mgr.wait()

    def restore_from_checkpoint(self, replay: bool = True) -> dict:
        """Crash recovery: newest checkpoint + redo-log replay (§3.6).

        Replay works identically for both cadences: deferred commits keep
        the row digest current per step, so every marked record's digest
        is checkable even when the crash hit mid-window.
        """
        assert self._ckpt_mgr is not None
        self._ckpt_mgr.wait()
        step, state, extra = self._ckpt_mgr.restore_latest()
        prot = self.protector.init(state)
        self.prot = dataclasses.replace(
            prot, step=jnp.asarray(step, jnp.uint32))
        self._host_step = int(step)
        self.cursor = int(extra.get("cursor", step))
        replayed = []
        if replay and extra.get("log") is not None:
            log = extra["log"]
            if isinstance(log, dict):
                # manifest round-trip: pytrees serialize as
                # {"__pytree__": name, "children": [...]} with ndarray
                # children as {"__ndarray__": ..., "dtype": ..., "shape": ...}
                def _arr(c):
                    if isinstance(c, dict) and "__ndarray__" in c:
                        return jnp.asarray(np.asarray(
                            c["__ndarray__"], dtype=c["dtype"]
                        ).reshape(c["shape"]))
                    return jnp.asarray(c)
                log = redolog.RedoLog(*[_arr(c) for c in log["children"]])
            else:
                log = redolog.RedoLog(*[jnp.asarray(x) for x in
                                        (log.step, log.data_cursor, log.rng,
                                         log.digest, log.mark)])
            for s in redolog.replayable_steps(log, step):
                rec = redolog.lookup(log, s)
                self.cursor = int(jax.device_get(rec["data_cursor"]))
                out = self.step()
                replayed.append(out["step"])
                # verify the replayed step reproduced the logged digest
                if self.prot.digest is not None:
                    dig = np.asarray(jax.device_get(
                        self.prot.digest)).reshape(-1, 2)[0]
                    want = np.asarray(jax.device_get(rec["digest"]))
                    if not np.array_equal(dig, want):
                        raise RuntimeError(
                            f"replay digest mismatch at step {s}")
        return {"restored_step": step, "replayed": replayed}
