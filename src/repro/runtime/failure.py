"""Failure injection (Pangolin §4.6).

The paper emulates NVMM media errors with mprotect+SIGSEGV and injects
targeted scribbles.  Here:

  * `inject_rank_loss`   — garbles one data-rank's entire state shard
    (chip/host failure, HBM UE).  The "SIGBUS" analogue is the returned
    FailureEvent the runtime feeds to recovery.
  * `inject_scribble`    — XORs a corruption mask into chosen words of one
    rank's flat row (SDC / wild-store analogue), invisible until a checksum
    verification catches it.
  * `inject_canary_smash`— simulates a kernel overrun into a staged
    micro-buffer's guard page (caught at commit, before state is touched).

All injections are jitted shard_map ops against the protected state so they
work at any mesh size.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import layout as layout_mod
from repro.core import microbuffer
from repro.core.txn import ProtectedState, Protector


@dataclasses.dataclass
class FailureEvent:
    kind: str                  # "rank_loss" | "multi_loss" | "scribble"
                               # | "canary"
    lost_rank: Optional[int] = None
    locations: Optional[list] = None   # [(rank, page)] for scribbles
    lost_ranks: Optional[list] = None  # every lost rank for multi_loss


def inject_rank_loss(protector: Protector, prot: ProtectedState,
                     rank: int) -> tuple:
    """Overwrite one data-rank's shards with garbage; returns (prot, event)."""
    lo, ax = protector.layout, protector.data_axis

    def _garble(state):
        row = layout_mod.flatten_row(lo, state)
        me = lax.axis_index(ax)
        garbage = row ^ jnp.uint32(0xA5A5A5A5)
        out = jnp.where(me == rank, garbage, row)
        return layout_mod.unflatten_row(lo, out)

    fn = jax.jit(shard_map(_garble, mesh=protector.mesh,
                           in_specs=(protector.state_specs,),
                           out_specs=protector.state_specs,
                           check_vma=False))
    bad_state = fn(prot.state)
    return (dataclasses.replace(prot, state=bad_state),
            FailureEvent("rank_loss", lost_rank=rank))


def inject_multi_rank_loss(protector: Protector, prot: ProtectedState,
                           ranks) -> tuple:
    """Garble e data-ranks' shards at once (overlapping failures).

    The pod-scale scenario an (e-1)-syndrome zone cannot survive: all e
    rows gone before any could be rebuilt.  Returns (prot, event) with a
    "multi_loss" event carrying every lost rank.
    """
    dead = sorted({int(r) for r in ranks})
    assert len(dead) == len(list(ranks)) and len(dead) >= 2, (
        f"multi loss needs >= 2 distinct ranks, got {list(ranks)}")
    lo, ax = protector.layout, protector.data_axis

    def _garble(state):
        row = layout_mod.flatten_row(lo, state)
        me = lax.axis_index(ax)
        garbage = row ^ jnp.uint32(0xA5A5A5A5)
        lost = functools.reduce(jnp.logical_or, [me == a for a in dead])
        out = jnp.where(lost, garbage, row)
        return layout_mod.unflatten_row(lo, out)

    fn = jax.jit(shard_map(_garble, mesh=protector.mesh,
                           in_specs=(protector.state_specs,),
                           out_specs=protector.state_specs,
                           check_vma=False))
    bad_state = fn(prot.state)
    return (dataclasses.replace(prot, state=bad_state),
            FailureEvent("multi_loss", lost_ranks=dead))


def inject_double_rank_loss(protector: Protector, prot: ProtectedState,
                            ranks) -> tuple:
    """Back-compat alias: the e=2 multi-rank loss."""
    a, b = (int(r) for r in ranks)
    return inject_multi_rank_loss(protector, prot, (a, b))


def inject_scribble(protector: Protector, prot: ProtectedState,
                    rank: int, word_offsets: Sequence[int],
                    xor_mask: int = 0x00010000) -> tuple:
    """Flip bits at given word offsets of one rank's row (silent until scrub)."""
    lo, ax = protector.layout, protector.data_axis
    offsets = jnp.asarray(list(word_offsets), jnp.int32)

    def _scribble(state):
        row = layout_mod.flatten_row(lo, state)
        me = lax.axis_index(ax)
        vals = row[offsets] ^ jnp.uint32(xor_mask)
        scribbled = row.at[offsets].set(vals)
        out = jnp.where(me == rank, scribbled, row)
        return layout_mod.unflatten_row(lo, out)

    fn = jax.jit(shard_map(_scribble, mesh=protector.mesh,
                           in_specs=(protector.state_specs,),
                           out_specs=protector.state_specs,
                           check_vma=False))
    bad_state = fn(prot.state)
    pages = sorted({int(o) // lo.block_words for o in word_offsets})
    return (dataclasses.replace(prot, state=bad_state),
            FailureEvent("scribble", locations=[(rank, p) for p in pages]))


# ---------------------------------------------------------------------------
# Seeded deterministic injectors (chaos campaign).
#
# The raw injectors above take their victims from the caller; the chaos
# runner needs the *same* victims on every run of a scenario so the
# recovered end state can be diffed bit-for-bit against a fault-free
# golden run.  Each seeded form derives its choices from
# np.random.default_rng seeded with (seed, crc32(kind)) — crc32, not
# hash(), because hash() is salted per process and would break replay.
# ---------------------------------------------------------------------------


def _rng(seed: int, kind: str) -> np.random.Generator:
    return np.random.default_rng((int(seed), zlib.crc32(kind.encode())))


def seeded_rank_loss(protector: Protector, prot: ProtectedState,
                     seed: int, rank: Optional[int] = None) -> tuple:
    """Deterministic rank loss: victim drawn from (seed, "rank_loss")."""
    if rank is None:
        rank = int(_rng(seed, "rank_loss").integers(protector.group_size))
    return inject_rank_loss(protector, prot, rank)


def seeded_multi_rank_loss(protector: Protector, prot: ProtectedState,
                           seed: int, e: int = 2,
                           ranks: Optional[Sequence[int]] = None) -> tuple:
    """Deterministic e-rank loss: victims drawn without replacement."""
    if ranks is None:
        ranks = _rng(seed, "multi_loss").choice(
            protector.group_size, size=e, replace=False)
    return inject_multi_rank_loss(protector, prot,
                                  [int(r) for r in ranks])


def scribble_plan(protector: Protector, seed: int,
                  n_words: int = 4, rank: Optional[int] = None) -> tuple:
    """Deterministic scribble parameters: (rank, word_offsets, xor_mask).

    Offsets land in the rank's flat row; the mask is any nonzero u32 so
    the flip is guaranteed visible to the checksums.  Exposed separately
    from `seeded_scribble` so tests and the chaos runner can predict the
    victim pages without touching state.
    """
    g = _rng(seed, "scribble")
    if rank is None:
        rank = int(g.integers(protector.group_size))
    # draw from the payload region only — a scribble into row padding
    # vanishes on unflatten and would test nothing
    row_words = protector.layout.payload_words
    offsets = sorted(int(o) for o in g.choice(
        row_words, size=min(n_words, row_words), replace=False))
    mask = int(g.integers(1, 1 << 32))
    return rank, offsets, mask


def seeded_scribble(protector: Protector, prot: ProtectedState,
                    seed: int, n_words: int = 4,
                    rank: Optional[int] = None) -> tuple:
    """Deterministic scribble: victims from `scribble_plan(seed)`."""
    rank, offsets, mask = scribble_plan(protector, seed,
                                        n_words=n_words, rank=rank)
    return inject_scribble(protector, prot, rank, offsets, xor_mask=mask)


def smashed_canary_buffer(n_words: int = 4096) -> jax.Array:
    """A staged micro-buffer whose guard page was overrun (for tests)."""
    buf = microbuffer.guard(jnp.zeros((n_words,), jnp.uint32))
    # simulate an out-of-bounds kernel write running past the payload
    return buf.at[n_words + 3].set(jnp.uint32(0x12345678))
