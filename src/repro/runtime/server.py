"""Serving runtime: batched decode with Pangolin protection of the KV cache.

Decode is the paper's *atomic-style small update* case: each step touches a
tiny, known range of the cache (one token slot per layer).  The dirty page
set of a decode step is computed from the cache layout on the host
(`layout.time_slice_pages`: the page columns under time slot `pos` of every
cache leaf; leaves without a sequence axis — recurrent state, conv windows
— count as fully dirty), so decode commits always take the *patch* path:
block checksums refreshed incrementally and parity patched over dirty
pages only.  A previous version jitted `make_commit()` with no dirty pages,
silently sending every decode commit down the bulk path.

Two protection cadences:

  * `window=1` — synchronous: every step routes through
    `Protector.commit(..., dirty_pages=...)` with the static per-position
    page set (compiled once per distinct set, cached).
  * `window=W>1` — deferred epochs (core/epoch.py): in-window steps pay
    protection proportional to the *words* a decode step writes
    (`layout.time_slice_words` — position-independent shapes, so one
    compiled program serves every position) while the cached row stays
    pinned at the epoch start; parity and the checksum table refresh
    once per epoch from the unioned dirty pages.  The scrubber sees
    flushed (current) redundancy: the engine flushes before every scrub.

Both cadences donate the previous protected state into its successor, so
steady-state decode allocates no row-sized buffers.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ProtectConfig
from repro.core import layout as layout_mod
from repro.core.epoch import DeferredProtector, EngineHost
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector, resolve_mode
from repro.models import api
from repro.models.transformer import build_model

PyTree = Any


class Server(EngineHost):
    def __init__(self, cfg: ModelConfig, protect_cfg: ProtectConfig, mesh,
                 *, batch: int, max_len: int, protect_cache: bool = True,
                 window: Optional[int] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.batch = batch
        self.max_len = max_len
        self.model = build_model(cfg, mesh)
        self._decode = jax.jit(api.make_decode_step(self.model))
        self.window = int(window if window is not None
                          else protect_cfg.window)

        self.protect_cache = protect_cache and protect_cfg.mode != "none"
        self.protector: Optional[Protector] = None
        self._engine: Optional[DeferredProtector] = None
        self._est = None
        self._prot = None
        if self.protect_cache:
            cache_abs = jax.eval_shape(
                lambda: self.model._cache_defs(batch, max_len))
            cache_specs = self.model.cache_specs(batch, max_len, mesh)
            self.protector = Protector(
                mesh, cache_abs, cache_specs,
                mode=resolve_mode(protect_cfg.mode,
                                  protect_cfg.redundancy),
                block_words=protect_cfg.block_words,
                hybrid_threshold=protect_cfg.hybrid_threshold)
            lo = self.protector.layout
            self._dirty_cap = layout_mod.time_slice_page_capacity(
                lo, max_len)
            self._page_cache: dict = {}
            self._word_cache: dict = {}
            mode = self.protector.mode
            if self.window > 1 and (mode.has_parity or mode.has_cksums):
                self._engine = DeferredProtector(
                    self.protector, window=self.window,
                    dirty_capacity=self._dirty_cap,
                    dirty_leaf_idx=range(len(lo.slots)))
            # scrub pressure feeds the adaptive window (engine=None inert)
            self.scrubber = Scrubber(self.protector,
                                     period=protect_cfg.scrub_period,
                                     engine=self._engine)

    # protected-state plumbing (prot property / flush) comes from
    # core.epoch.EngineHost

    def _dirty_pages(self, pos: int) -> np.ndarray:
        key = pos % self.max_len
        if key not in self._page_cache:
            self._page_cache[key] = layout_mod.time_slice_pages(
                self.protector.layout, self.max_len, key)
        return self._page_cache[key]

    def _dirty_words(self, pos: int) -> tuple:
        key = pos % self.max_len
        if key not in self._word_cache:
            self._word_cache[key] = tuple(layout_mod.time_slice_words(
                self.protector.layout, self.max_len, key))
        return self._word_cache[key]

    def start(self, params: PyTree) -> None:
        self.params = params
        cache = self.model.init_cache(self.batch, self.max_len)
        specs = self.model.cache_specs(self.batch, self.max_len, self.mesh)
        cache = jax.device_put(cache, jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        if self.protect_cache:
            if self._engine is not None:
                self._est = self._engine.init(cache)
            else:
                self._prot = self.protector.init(cache)
        else:
            self.prot = None
            self.cache = cache
        self.pos = 0

    def _current_cache(self):
        return self.prot.state if self.prot is not None else self.cache

    def step(self, tokens: jax.Array) -> jax.Array:
        """One decode step for the whole batch; returns next tokens."""
        next_tok, logits, new_cache = self._decode(
            self.params, tokens, self._current_cache(),
            jnp.asarray(self.pos, jnp.int32))
        if self.prot is not None:
            if self._engine is not None:
                self._est, ok = self._engine.commit(
                    self._est, new_cache,
                    dirty_words=self._dirty_words(self.pos))
            else:
                self._prot, ok = self.protector.commit(
                    self._prot, new_cache,
                    dirty_pages=self._dirty_pages(self.pos).tolist(),
                    donate=True)
            self.scrubber.on_commit()
            if self.scrubber.due():
                if self._engine is not None:
                    self._est = self._engine.flush_if_pending(self._est)
                prot, _ = self.scrubber.run(self.prot)
                self.prot = prot
        else:
            self.cache = new_cache
        self.pos += 1
        return next_tok

    def prefill(self, prompt: jax.Array) -> jax.Array:
        """Feed a prompt through decode steps (small-scale serving path)."""
        tok = prompt[:, 0]
        for t in range(prompt.shape[1]):
            tok = self.step(prompt[:, t])
        return tok

    def generate(self, prompt: jax.Array, n_new: int) -> np.ndarray:
        tok = self.prefill(prompt)
        out = [np.asarray(jax.device_get(tok))]
        for _ in range(n_new - 1):
            tok = self.step(tok)
            out.append(np.asarray(jax.device_get(tok)))
        return np.stack(out, axis=1)
