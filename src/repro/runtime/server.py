"""Serving runtime: batched decode with Pangolin protection of the KV cache.

Decode is the paper's *atomic-style small update* case: each step touches a
tiny, known range of the cache (one token slot per layer).  The server
protects the cache with:

  * block checksums refreshed incrementally (cost ∝ dirty pages — the
    Adler32 range-update property), and
  * the parity *patch* path (XOR patch over dirty pages only), the
    "atomic XOR" side of the hybrid scheme; params are static and scrubbed.

For simplicity and testability the protected unit here is the cache pytree;
the dirty page set of a decode step is computed from the cache layout once
(it is position-independent for ring buffers, position-dependent for linear
caches — we conservatively take the union of slots the update may touch
when the position is dynamic, or recompute per call when static).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ProtectConfig
from repro.core.scrub import Scrubber
from repro.core.txn import Mode, Protector
from repro.models import api
from repro.models.transformer import build_model

PyTree = Any


class Server:
    def __init__(self, cfg: ModelConfig, protect_cfg: ProtectConfig, mesh,
                 *, batch: int, max_len: int, protect_cache: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.batch = batch
        self.max_len = max_len
        self.model = build_model(cfg, mesh)
        self._decode = jax.jit(api.make_decode_step(self.model))

        self.protect_cache = protect_cache and protect_cfg.mode != "none"
        self.protector: Optional[Protector] = None
        if self.protect_cache:
            cache_abs = jax.eval_shape(
                lambda: self.model._cache_defs(batch, max_len))
            cache_specs = self.model.cache_specs(batch, max_len, mesh)
            self.protector = Protector(
                mesh, cache_abs, cache_specs, mode=Mode(protect_cfg.mode),
                block_words=protect_cfg.block_words,
                hybrid_threshold=protect_cfg.hybrid_threshold)
            self._commit = jax.jit(self.protector.make_commit())
            self.scrubber = Scrubber(self.protector,
                                     period=protect_cfg.scrub_period)

    def start(self, params: PyTree) -> None:
        self.params = params
        cache = self.model.init_cache(self.batch, self.max_len)
        specs = self.model.cache_specs(self.batch, self.max_len, self.mesh)
        cache = jax.device_put(cache, jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        if self.protect_cache:
            self.prot = self.protector.init(cache)
        else:
            self.prot = None
            self.cache = cache
        self.pos = 0

    def _current_cache(self):
        return self.prot.state if self.prot is not None else self.cache

    def step(self, tokens: jax.Array) -> jax.Array:
        """One decode step for the whole batch; returns next tokens."""
        next_tok, logits, new_cache = self._decode(
            self.params, tokens, self._current_cache(),
            jnp.asarray(self.pos, jnp.int32))
        if self.prot is not None:
            self.prot, ok = self._commit(self.prot, new_cache)
            self.scrubber.on_commit()
            if self.scrubber.due():
                self.prot, _ = self.scrubber.run(self.prot)
        else:
            self.cache = new_cache
        self.pos += 1
        return next_tok

    def prefill(self, prompt: jax.Array) -> jax.Array:
        """Feed a prompt through decode steps (small-scale serving path)."""
        tok = prompt[:, 0]
        for t in range(prompt.shape[1]):
            nxt = self.step(prompt[:, t])
        return nxt

    def generate(self, prompt: jax.Array, n_new: int) -> np.ndarray:
        tok = self.prefill(prompt)
        out = [np.asarray(jax.device_get(tok))]
        for _ in range(n_new - 1):
            tok = self.step(tok)
            out.append(np.asarray(jax.device_get(tok)))
        return np.stack(out, axis=1)
