"""Serving runtime: batched decode with Pangolin protection of the KV cache.

Decode is the paper's *atomic-style small update* case: each step touches a
tiny, known range of the cache (one token slot per layer).  The dirty page
set of a decode step is computed from the cache layout on the host
(`layout.time_slice_pages`: the page columns under time slot `pos` of every
cache leaf; leaves without a sequence axis — recurrent state, conv windows
— count as fully dirty), so decode commits always take the *patch* path:
block checksums refreshed incrementally and parity patched over dirty
pages only.  A previous version jitted `make_commit()` with no dirty pages,
silently sending every decode commit down the bulk path.

All engine selection lives in the `Pool` facade (repro/pool.py): the
server builds one cold pool over the cache layout and feeds it both
footprint spellings per step — `dirty_pages` (static page set, keying
the synchronous engine's compiled commit at `window=1`) and
`dirty_words` (position-independent word indices from
`layout.time_slice_words`, the deferred engine's per-step footprint at
`window=W>1`) — and the pool routes to whichever engine the config
built.  The scrubber sees flushed (current) redundancy: `pool.scrub`
flushes before every scrub.

Both cadences donate the previous protected state into its successor, so
steady-state decode allocates no row-sized buffers.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs.base import ModelConfig, ProtectConfig
from repro.core import layout as layout_mod
from repro.models import api
from repro.models.transformer import build_model
from repro.pool import Pool, PoolHost

PyTree = Any


class Server(PoolHost):
    def __init__(self, cfg: ModelConfig, protect_cfg: ProtectConfig, mesh,
                 *, batch: int, max_len: int, protect_cache: bool = True,
                 window: Optional[int] = None,
                 metrics_dir: Optional[str] = None,
                 trace_dir: Optional[str] = None,
                 metrics_every: int = 100):
        self.cfg = cfg
        self.mesh = mesh
        self.batch = batch
        self.max_len = max_len
        self.model = build_model(cfg, mesh)
        self._decode = jax.jit(api.make_decode_step(self.model))
        self.window = int(window if window is not None
                          else protect_cfg.window)

        self.protect_cache = protect_cache and protect_cfg.mode != "none"
        if (self.protect_cache and window is not None
                and window != protect_cfg.window):
            # the kwarg is a per-server override folded back into the
            # config — ProtectConfig stays the single source of truth
            # (and validates it; folded only when a pool is actually
            # built, so unprotected servers accept any window)
            protect_cfg = dataclasses.replace(protect_cfg, window=window)
        # commit ring depth: decode commits at depth > 1 go through
        # `commit_async` and resolve as their verdicts land, so the
        # per-token protection program never blocks token emission;
        # depth 1 keeps the classic resolve-per-commit path
        self.pipeline_depth = int(protect_cfg.pipeline_depth)
        # telemetry surfaces (repro.obs) — mirrors the trainer's flags;
        # on an unprotected server (no pool) they are inert
        self.metrics_dir = metrics_dir
        self.metrics_every = max(1, int(metrics_every))
        tracer = None
        if trace_dir:
            os.makedirs(trace_dir, exist_ok=True)
            tracer = obs.Tracer(
                os.path.join(trace_dir, "server.trace.jsonl"))
        self.pool: Optional[Pool] = None
        if self.protect_cache:
            cache_abs = jax.eval_shape(
                lambda: self.model._cache_defs(batch, max_len))
            cache_specs = self.model.cache_specs(batch, max_len, mesh)
            # decode's deferred engine spans every cache leaf, with the
            # per-step page capacity sized from the layout the pool builds
            self.pool = Pool(
                mesh, cache_abs, cache_specs, protect_cfg,
                dirty_leaf_idx=(
                    None if self.window == 1
                    else (lambda lo: range(len(lo.slots)))),
                dirty_capacity=(
                    None if self.window == 1
                    else (lambda lo: layout_mod.time_slice_page_capacity(
                        lo, max_len))),
                tracer=tracer)
            self._page_cache: dict = {}
            self._word_cache: dict = {}
        # chaos/observability: hooks fired after every decode step with
        # {"pos": absolute position} (schedule attachment, tracing)
        self._step_hooks: list = []

    def add_step_hook(self, fn) -> None:
        """Register `fn(server, out_dict)`, fired after every decode
        step — the chaos campaign's schedule attachment point."""
        self._step_hooks.append(fn)

    # pool delegation (protector / scrubber / prot / flush) comes from
    # repro.pool.PoolHost

    # -- decode-footprint plumbing ----------------------------------------------

    def _dirty_pages(self, pos: int) -> np.ndarray:
        key = pos % self.max_len
        if key not in self._page_cache:
            self._page_cache[key] = layout_mod.time_slice_pages(
                self.protector.layout, self.max_len, key)
        return self._page_cache[key]

    def _dirty_words(self, pos: int) -> tuple:
        key = pos % self.max_len
        if key not in self._word_cache:
            self._word_cache[key] = tuple(layout_mod.time_slice_words(
                self.protector.layout, self.max_len, key))
        return self._word_cache[key]

    def start(self, params: PyTree) -> None:
        self.params = params
        cache = self.model.init_cache(self.batch, self.max_len)
        specs = self.model.cache_specs(self.batch, self.max_len, self.mesh)
        cache = jax.device_put(cache, jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P)))
        if self.pool is not None:
            self.pool.init(cache)
        else:
            self.cache = cache
        self.pos = 0

    def _current_cache(self):
        return self.prot.state if self.prot is not None else self.cache

    def step(self, tokens: jax.Array) -> jax.Array:
        """One decode step for the whole batch; returns next tokens."""
        next_tok, logits, new_cache = self._decode(
            self.params, tokens, self._current_cache(),
            jnp.asarray(self.pos, jnp.int32))
        if self.pool is not None:
            # only the built engine's footprint spelling is computed —
            # the other would be host work cached for nothing
            fp = (dict(dirty_words=self._dirty_words(self.pos))
                  if self.pool.engine is not None
                  else dict(dirty_pages=self._dirty_pages(self.pos)
                            .tolist()))
            if self.pipeline_depth > 1:
                # ring cadence: dispatch and move on; earlier verdicts
                # resolve opportunistically as they land (the ring
                # force-resolves the oldest past depth), and `generate`
                # drains at the end
                self.pool.commit_async(new_cache, **fp)
                self.pool.poll()
            else:
                self.pool.commit(new_cache, **fp)
            self.pool.maybe_scrub()
            reg = self.pool.metrics
            reg.counter("server_steps_total").inc()
            if (self.metrics_dir
                    and (self.pos + 1) % self.metrics_every == 0):
                obs.write_metrics(reg, self.metrics_dir,
                                  prefix="server",
                                  stats=self.pool.stats())
        else:
            self.cache = new_cache
        self.pos += 1
        for hook in list(self._step_hooks):
            hook(self, {"pos": self.pos - 1})
        return next_tok

    def prefill(self, prompt: jax.Array) -> jax.Array:
        """Feed a prompt through decode steps (small-scale serving path)."""
        tok = prompt[:, 0]
        for t in range(prompt.shape[1]):
            tok = self.step(prompt[:, t])
        return tok

    def generate(self, prompt: jax.Array, n_new: int) -> np.ndarray:
        tok = self.prefill(prompt)
        out = [np.asarray(jax.device_get(tok))]
        for _ in range(n_new - 1):
            tok = self.step(tok)
            out.append(np.asarray(jax.device_get(tok)))
        if self.pool is not None:
            # a generation boundary is a pipeline boundary: every
            # in-flight commit verdict resolves before tokens return
            self.pool.drain()
        return np.stack(out, axis=1)
