"""Low-level helpers: bit-exact dtype<->uint32 casting, padding, tree utilities.

Pangolin computes parity/checksums over raw bytes.  The JAX analogue is a
uint32 "word" view of every tensor: parity and checksums are computed on bit
patterns, never on float values, so reconstruction is bit-exact for any dtype.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

PyTree = Any

# ---------------------------------------------------------------------------
# dtype <-> uint32 word views
# ---------------------------------------------------------------------------

_U32_PER_ELEM = {
    jnp.dtype(jnp.float32): 1,
    jnp.dtype(jnp.int32): 1,
    jnp.dtype(jnp.uint32): 1,
}
_U16_DTYPES = (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16),
               jnp.dtype(jnp.int16), jnp.dtype(jnp.uint16))
_U8_DTYPES = (jnp.dtype(jnp.int8), jnp.dtype(jnp.uint8))


def words_per_elem(dtype) -> float:
    """uint32 words per element of `dtype` (may be fractional for sub-32-bit)."""
    d = jnp.dtype(dtype)
    if d in _U32_PER_ELEM:
        return 1.0
    if d in _U16_DTYPES:
        return 0.5
    if d in _U8_DTYPES:
        return 0.25
    raise ValueError(f"unsupported dtype for word view: {d}")


def num_words(shape: Sequence[int], dtype) -> int:
    """Number of uint32 words needed to hold a tensor (with padding)."""
    n = math.prod(shape)
    d = jnp.dtype(dtype)
    if d in _U32_PER_ELEM:
        return n
    if d in _U16_DTYPES:
        return (n + 1) // 2
    if d in _U8_DTYPES:
        return (n + 3) // 4
    raise ValueError(f"unsupported dtype for word view: {d}")


def to_words(x: jax.Array) -> jax.Array:
    """Bit-exact view of `x` as a flat uint32 vector (zero-padded)."""
    d = jnp.dtype(x.dtype)
    flat = x.reshape(-1)
    if d in _U32_PER_ELEM:
        return lax.bitcast_convert_type(flat, jnp.uint32)
    if d in _U16_DTYPES:
        u16 = lax.bitcast_convert_type(flat, jnp.uint16)
        if u16.size % 2:
            u16 = jnp.concatenate([u16, jnp.zeros((1,), jnp.uint16)])
        pair = u16.reshape(-1, 2).astype(jnp.uint32)
        return pair[:, 0] | (pair[:, 1] << 16)
    if d in _U8_DTYPES:
        u8 = lax.bitcast_convert_type(flat, jnp.uint8)
        pad = (-u8.size) % 4
        if pad:
            u8 = jnp.concatenate([u8, jnp.zeros((pad,), jnp.uint8)])
        quad = u8.reshape(-1, 4).astype(jnp.uint32)
        return (quad[:, 0] | (quad[:, 1] << 8) | (quad[:, 2] << 16)
                | (quad[:, 3] << 24))
    raise ValueError(f"unsupported dtype for word view: {d}")


def from_words(w: jax.Array, shape: Sequence[int], dtype) -> jax.Array:
    """Inverse of :func:`to_words` — bit-exact reconstruction."""
    d = jnp.dtype(dtype)
    n = math.prod(shape)
    if d in _U32_PER_ELEM:
        flat = lax.bitcast_convert_type(w[:n], d)
        return flat.reshape(shape)
    if d in _U16_DTYPES:
        lo = (w & 0xFFFF).astype(jnp.uint16)
        hi = (w >> 16).astype(jnp.uint16)
        u16 = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
        return lax.bitcast_convert_type(u16, d).reshape(shape)
    if d in _U8_DTYPES:
        bs = [((w >> (8 * i)) & 0xFF).astype(jnp.uint8) for i in range(4)]
        u8 = jnp.stack(bs, axis=-1).reshape(-1)[:n]
        return lax.bitcast_convert_type(u8, d).reshape(shape)
    raise ValueError(f"unsupported dtype for word view: {d}")


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_to(x: jax.Array, n: int, value=0) -> jax.Array:
    """Pad 1-D `x` with `value` up to length `n`."""
    if x.shape[0] == n:
        return x
    assert x.shape[0] < n, (x.shape, n)
    return jnp.concatenate(
        [x, jnp.full((n - x.shape[0],), value, dtype=x.dtype)])


def tree_bytes(tree: PyTree) -> int:
    """Total payload bytes of a pytree of arrays / ShapeDtypeStructs."""
    return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def tree_equal_bits(a: PyTree, b: PyTree) -> bool:
    """Bit-exact equality of two pytrees (host-side)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    for x, y in zip(la, lb):
        xn, yn = np.asarray(x), np.asarray(y)
        if xn.shape != yn.shape or xn.dtype != yn.dtype:
            return False
        if xn.tobytes() != yn.tobytes():
            return False
    return True


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Placement of one pytree leaf inside the flat word row (a 'zone object')."""
    offset: int          # word offset in the row
    n_words: int         # words occupied (incl. sub-word padding)
    shape: tuple         # local shard shape
    dtype: Any


def fingerprint(tree: PyTree) -> int:
    """Cheap structural fingerprint for layout-compatibility checks."""
    parts = []
    for path, leaf in jax.tree.leaves_with_path(tree):
        parts.append((str(path), tuple(leaf.shape), str(jnp.dtype(leaf.dtype))))
    return hash(tuple(parts))
