import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them
# and no __future__ import is used in this module.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the jitted step function with full
production shardings and runs `.lower(**abstract_inputs).compile()` —
ShapeDtypeStruct stand-ins only, zero allocation.  It records:

  * memory_analysis()    — per-device bytes (proves the cell fits HBM),
  * cost_analysis()      — HLO FLOPs / bytes for the roofline,
  * parsed collective wire bytes (launch/hlo_analysis.py),
  * compile wall time.

Train cells lower the *protected* train step (train_step + Pangolin commit
fused in one program) so the parity reduce-scatter and checksum sweeps are
part of the compiled artifact the roofline reads.  Decode cells lower
serve_step (one token against a full KV cache); prefill cells lower the
forward pass.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch ID|all] [--workload NAME|all] [--mesh single|multi|both]
        [--protect mlpc|mlp|ml|none|replica] [--out results.json]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import WORKLOADS, get_config, workload_skips
from repro.configs.base import ProtectConfig, TrainConfig
from repro.configs.registry import list_archs
from repro.launch import hlo_analysis as hlo
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.transformer import build_model
from repro.optim import build_optimizer

# per-arch gradient-accumulation factors for the train_4k cell (activation
# memory control; see DESIGN.md §7)
MICROBATCHES = {
    "llama4-maverick-400b-a17b": 8,
    "chameleon-34b": 16,
    "minitron-8b": 8,
    "glm4-9b": 8,
    "moonshot-v1-16b-a3b": 8,
    "seamless-m4t-large-v2": 8,
    "recurrentgemma-2b": 4,
    "xlstm-1.3b": 4,
    "qwen2-0.5b": 4,
    "qwen3-0.6b": 4,
}


def _specs_to_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _analyze(lowered, compiled, n_devices: int, model_flops: float) -> dict:
    # XLA's cost_analysis counts loop bodies once; the trip-count-aware
    # model (launch/hlo_cost.py) rolls the call graph up with multipliers.
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0]
    text = compiled.as_text()
    totals = hlo_cost.analyze_text(text)
    mem = compiled.memory_analysis()
    memd = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        memd[attr] = int(getattr(mem, attr, 0) or 0)
    memd["total_bytes_per_device"] = (
        memd["argument_size_in_bytes"] + memd["output_size_in_bytes"]
        + memd["temp_size_in_bytes"] - memd["alias_size_in_bytes"])
    roof = hlo.roofline_terms(totals.flops, totals.hbm_bytes,
                              totals.total_wire_bytes,
                              model_flops=model_flops / n_devices)
    return {
        "cost": {"flops": totals.flops, "hbm_bytes": totals.hbm_bytes,
                 "raw_hbm_bytes": totals.raw_hbm_bytes,
                 "xla_raw_flops": float(xla_cost.get("flops", 0.0)),
                 "xla_raw_bytes": float(
                     xla_cost.get("bytes accessed", 0.0))},
        "memory": memd,
        "collectives": {"wire_bytes": totals.wire_bytes,
                        "counts": totals.coll_counts,
                        "total_wire_bytes": totals.total_wire_bytes},
        "roofline": roof.as_dict(),
    }


def dryrun_cell(arch: str, wl_name: str, multi_pod: bool,
                protect: str = "mlpc", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    wl = WORKLOADS[wl_name]
    skip = workload_skips(cfg, wl)
    rec = {"arch": arch, "workload": wl_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "protect": protect, "status": "skip" if skip else "run"}
    if skip:
        rec["skip_reason"] = skip
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    model = build_model(cfg, mesh)
    n_params = api.count_params(cfg)
    n_active = api.count_params(cfg, active_only=True)

    if wl.kind == "train":
        train_cfg = TrainConfig(microbatches=MICROBATCHES.get(arch, 1))
        optimizer = build_optimizer(train_cfg, cfg)
        abstract_state = api.abstract_train_state(model, optimizer)
        state_specs = api.train_state_specs(model, optimizer, mesh)
        # a cold pool: layout + compiled programs, zero allocation
        from repro.pool import Pool
        pool = Pool(mesh, abstract_state, state_specs,
                    ProtectConfig(mode=protect))
        protector = pool.protector
        commit = protector.make_commit()
        train_step = api.make_train_step(model, optimizer, train_cfg)

        def step(prot, batch):
            new_state, metrics = train_step(prot.state, batch)
            prot2, ok = commit(prot, new_state,
                               data_cursor=prot.step,
                               rng_key=jax.random.PRNGKey(0))
            return prot2, (metrics["loss"], ok)

        prot_abs = protector.abstract_protected(abstract_state)
        prot_specs = protector.protected_specs()
        batch_abs = api.batch_abstract(cfg, wl)
        b_specs = api.batch_specs(cfg, mesh, wl.global_batch)
        in_sh = (_specs_to_shardings(prot_specs, mesh),
                 _specs_to_shardings(b_specs, mesh))
        # donate the protected state: the commit's functional select and the
        # new optimizer state then alias the old buffers in place — without
        # this the step holds old+new state copies (llama4: +12.5 GiB/dev)
        fn = jax.jit(step, in_shardings=in_sh, donate_argnums=(0,))
        lowered = fn.lower(prot_abs, batch_abs)
        tokens = wl.global_batch * wl.seq_len
        model_flops = 6.0 * n_active * tokens
        rec["protection_overhead"] = protector.overhead_report()
    elif wl.kind == "prefill":
        forward = api.make_prefill(model)
        pspecs = model.param_specs(mesh)
        abstract_params = model.abstract_params()
        batch_abs = api.batch_abstract(cfg, wl)
        b_specs = api.batch_specs(cfg, mesh, wl.global_batch)
        in_sh = (_specs_to_shardings(pspecs, mesh),
                 _specs_to_shardings(b_specs, mesh))
        fn = jax.jit(forward, in_shardings=in_sh)
        lowered = fn.lower(abstract_params, batch_abs)
        tokens = wl.global_batch * wl.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        decode = api.make_decode_step(model)
        pspecs = model.param_specs(mesh)
        abstract_params = model.abstract_params()
        dec_abs = api.decode_abstract(cfg, wl, model)
        dec_specs = api.decode_specs(cfg, wl, model, mesh)
        in_sh = (_specs_to_shardings(pspecs, mesh),
                 _specs_to_shardings(dec_specs["token"], mesh),
                 _specs_to_shardings(dec_specs["cache"], mesh),
                 NamedSharding(mesh, P()))
        fn = jax.jit(decode, in_shardings=in_sh)
        lowered = fn.lower(abstract_params, dec_abs["token"],
                           dec_abs["cache"], dec_abs["pos"])
        model_flops = 2.0 * n_active * wl.global_batch
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    rec.update(_analyze(lowered, compiled, n_dev, model_flops))
    rec.update({
        "status": "ok",
        "n_devices": n_dev,
        "n_params": n_params,
        "n_active_params": n_active,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    })
    if verbose:
        r = rec["roofline"]
        print(f"[{arch} x {wl_name} x {rec['mesh']}] OK "
              f"compile={t_compile:.1f}s "
              f"mem/dev={rec['memory']['total_bytes_per_device']/2**30:.2f}GiB "
              f"compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"coll={r['collective_s']*1e3:.2f}ms bound={r['bound']}",
              flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--workload", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--protect", default="mlpc")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    wls = list(WORKLOADS) if args.workload == "all" else [args.workload]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        done = {(r["arch"], r["workload"], r["mesh"]) for r in results
                if r.get("status") in ("ok", "skip")}

    failures = 0
    for arch in archs:
        for wl in wls:
            for mp in meshes:
                key = (arch, wl, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                try:
                    rec = dryrun_cell(arch, wl, mp, protect=args.protect)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = {"arch": arch, "workload": wl,
                           "mesh": key[2], "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    failures += 1
                    print(f"[{arch} x {wl} x {key[2]}] FAILED: "
                          f"{rec['error']}", flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skip")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skip, "
          f"{failures} failed -> {args.out}")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
