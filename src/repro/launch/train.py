"""Training launcher: `PYTHONPATH=src python -m repro.launch.train --arch <id>`.

Runs the fault-tolerant training loop (runtime/trainer.py) for any
registered architecture.  On real hardware the mesh comes from
`jax.devices()` after `jax.distributed.initialize()`; on this container,
`--host-devices N` forces N CPU host devices so the zone collectives run.
Reduced configs (`--reduced`, default) train on CPU; full configs are for
cluster use (the dry-run exercises them without allocation).
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--data", type=int, default=4, help="data-axis size")
    ap.add_argument("--model", type=int, default=2, help="model-axis size")
    ap.add_argument("--protect", default="mlpc",
                    choices=["none", "ml", "mlp", "mlpc", "replica",
                             "mlp2", "mlpc2"])
    ap.add_argument("--redundancy", type=int, default=1,
                    choices=[1, 2, 3, 4],
                    help="syndrome stack height r = rank losses survived "
                         "per zone: 1 = XOR parity, 2 adds the GF(2^32) "
                         "Q row, 3-4 add higher Vandermonde rows "
                         "(requires r <= data-axis size - 1)")
    ap.add_argument("--scrub-period", type=int, default=50)
    ap.add_argument("--window", type=int, default=1,
                    help="deferred-epoch window W (1 = synchronous "
                         "per-commit protection)")
    ap.add_argument("--overlap-commit", action="store_true",
                    help="dispatch step t+1 before awaiting commit t "
                         "(shorthand for --pipeline-depth 2)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="async commit ring depth: up to this many "
                         "steps stay dispatched with unresolved "
                         "verdicts (1 = resolve every step)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--host-devices", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-dir", default=None,
                    help="publish the pool's metric registry "
                         "(trainer.prom + trainer.stats.json) here "
                         "every --metrics-every resolved steps")
    ap.add_argument("--metrics-every", type=int, default=25)
    ap.add_argument("--trace-dir", default=None,
                    help="append the pool's JSONL span trace "
                         "(trainer.trace.jsonl) here")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the live Prometheus scrape endpoint "
                         "(obs.serve_metrics) on this port for the run "
                         "(0 = OS-assigned; the bound port is printed)")
    args = ap.parse_args(argv)

    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    import jax
    from repro.configs.base import ProtectConfig, TrainConfig
    from repro.configs.registry import get_config
    from repro.runtime.trainer import Trainer

    n_dev = len(jax.devices())
    data = min(args.data, n_dev // args.model)
    mesh = jax.make_mesh((data, args.model), ("data", "model"))
    cfg = get_config(args.arch, reduced=args.reduced)
    trainer = Trainer(
        cfg,
        TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                    microbatches=args.microbatches,
                    optimizer=args.optimizer),
        ProtectConfig(mode=args.protect, scrub_period=args.scrub_period,
                      redundancy=args.redundancy, window=args.window,
                      overlap_commit=args.overlap_commit,
                      pipeline_depth=args.pipeline_depth),
        mesh, seq_len=args.seq_len, global_batch=args.global_batch,
        checkpoint_dir=args.ckpt_dir, seed=args.seed,
        metrics_dir=args.metrics_dir, trace_dir=args.trace_dir,
        metrics_every=args.metrics_every)
    trainer.initialize()
    scrape = None
    if args.metrics_port is not None:
        from repro import obs
        scrape = obs.serve_metrics(trainer.pool.metrics,
                                   port=args.metrics_port)
        print("metrics endpoint: "
              f"http://127.0.0.1:{scrape.server_address[1]}/metrics")
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} protect={args.protect} "
          f"overhead={trainer.pool.overhead_report()}")
    outs = trainer.run(args.steps, checkpoint_every=args.ckpt_every)
    for o in outs[:: max(args.steps // 10, 1)]:
        print(f"step {o['step']:5d}  loss {o['loss']:.4f}")
    print(f"final: step {outs[-1]['step']} loss {outs[-1]['loss']:.4f}")
    health = trainer.pool.health()
    print(f"health: {health.status}"
          + (f" ({'; '.join(health.reasons)})" if health.reasons else ""))
    if args.metrics_dir:
        from repro import obs
        paths = obs.write_metrics(trainer.pool.metrics, args.metrics_dir,
                                  prefix="trainer",
                                  stats=trainer.pool.stats())
        print(f"metrics: {paths['prom']}")
    if scrape is not None:
        scrape.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
