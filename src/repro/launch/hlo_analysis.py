"""HLO-level analysis for the roofline: collective volume + cost terms.

`cost_analysis()` gives HLO FLOPs and bytes, but not collective traffic —
we parse the optimized HLO text, build an instruction-name -> shape map, and
sum wire bytes for every collective with the standard volume conventions:

    all-gather          (G-1)/G * result_bytes
    reduce-scatter      (G-1)/G * operand_bytes
    all-reduce          2 (G-1)/G * operand_bytes      (RS + AG)
    all-to-all          (G-1)/G * operand_bytes
    collective-permute  operand_bytes

Group size G is parsed from replica_groups when present.  v5e hardware
constants for the three roofline terms live here too.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

# -- TPU v5e constants (per chip) -------------------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (assignment's constant)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# `%name = dtype[dims]{layout} opcode(...)` — optimized HLO instruction line
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]"
    r"[^\s]*\s+([a-z0-9\-]+)\(")
_TUPLE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: Dict[str, float]          # per collective kind, per device
    counts: Dict[str, int]
    total_wire_bytes: float = 0.0

    def __post_init__(self):
        self.total_wire_bytes = sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    shapes: Dict[str, int] = {}
    wire = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}

    pending = []  # (opcode, operand names, result bytes, group size, line)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, dtype, dims, opcode = m.groups()
        nbytes = _shape_bytes(dtype, dims)
        shapes[name] = nbytes
        base = None
        for c in COLLECTIVES:
            # opcodes appear as all-gather / all-gather-start / -done etc.
            if opcode == c or opcode.startswith(c + "-"):
                base = c
                break
        if base is None or opcode.endswith("-done"):
            continue
        # operand list: text between the first '(' and matching ')'
        try:
            args_str = line.split("(", 1)[1]
        except IndexError:
            args_str = ""
        # cut at '), ' attributes boundary
        depth, end = 1, len(args_str)
        for i, ch in enumerate(args_str):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_names = []
        for tok in args_str[:end].split(","):
            tok = tok.strip()
            mm = _OPERAND_RE.match(tok)
            if mm:
                operand_names.append(mm.group(1))
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gm2 = _GROUPS_V2_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        pending.append((base, operand_names, nbytes, g))
        counts[base] += 1

    for base, operand_names, result_bytes, g in pending:
        operand_bytes = sum(shapes.get(o, 0) for o in operand_names)
        if operand_bytes == 0:
            operand_bytes = result_bytes
        if g is None or g <= 1:
            frac = 1.0
        else:
            frac = (g - 1) / g
        if base == "all-gather":
            wire[base] += frac * result_bytes
        elif base == "all-reduce":
            wire[base] += 2.0 * frac * operand_bytes
        elif base == "reduce-scatter":
            wire[base] += frac * operand_bytes
        elif base == "all-to-all":
            wire[base] += frac * operand_bytes
        elif base == "collective-permute":
            wire[base] += operand_bytes
    return CollectiveStats(wire_bytes=wire, counts=counts)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    hbm_bytes: float             # per device
    wire_bytes: float            # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float = 0.0     # analytic 6ND (whole step, per device)
    useful_ratio: float = 0.0    # model_flops / hlo flops

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   model_flops: float = 0.0) -> Roofline:
    ct = flops / PEAK_FLOPS_BF16
    mt = hbm_bytes / HBM_BW
    lt = wire_bytes / ICI_BW
    bound = max((("compute", ct), ("memory", mt), ("collective", lt)),
                key=lambda kv: kv[1])[0]
    return Roofline(
        flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire_bytes,
        compute_s=ct, memory_s=mt, collective_s=lt, bound=bound,
        model_flops=model_flops,
        useful_ratio=(model_flops / flops) if flops else 0.0)
