"""Trip-count-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts each `while` body ONCE — for
scanned models (layers, microbatches, CE chunks, KV chunks) that
under-reports FLOPs/bytes/collectives by the loop trip counts.  This module
parses the optimized HLO text into its computation call graph, reads the
`known_trip_count` backend_config that XLA attaches to rolled loops, and
rolls costs up with multipliers:

  flops        2 * result_elems * contraction_extent per dot (+1/elem for
               other float ops — matches XLA's convention to ~1%)
  hbm bytes    operand+result bytes of materializing top-level instructions
               (fusion-internal traffic excluded, as XLA does)
  collectives  wire bytes by kind with the standard volume conventions,
               multiplied through loops

Validated against XLA's own numbers on unrolled programs (test_hlo_cost).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}
_FLOAT_DTYPES = {"f64", "f32", "f16", "bf16", "f8e4m3fn", "f8e5m2"}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# skip these opcodes entirely for byte accounting
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "optimization-barrier",
    "get-dimension-size", "partition-id", "replica-id", "custom-call",
    "infeed", "outfeed", "copy-start", "copy-done",
}

# On TPU these fuse into their consumers (producer-consumer fusion), so
# their intermediates never touch HBM.  The CPU backend leaves many of them
# unfused; counting them would inflate the memory term ~5-20x vs what the
# TPU compiler emits.  "Fused bytes" (the headline) skips them; "raw bytes"
# keeps them as an upper bound.
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "convert", "select",
    "compare", "and", "or", "xor", "not", "clamp", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "broadcast", "iota",
    "reverse", "real", "imag", "is-finite", "expm1", "log1p", "atan2",
    "remainder", "pad", "cosine", "sine", "erf", "reduce-precision", "copy",
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z0-9\-]+)\(")
_ATTR_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_ATTR_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _tuple_shapes(type_str: str) -> List[Tuple[str, int]]:
    """All (dtype, elems) leaf shapes in a (possibly tuple) HLO type."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        out.append((dtype, n))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(n * _DTYPE_BYTES.get(dt, 4) for dt, n in
               _tuple_shapes(type_str))


def _elems_of(type_str: str) -> int:
    shapes = _tuple_shapes(type_str)
    return shapes[0][1] if shapes else 0


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2).strip():
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            # computation headers start at column 0:
            #   [ENTRY] %name (params...) -> type {
            if (line and not line[0].isspace() and line.endswith("{")
                    and "->" in line):
                stripped = line.strip()
                is_entry = stripped.startswith("ENTRY")
                if is_entry:
                    stripped = stripped[len("ENTRY"):].strip()
                name = stripped.lstrip("%").split(" ", 1)[0].split("(")[0]
                if name:
                    current = Computation(name=name, instrs=[],
                                          is_entry=is_entry)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        # operands: text inside the first top-level parens after opcode
        after = line.split(opcode + "(", 1)
        ops: List[str] = []
        if len(after) == 2:
            depth, buf = 1, []
            for ch in after[1]:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                buf.append(ch)
            ops = _OPERAND_RE.findall("".join(buf))
        current.instrs.append(Instr(name, type_str, opcode, line, ops))
    return comps


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0          # fusion-aware (headline memory term)
    raw_hbm_bytes: float = 0.0      # every top-level op (upper bound)
    wire_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.raw_hbm_bytes += other.raw_hbm_bytes * mult
        for k in COLLECTIVES:
            self.wire_bytes[k] += other.wire_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult


_CONST_RE = re.compile(r"=\s+s32\[\]\s+constant\((\d+)\)")


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self.shapes: Dict[str, str] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                self.shapes[ins.name] = ins.type_str
        # computations called as fusion bodies / scalar appliers: flops-only
        self.fused: set = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                for m in _ATTR_CALLS.finditer(ins.line):
                    self.fused.add(m.group(1))
                for m in _ATTR_APPLY.finditer(ins.line):
                    self.fused.add(m.group(1))
        self._memo: Dict[str, CostTotals] = {}

    # -- per-instruction costs ---------------------------------------------------

    def _dot_flops(self, ins: Instr) -> float:
        out_elems = _elems_of(ins.type_str)
        lhs = self.shapes.get(ins.operands[0] if ins.operands else "", "")
        lhs_dims = _dims_of(lhs)
        cm = _LHS_C_RE.search(ins.line)
        contraction = 1
        if cm and cm.group(1).strip() and lhs_dims:
            for idx in cm.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contraction *= lhs_dims[i]
        return 2.0 * out_elems * contraction

    def _collective(self, ins: Instr, tot: CostTotals):
        base = None
        for c in COLLECTIVES:
            if ins.opcode == c or ins.opcode.startswith(c + "-"):
                base = c
                break
        if base is None or ins.opcode.endswith("-done"):
            return
        operand_bytes = sum(_bytes_of(self.shapes.get(o, ""))
                            for o in ins.operands
                            if o in self.shapes)
        result_bytes = _bytes_of(ins.type_str)
        if operand_bytes == 0:
            operand_bytes = result_bytes
        g = None
        gm = _GROUPS_RE.search(ins.line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip()])
        else:
            gm2 = _GROUPS_V2_RE.search(ins.line)
            if gm2:
                g = int(gm2.group(2))
        frac = 1.0 if not g or g <= 1 else (g - 1) / g
        if base == "all-gather":
            tot.wire_bytes[base] += frac * result_bytes
        elif base == "all-reduce":
            tot.wire_bytes[base] += 2.0 * frac * operand_bytes
        elif base == "reduce-scatter":
            tot.wire_bytes[base] += frac * operand_bytes
        elif base == "all-to-all":
            tot.wire_bytes[base] += frac * operand_bytes
        else:  # collective-permute
            tot.wire_bytes[base] += operand_bytes
        tot.coll_counts[base] += 1

    _PARAM_RE = re.compile(r"parameter\((\d+)\)")

    def _fusion_bytes(self, ins: Instr) -> Optional[int]:
        """HBM traffic of a fusion, modeling in-place slice semantics.

        XLA fuses dynamic-(update-)slice into producers/consumers and
        performs them in place: a fusion whose root updates one slot of a
        scan's stacked carry writes ONLY the slot, and a fused
        dynamic-slice reads only the slot — charging full operand/result
        shapes turns every scan-carried buffer into phantom traffic
        multiplied by the trip count (32k-step scans: petabytes).
        Returns None if the fused computation cannot be resolved.
        """
        cm = _ATTR_CALLS.search(ins.line)
        if not cm:
            return None
        comp = self.comps.get(cm.group(1))
        if comp is None or not comp.instrs:
            return None
        by_name = {i.name: i for i in comp.instrs}
        # positional param name -> uses inside the fused computation
        param_of_pos: Dict[int, str] = {}
        uses: Dict[str, List[Instr]] = {}
        for i in comp.instrs:
            pm = self._PARAM_RE.search(i.line)
            if i.opcode == "parameter" and pm:
                param_of_pos[int(pm.group(1))] = i.name
            for o in i.operands:
                uses.setdefault(o, []).append(i)

        def slice_only_bytes(pname: str) -> Optional[int]:
            """If a param is consumed only via dynamic-slice/gather (possibly
            through bitcasts/copies), the traffic is the slices' sizes."""
            total = 0
            stack = [pname]
            seen = set()
            while stack:
                nm = stack.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                for u in uses.get(nm, ()):
                    if u.opcode in ("bitcast", "copy", "reshape"):
                        stack.append(u.name)
                    elif u.opcode in ("dynamic-slice", "gather"):
                        total += _bytes_of(u.type_str)
                    elif (u.opcode == "dynamic-update-slice"
                          and u.operands and u.operands[0] == nm):
                        # in-place update target: charged on the write side
                        continue
                    else:
                        return None
            return total

        read = 0
        for pos, oname in enumerate(ins.operands):
            pname = param_of_pos.get(pos)
            sb = slice_only_bytes(pname) if pname is not None else None
            if sb is not None:
                read += sb
            else:
                read += _bytes_of(self.shapes.get(oname, ""))

        # writes: tuple elements / root — DUS roots write only the update
        root = comp.instrs[-1]
        roots = [root]
        if root.opcode == "tuple":
            roots = [by_name[o] for o in root.operands if o in by_name]
        write = 0
        for r in roots:
            if r.opcode == "dynamic-update-slice" and len(r.operands) > 1:
                write += _bytes_of(self.shapes.get(r.operands[1], ""))
            else:
                write += _bytes_of(r.type_str)
        return read + write

    def _trip_from_cond(self, ins: Instr) -> int:
        """Fallback trip count for un-annotated whiles: the loop bound is the
        largest scalar s32 constant in the condition computation (lax.scan
        lowers to `counter < N` with counter starting at 0)."""
        cm = _ATTR_COND.search(ins.line)
        if not cm:
            return 1
        cond = self.comps.get(cm.group(1))
        if cond is None:
            return 1
        best = 1
        for ci in cond.instrs:
            m = _CONST_RE.search(ci.line)
            if m:
                best = max(best, int(m.group(1)))
        return best

    # -- roll-up -------------------------------------------------------------------

    def comp_cost(self, name: str) -> CostTotals:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        tot = CostTotals()
        self._memo[name] = tot          # break cycles defensively
        if comp is None:
            return tot
        count_bytes = name not in self.fused
        for ins in comp.instrs:
            dt0 = _tuple_shapes(ins.type_str)
            is_float = bool(dt0) and dt0[0][0] in _FLOAT_DTYPES
            if ins.opcode == "dot" or ins.opcode == "convolution":
                tot.flops += self._dot_flops(ins)
            elif is_float and ins.opcode not in _NO_BYTES:
                tot.flops += _elems_of(ins.type_str)
            self._collective(ins, tot)
            if count_bytes and ins.opcode not in _NO_BYTES:
                if ins.opcode == "fusion":
                    fb = self._fusion_bytes(ins)
                    nbytes = fb if fb is not None else (
                        sum(_bytes_of(self.shapes.get(o, ""))
                            for o in ins.operands if o in self.shapes)
                        + _bytes_of(ins.type_str))
                elif ins.opcode in ("dynamic-slice", "slice", "gather"):
                    # reads only the sliced/gathered region (≈ result
                    # size), not the whole operand — charging operand
                    # bytes makes a scan that slices a carried buffer
                    # appear to stream the full buffer EVERY step
                    # (petabytes of phantom traffic for 32k-step scans).
                    nbytes = 2 * _bytes_of(ins.type_str)
                elif ins.opcode in ("dynamic-update-slice", "scatter"):
                    # read+write of the updated region only; the update
                    # operand is operand #1
                    upd = (_bytes_of(self.shapes.get(ins.operands[1], ""))
                           if len(ins.operands) > 1 else 0)
                    nbytes = 2 * upd
                else:
                    ob = sum(_bytes_of(self.shapes.get(o, ""))
                             for o in ins.operands if o in self.shapes)
                    nbytes = ob + _bytes_of(ins.type_str)
                tot.raw_hbm_bytes += nbytes
                if ins.opcode not in _ELEMENTWISE:
                    tot.hbm_bytes += nbytes
            # nested computations
            trip = 1
            tm = _TRIP_RE.search(ins.line)
            if tm:
                trip = int(tm.group(1))
            elif ins.opcode == "while":
                trip = self._trip_from_cond(ins)
            bm = _ATTR_BODY.search(ins.line)
            if bm:
                tot.add(self.comp_cost(bm.group(1)), trip)
                cm = _ATTR_COND.search(ins.line)
                if cm:
                    tot.add(self.comp_cost(cm.group(1)), trip + 1)
            for m in _ATTR_CALLS.finditer(ins.line):
                tot.add(self.comp_cost(m.group(1)), 1)
            am = _ATTR_APPLY.search(ins.line)
            if am:
                # scalar applier of reduce/sort/etc: flops ~ result elems,
                # already approximated above; skip roll-up
                pass
            brm = _ATTR_BRANCHES.search(ins.line)
            if brm:
                for b in _OPERAND_RE.findall(brm.group(1)):
                    tot.add(self.comp_cost(b), 1.0)
            if ins.opcode == "call":
                # call(...), to_apply=
                if am:
                    tot.add(self.comp_cost(am.group(1)), 1)
        self._memo[name] = tot
        return tot

    def entry_cost(self) -> CostTotals:
        for comp in self.comps.values():
            if comp.is_entry:
                return self.comp_cost(comp.name)
        raise ValueError("no ENTRY computation found")


def analyze_text(text: str) -> CostTotals:
    return HloCostModel(text).entry_cost()
