"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* first init.

Mesh axes:
  single-pod:  (16, 16)        -> ("data", "model")     = 256 chips (v5e pod)
  multi-pod:   (2, 16, 16)     -> ("pod", "data", "model") = 512 chips

The "data" axis is the Pangolin zone axis (parity groups of G=16); "pod"
carries cross-pod redo-log/metadata replication and (optionally) the
compressed-gradient exchange.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def _mk(shape, axes) -> Mesh:
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2, pod: int = 0) -> Mesh:
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def data_axis_size(mesh: Mesh) -> int:
    return mesh.shape["data"]
