"""Serving launcher: `PYTHONPATH=src python -m repro.launch.serve --arch <id>`.

Batched decode with Pangolin protection of the KV cache (the paper's
atomic-style small-update case: incremental checksums + parity patches).
"""
import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--data", type=int, default=4)
    ap.add_argument("--model", type=int, default=2)
    ap.add_argument("--protect", default="mlpc")
    ap.add_argument("--redundancy", type=int, default=1,
                    choices=[1, 2, 3, 4],
                    help="syndrome stack height r = rank losses survived "
                         "per zone: 1 = XOR parity, 2 adds the GF(2^32) "
                         "Q row, 3-4 add higher Vandermonde rows "
                         "(requires r <= data-axis size - 1)")
    ap.add_argument("--scrub-period", type=int, default=16)
    ap.add_argument("--window", type=int, default=1,
                    help="deferred-epoch window W for the KV cache "
                         "(1 = synchronous per-commit protection)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="async commit ring depth: decode commits "
                         "dispatch up to this many verdicts ahead of "
                         "resolution (1 = resolve per token)")
    ap.add_argument("--host-devices", type=int, default=8)
    ap.add_argument("--metrics-dir", default=None,
                    help="publish the pool's metric registry "
                         "(server.prom + server.stats.json) here every "
                         "--metrics-every decode steps")
    ap.add_argument("--metrics-every", type=int, default=100)
    ap.add_argument("--trace-dir", default=None,
                    help="append the pool's JSONL span trace "
                         "(server.trace.jsonl) here")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the live Prometheus scrape endpoint "
                         "(obs.serve_metrics) on this port for the run "
                         "(0 = OS-assigned; the bound port is printed)")
    args = ap.parse_args(argv)

    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.host_devices}")

    import time

    import jax
    from repro.configs.base import ProtectConfig
    from repro.configs.registry import get_config
    from repro.models.transformer import build_model
    from repro.runtime.server import Server

    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, mesh)
    params = model.init(jax.random.PRNGKey(0))
    srv = Server(cfg, ProtectConfig(mode=args.protect, block_words=256,
                                    scrub_period=args.scrub_period,
                                    redundancy=args.redundancy,
                                    window=args.window,
                                    pipeline_depth=args.pipeline_depth),
                 mesh, batch=args.batch,
                 max_len=args.prompt_len + args.new_tokens + 1,
                 metrics_dir=args.metrics_dir, trace_dir=args.trace_dir,
                 metrics_every=args.metrics_every)
    srv.start(params)
    scrape = None
    if args.metrics_port is not None and srv.pool is not None:
        from repro import obs
        scrape = obs.serve_metrics(srv.pool.metrics,
                                   port=args.metrics_port)
        print("metrics endpoint: "
              f"http://127.0.0.1:{scrape.server_address[1]}/metrics")
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = srv.generate(prompt, n_new=args.new_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    if srv.pool is not None:
        print("cache protection overhead:",
              srv.pool.overhead_report()["protection_fraction"])
        health = srv.pool.health()
        print(f"health: {health.status}"
              + (f" ({'; '.join(health.reasons)})"
                 if health.reasons else ""))
        if args.metrics_dir:
            from repro import obs
            paths = obs.write_metrics(srv.pool.metrics, args.metrics_dir,
                                      prefix="server",
                                      stats=srv.pool.stats())
            print(f"metrics: {paths['prom']}")
    if scrape is not None:
        scrape.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
