"""`Pool` — the Pangolin-style front door to the whole protection stack.

Pangolin's value proposition is a *small* persistent-object API
(`pgl_open` / `pgl_tx_begin` / `pgl_tx_commit`) that hides checksums,
parity, micro-buffering and recovery behind three calls.  This module is
that surface for the reproduction: one facade that owns the engine
choice (synchronous single-sweep vs deferred-epoch), the scrubber
pressure loop, window-meta replication, and every recovery path, so
callers never touch `Protector` / `DeferredProtector` / `Scrubber`
plumbing directly.

pgl -> Pool mapping (paper §3, Listing 2):

    ================  =============================================
    Pangolin          this library
    ================  =============================================
    pgl_open          Pool.open(state, specs, mesh=..., config=...)
    pgl_begin/commit  with pool.transaction() as tx: tx.stage(new)
                      (or pool.commit(new, ...) directly)
    pgl_tx_abort      canary mismatch / exception inside the context
    scrubbing thread  pool.maybe_scrub() on the commit cadence
                      (pool.scrub() forces one)
    SIGBUS handler    pool.recover(Fault.rank_loss(r))
    corruption repair pool.recover(Fault.scribble(rank, pages))
    (beyond paper)    pool.recover(Fault.multi_loss(*ranks)) — any
                      e <= redundancy simultaneous losses via the
                      Reed-Solomon syndrome stack
    pool resize       pool.rescale(new_mesh)
    ================  =============================================

Protection-mode ladder (paper Table 2), selected by `ProtectConfig`:
`none < ml < mlp < mlpc` plus `replica` (2x baseline); `redundancy`
r ∈ {1..4} stacks r Reed-Solomon syndromes onto the parity modes
(the legacy `mlp2`/`mlpc2` names alias redundancy=2).
`config.window` selects the engine: 1 = the
synchronous single-sweep commit, W>1 = the deferred-epoch engine whose
parity/checksum refresh amortizes over W commits.  The facade routes
both through the same jit caches as direct engine use, so a
`Pool`-routed commit is bit-identical to — and compiles the very same
program as — a hand-wired one (asserted in tests/test_pool.py).

`Protector` and `DeferredProtector` stay importable as the low-level
layer; `Pool` is the contract new subsystems plug into.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtectConfig
from repro.core import microbuffer
from repro.core import recovery as recovery_mod
from repro.core.epoch import DeferredProtector, EngineHost
from repro.core.pipeline import CommitRing, CommitTicket
from repro.core.scrub import ScrubReport, Scrubber
from repro.core.txn import Mode, ProtectedState, Protector
from repro.kernels import ops as kops
from repro.dist import elastic
from repro.dist.straggler import StragglerPolicy
from repro.obs import health as obs_health
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

PyTree = Any


def _is_abstract(state: PyTree) -> bool:
    leaves = jax.tree.leaves(state)
    return bool(leaves) and all(
        isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One recovery request — the argument to `Pool.recover`.

    Constructors mirror the failure taxonomy (runtime/failure.py):

        Fault.rank_loss(r)         one data-rank's row lost (media error)
        Fault.multi_loss(*ranks)   e ranks lost at once (needs
                                   redundancy >= e syndromes)
        Fault.double_loss(a, b)    the e=2 alias
        Fault.scribble(rank, pages) silent corruption at (rank, page)s
        Fault.from_event(event)    adapt a runtime FailureEvent
    """
    kind: str                                   # rank_loss | multi_loss
                                                # | scribble
    rank: Optional[int] = None                  # rank_loss
    ranks: Optional[Tuple[int, ...]] = None     # multi_loss
    locations: Optional[Tuple[Tuple[int, int], ...]] = None  # scribble

    @staticmethod
    def rank_loss(rank: int) -> "Fault":
        return Fault("rank_loss", rank=int(rank))

    @staticmethod
    def multi_loss(*ranks: int) -> "Fault":
        dead = tuple(sorted(int(r) for r in ranks))
        if len(set(dead)) != len(dead) or len(dead) < 2:
            raise ValueError(
                f"multi loss needs >= 2 distinct ranks, got {ranks}")
        return Fault("multi_loss", ranks=dead)

    @staticmethod
    def double_loss(a: int, b: int) -> "Fault":
        return Fault.multi_loss(a, b)

    @staticmethod
    def scribble(rank: int, pages: Sequence[int]) -> "Fault":
        return Fault("scribble",
                     locations=tuple((int(rank), int(p)) for p in pages))

    @classmethod
    def from_event(cls, event) -> "Fault":
        """Adapt a runtime/failure.py FailureEvent (duck-typed)."""
        if event.kind == "rank_loss":
            return cls.rank_loss(event.lost_rank)
        if event.kind in ("multi_loss", "double_loss"):
            return cls.multi_loss(*event.lost_ranks)
        if event.kind == "scribble":
            return cls("scribble",
                       locations=tuple((int(r), int(p))
                                       for r, p in event.locations))
        raise ValueError(f"no recovery path for fault kind {event.kind!r}")


class Transaction:
    """`pgl_tx_begin .. pgl_tx_commit` as a context manager.

    Stage the micro-buffered update with `stage(new_state)`; register
    canary-guarded staging buffers (microbuffer.guard/guard_nd) with
    `watch(...)`.  On exit the canaries are verified host-side and the
    staged state commits through the pool — a smashed canary (or an
    explicit `abort()`) aborts the transaction without touching
    protected state, exactly like `commit(..., canary_ok=False)`.  An
    exception inside the block also aborts (nothing is committed) and
    propagates.
    """

    def __init__(self, pool: "Pool", *, data_cursor=0, rng_key=None,
                 pages: Optional[Sequence[int]] = None):
        self._pool = pool
        self._data_cursor = data_cursor
        self._rng_key = rng_key
        # the page footprint declared at pool.transaction(pages=...) —
        # the merge-group conflict-check currency (None = whole state)
        self.pages = (None if pages is None
                      else tuple(int(p) for p in pages))
        self._staged: Optional[PyTree] = None
        self._commit_kw: dict = {}
        self._guarded: list = []          # (buffer, nd) pairs
        self._aborted = False
        self._ok = None                   # device bool after commit

    # -- staging ---------------------------------------------------------------

    def stage(self, new_state: PyTree, *, dirty_pages=None,
              dirty_words=None, verify_old: bool = False) -> None:
        """Stage the transaction's result (the micro-buffer contents)."""
        self._staged = new_state
        self._commit_kw = {"dirty_pages": dirty_pages,
                           "dirty_words": dirty_words,
                           "verify_old": verify_old}

    def watch(self, guarded: jax.Array, *, nd: bool = False) -> jax.Array:
        """Register a canary-guarded staging buffer for verification at
        commit; returns the buffer unchanged for chaining."""
        self._guarded.append((guarded, nd))
        return guarded

    def guard(self, row: jax.Array) -> jax.Array:
        """Append a canary page to a 1-D u32 staging buffer and watch it.

        Functional staging: if kernels produce a *new* buffer from this
        one, `watch` the final buffer too — the canary travels with it.
        """
        return self.watch(microbuffer.guard(row))

    def abort(self) -> None:
        """Abort explicitly: nothing commits when the block exits."""
        self._aborted = True

    # -- verdicts ---------------------------------------------------------------

    @property
    def canary_ok(self) -> bool:
        """Host verdict over every watched guard page (True if none)."""
        checks = [microbuffer.check_nd(b) if nd else microbuffer.check(b)
                  for b, nd in self._guarded]
        return all(bool(jax.device_get(c)) for c in checks)

    def canary_device(self) -> jax.Array:
        """The staged DEVICE verdict over every watched guard page — one
        unfetched bool scalar (`kernels.ops.stage_verdict`).  The async
        pipeline's canary form: feed it to
        `pool.commit_async(canary_ok=tx.canary_device())` and the abort
        select rides inside the commit program, so dispatch never blocks
        on the host the way `canary_ok` (a per-buffer device_get) does.
        """
        checks = [microbuffer.check_nd(b) if nd else microbuffer.check(b)
                  for b, nd in self._guarded]
        return kops.stage_verdict(checks)

    @property
    def aborted(self) -> bool:
        return self._aborted

    @property
    def ok(self) -> bool:
        """Did the commit land?  (Syncs on the commit program's verdict.)"""
        if self._aborted or self._ok is None:
            return False
        return bool(jax.device_get(self._ok))

    @property
    def committed(self) -> bool:
        """Alias of `ok` — True only when the commit actually landed,
        including device-side verdicts (a verify-at-open failure aborts
        on device after the host canary passed)."""
        return self.ok

    # -- context protocol -------------------------------------------------------

    def __enter__(self) -> "Transaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._aborted = True          # exception == pgl_tx_abort
            return False                  # propagate
        if self._staged is None:
            return False                  # nothing staged: a no-op tx
        canary_ok = (not self._aborted) and self.canary_ok
        self._ok = self._pool.commit(
            self._staged, data_cursor=self._data_cursor,
            rng_key=self._rng_key, canary_ok=canary_ok, **self._commit_kw)
        if not canary_ok:
            self._aborted = True
        return False


class Pool(EngineHost):
    """The single public entry point over one protected state layout.

    Construction wires the whole stack from `ProtectConfig` (the single
    source of truth for mode / redundancy / window / scrub cadence):
    the `Protector` for the zone layout, the `DeferredProtector` when
    `config.window > 1`, the `Scrubber` with its adaptive-window
    pressure loop, and window-meta replication for bulk engines.  The
    protected snapshot itself (`ProtectedState` vs `EpochState`) is an
    internal detail — callers see `pool.state` and `pool.step`.

    Low-level escape hatches (`pool.protector`, `pool.engine`,
    `pool.scrubber`) stay public for benchmarks and tests, but nothing
    outside pool.py should *construct* those classes for a protected
    runtime.
    """

    def __init__(self, mesh, abstract_state: PyTree, state_specs: PyTree,
                 config: Optional[ProtectConfig] = None, *,
                 data_axis: str = "data",
                 dirty_leaf_idx: Optional[Sequence[int]] = None,
                 dirty_capacity: Optional[int] = None,
                 donate: bool = True,
                 replicate_meta: Optional[bool] = None,
                 on_freeze: Optional[Callable] = None,
                 on_resume: Optional[Callable] = None,
                 straggler_policy: Optional[StragglerPolicy] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 protector: Optional[Protector] = None):
        self.config = config if config is not None else ProtectConfig()
        self.mesh = mesh
        self.abstract_state = abstract_state
        self.state_specs = state_specs
        self.donate = bool(donate)
        self.on_freeze = on_freeze
        self.on_resume = on_resume
        # telemetry plane (repro.obs) — every pool owns a registry and a
        # tracer; a caller-supplied pair survives rescale (threaded
        # through _open_kw below) so one campaign is one metric namespace
        # and one connected trace.  Publication is host-side only:
        # commit-path instrumentation is perf_counter + dict hits, never
        # a device fetch or a jit wrapper, so a wired pool compiles
        # byte-identical programs (benchmarks/obs_overhead.py asserts).
        self.metrics = (metrics if metrics is not None
                        else MetricsRegistry())
        self.tracer = tracer if tracer is not None else Tracer()
        self._open_kw = dict(data_axis=data_axis,
                             dirty_leaf_idx=dirty_leaf_idx,
                             dirty_capacity=dirty_capacity,
                             donate=donate, replicate_meta=replicate_meta,
                             straggler_policy=straggler_policy,
                             metrics=self.metrics, tracer=self.tracer)
        mode = self.config.resolved_mode
        if protector is not None:
            # cohort sharing (repro.tenancy): same-shape pools on the
            # same mesh+config hand in one Protector so they share its
            # layout and `_jit_cache` — N tenants compile each commit /
            # scrub / recovery program once, not N times.  The caller
            # owns the compatibility claim; the cheap invariants are
            # asserted.
            assert protector.mesh is mesh, \
                "shared protector must be built on this pool's mesh"
            assert protector.mode is mode and \
                protector.redundancy == self.config.resolved_redundancy, \
                "shared protector's mode/redundancy must match config"
            self.protector = protector
        else:
            self.protector = Protector(
                mesh, abstract_state, state_specs, data_axis=data_axis,
                mode=mode, redundancy=self.config.resolved_redundancy,
                block_words=self.config.block_words,
                hybrid_threshold=self.config.hybrid_threshold,
                log_capacity=self.config.log_capacity,
                stream_threshold_words=self.config.stream_threshold_words,
                stream_chunk_words=self.config.stream_chunk_words)
        self._due_scrubs = 0          # full_scrub_every cadence counter
        # footprint arguments may be callables of the built zone layout
        # (e.g. lambda lo: range(len(lo.slots))) so callers need not
        # construct the layout twice just to size the deferred engine.
        # _open_kw keeps the UNresolved forms: rescale re-resolves them
        # against the new mesh's layout (zone geometry changes with G).
        if callable(dirty_leaf_idx):
            dirty_leaf_idx = dirty_leaf_idx(self.protector.layout)
        if callable(dirty_capacity):
            dirty_capacity = dirty_capacity(self.protector.layout)
        self._engine: Optional[DeferredProtector] = None
        self._est = None
        self._prot: Optional[ProtectedState] = None
        if self.config.window > 1:
            # ProtectConfig.__post_init__ guarantees a parity/checksum
            # mode whenever window > 1, so the engine always exists here.
            # Bulk engines (whole state dirty per commit — training)
            # replicate the window's dirty mask + digest across the pod
            # so survivors of a mid-window loss can bound it; patch
            # engines (decode) default it off, matching the runtimes.
            if replicate_meta is None:
                replicate_meta = dirty_leaf_idx is None
            self._engine = DeferredProtector(
                self.protector, window=self.config.window,
                dirty_capacity=dirty_capacity,
                dirty_leaf_idx=dirty_leaf_idx, donate=donate,
                replicate_meta=replicate_meta)
        self.scrubber = Scrubber(
            self.protector, period=self.config.scrub_period,
            engine=self._engine,
            growth_commits=self.config.window_growth_commits)
        self.scrubber.metrics = self.metrics
        if self._engine is not None:
            self._engine.metrics = self.metrics
        r_armed = (self.protector.redundancy
                   if self.protector.mode.has_parity else 0)
        self.metrics.gauge("pool_window").set(
            self._engine.window if self._engine is not None else 1)
        self.metrics.gauge("pool_redundancy").set(r_armed)
        self.metrics.gauge("pool_budget_remaining").set(r_armed)
        # hot-path handles: commit() publishes through these cached
        # objects (no registry lookup per transaction)
        self._m_commits = self.metrics.counter("pool_commits_total")
        self._m_aborted = self.metrics.counter(
            "pool_commit_aborted_total")
        self._m_commit_ms = self.metrics.histogram(
            "pool_commit_dispatch_ms")
        # async commit pipeline (core/pipeline.py): the N-deep in-flight
        # ring behind commit_async; resolve latency carries the dispatch
        # span id as a histogram exemplar so a p99 sample links back to
        # its trace event
        self._m_resolve_ms = self.metrics.histogram(
            "pool_commit_resolve_ms")
        self._m_inflight = self.metrics.gauge("pool_inflight_depth")
        self._ring = CommitRing(self.config.pipeline_depth,
                                on_depth=self._m_inflight.set)
        self._ticket_seq = 0
        self._staged_sel = None       # cached jitted sync canary select
        # merged-window bookkeeping (lock-free dirty-union semantics):
        # the page-footprint union of every transaction opened since the
        # last flush; a conflicting footprint seals the group (drain +
        # flush) before the new transaction joins a fresh one, so
        # conflicting txns serialize and disjoint txns coalesce into ONE
        # telescoped flush
        self._merge_open = False
        self._merge_all = False
        self._merge_pages: set = set()
        self._m_txn_serialized = self.metrics.counter(
            "pool_txn_serialized_total")
        self._m_txn_coalesced = self.metrics.counter(
            "pool_txn_coalesced_total")
        # health bookkeeping (host flags; pool.health() folds these)
        self._n_recoveries = 0
        self._n_followups = 0
        self._suspect = False
        self._budget_exhausted = False
        self._last_reverify_ok: Optional[bool] = None
        self._unrepaired_pages = 0
        # fault ids noted (note_fault / inject) and not yet consumed by
        # the recovery/repair span that resolves them — the trace-linkage
        # currency (obs/trace.validate_events)
        self._open_fault_ids: list = []
        # straggler mitigation (ProtectConfig.straggler_threshold > 0):
        # the policy tracks per-replica commit-loop durations and drops
        # replicas past threshold x the fleet median; while ANY replica
        # is dropped the pool runs degraded — the adaptive window stays
        # collapsed at 1 so redundancy lag never piles up behind a rank
        # that cannot keep the flush cadence.  `straggler_policy`
        # overrides the default-built policy (tests/chaos tune the
        # observation window).
        self.straggler: Optional[StragglerPolicy] = None
        if straggler_policy is not None:
            self.straggler = straggler_policy
        elif self.config.straggler_threshold > 0:
            self.straggler = StragglerPolicy(
                self.protector.group_size,
                threshold=self.config.straggler_threshold)
        self._dropped: set = set()
        # async-safe recovery re-entry: faults arriving while a recovery
        # is already in flight (freeze/resume callbacks, chaos schedule
        # hooks) queue here and drain sequentially — never two
        # interleaved reconstructions over one pool
        self._recovering = False
        self._pending_faults: list = []
        self._arrival_fn: Optional[Callable] = None

    # -- open -------------------------------------------------------------------

    @classmethod
    def open(cls, state: PyTree, specs: PyTree, *, mesh,
             config: Optional[ProtectConfig] = None,
             **kw) -> "Pool":
        """The `pgl_open` analogue: protect `state` and return the pool.

        `state` may be concrete (protection is built immediately) or a
        ShapeDtypeStruct pytree (a *cold* pool: the layout and compiled
        programs exist, call `pool.init(state)` to attach real state —
        how the runtimes and the dry-run lowering use it).
        """
        abstract = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
        pool = cls(mesh, abstract, specs, config, **kw)
        if not _is_abstract(state):
            pool.init(state)
        return pool

    def init(self, state: PyTree) -> "Pool":
        """Build parity/checksums/row for `state` (fresh protection).

        Also the re-arm point after a budget-exhausted storm: fresh
        protection clears the exhaust/corruption health flags and
        restores the full syndrome budget.
        """
        # re-protection supersedes any commit still in flight: void the
        # tickets deterministically (verdict False, device untrusted)
        # rather than resolving against buffers the re-arm replaced
        self._ring.void_all()
        self.prot = self.protector.init(state)
        self._budget_exhausted = False
        self._unrepaired_pages = 0
        self._last_reverify_ok = None
        self._suspect = False
        self.metrics.gauge("pool_budget_remaining").set(
            self.protector.redundancy
            if self.protector.mode.has_parity else 0)
        return self

    # -- introspection ----------------------------------------------------------

    @property
    def mode(self) -> Mode:
        return self.protector.mode

    @property
    def redundancy(self) -> int:
        """Syndrome stack height r — simultaneous rank losses survived."""
        return self.protector.redundancy

    @property
    def engine(self) -> Optional[DeferredProtector]:
        """The deferred-epoch engine, or None on the synchronous cadence."""
        return self._engine

    @property
    def state(self) -> Optional[PyTree]:
        """The live protected state pytree."""
        prot = self.prot
        return None if prot is None else prot.state

    @property
    def step(self) -> int:
        """Committed transaction count (host value)."""
        return int(jax.device_get(self.prot.step))

    def overhead_report(self) -> dict:
        rep = self.protector.overhead_report()
        rep["window"] = (self._engine.window if self._engine is not None
                         else 1)
        return rep

    # -- telemetry surface -------------------------------------------------------

    def stats(self) -> dict:
        """One host-side snapshot of the pool's telemetry: commit
        counters and dispatch-wall summary, window state, exact scrub
        coverage, recovery history, degradation flags, and the full
        metric registry.  Never touches the device — poll it at any
        cadence (the step counter stays a device value; fetch
        `pool.step` explicitly when you want it)."""
        eng = self._engine
        return {
            "mode": self.mode.value,
            "redundancy": self.redundancy,
            "engine": "deferred" if eng is not None else "sync",
            "window": eng.window if eng is not None else 1,
            "max_window": eng.max_window if eng is not None else 1,
            "commits": int(self._m_commits.value),
            "aborted_commits": int(self._m_aborted.value),
            "commit_dispatch_ms": self._m_commit_ms.summary(),
            "pipeline_depth": self.config.pipeline_depth,
            "in_flight": len(self._ring),
            "commit_resolve_ms": self._m_resolve_ms.summary(),
            "scrub": self.scrubber.coverage(),
            "recoveries": self._n_recoveries,
            "recovery_followups": self._n_followups,
            "dropped_replicas": self.dropped_replicas,
            "suspect": self._suspect,
            "budget_exhausted": self._budget_exhausted,
            "metrics": self.metrics.snapshot(),
        }

    def health(self) -> obs_health.HealthReport:
        """Green / degraded / critical with named reasons — host state
        only, see obs/health.py for the exact semantics."""
        eng = self._engine
        return obs_health.assess(
            window=eng.window if eng is not None else 1,
            max_window=eng.max_window if eng is not None else 1,
            dropped_replicas=self._dropped,
            suspect=self._suspect,
            redundancy=(self.redundancy
                        if self.mode.has_parity else 0),
            budget_exhausted=self._budget_exhausted,
            scrub_coverage=self.scrubber.coverage(),
            unrepaired_pages=self._unrepaired_pages,
            reverify_failed=self._last_reverify_ok is False,
            recoveries=self._n_recoveries,
            recovery_followups=self._n_followups)

    def commit_program(self, *, dirty_pages=None, verify_old: bool = False):
        """The compiled synchronous-commit program the facade routes
        through (for benchmarks asserting facade == direct bytes)."""
        return self.protector.commit_program(
            dirty_pages=dirty_pages, verify_old=verify_old,
            donate=self.donate)

    # -- commit -----------------------------------------------------------------

    def commit(self, state_new: PyTree, *, dirty_pages=None,
               dirty_words=None, data_cursor=0, rng_key=None,
               canary_ok: bool = True, verify_old: bool = False):
        """One transactional update; returns the commit verdict (device
        bool — fetch it lazily to keep protection off the critical
        path).

        Routing is the facade's whole job: the deferred engine takes
        `dirty_words` (per-leaf word indices, position-independent
        shapes) and ignores `dirty_pages` — its page footprint is the
        static `dirty_leaf_idx` from construction; the synchronous
        engine takes `dirty_pages` (a static page set keying its own
        compiled commit).  Callers pass whichever they know; the pool
        feeds the right one to the engine it built.
        """
        assert self.prot is not None, "Pool.commit before init()"
        t0 = time.perf_counter()
        if self._engine is not None:
            assert not verify_old, \
                "verify_old is a synchronous-engine feature (window=1)"
            self._est, ok = self._engine.commit(
                self._est, state_new, dirty_words=dirty_words,
                data_cursor=data_cursor, rng_key=rng_key,
                canary_ok=canary_ok)
        else:
            self._prot, ok = self.protector.commit(
                self._prot, state_new, dirty_pages=dirty_pages,
                verify_old=verify_old, donate=self.donate,
                data_cursor=data_cursor, rng_key=rng_key,
                canary_ok=canary_ok)
            if self._arrival_fn is not None:
                # synchronous cadence: every commit is its own window
                # boundary, so the arrival point is right after it
                new = self._arrival_fn(self._prot, 1, True)
                if new is not None:
                    self._prot = new
        # the scrub cadence + clean-streak window growth ride on the
        # host-known canary verdict (no device sync on the hot path)
        self.scrubber.on_commit(clean=bool(canary_ok))
        # telemetry: the observed wall is DISPATCH wall — commits return
        # a device verdict unfetched, so this measures the host cost of
        # launching the program, which is exactly what instrumentation
        # could perturb (the device-side cost is the benchmarks' job)
        self._m_commits.inc()
        if not canary_ok:
            self._m_aborted.inc()
        self._m_commit_ms.observe((time.perf_counter() - t0) * 1e3)
        return ok

    # -- async commit pipeline ---------------------------------------------------

    def _staged_sel_fn(self):
        """Cached jitted select for the synchronous engine's device
        canary: `Protector.commit` keys its canary statically (the jit
        cache's `static_argnames`), so a traced verdict cannot ride the
        existing program — instead the all-clear commit runs without
        donation and this select gates the WHOLE new protected state on
        the device canary per leaf.  A False canary yields the old state
        bit-identically (the static abort path's result)."""
        if self._staged_sel is None:
            def _sel(v, ok, new, old):
                v = jnp.asarray(v, bool).reshape(())
                sel = jax.tree.map(lambda n, o: jnp.where(v, n, o),
                                   new, old)
                return sel, jnp.logical_and(v, ok)
            self._staged_sel = jax.jit(_sel)
        return self._staged_sel

    def commit_async(self, state_new: PyTree, *, dirty_pages=None,
                     dirty_words=None, data_cursor=0, rng_key=None,
                     canary_ok=True, verify_old: bool = False,
                     extras: Optional[dict] = None) -> CommitTicket:
        """One transactional update as a future: dispatches the commit
        and returns a `CommitTicket` carrying the UNfetched device
        verdict, the dispatch timestamp, and the trace span id.  Up to
        `ProtectConfig.pipeline_depth` tickets stay in flight (the ring
        force-resolves the oldest past that); tickets resolve as their
        device scalars land — `ticket.result()`, `pool.poll()` out of
        dispatch order, or `pool.drain()` at a boundary.

        `canary_ok` accepts either the host bool the synchronous
        `commit` takes, or an UNfetched device bool (e.g.
        `tx.canary_device()` / `kernels.ops.stage_verdict`) — the
        staged form: the abort select rides inside the program, the
        verdict can't be host-known at dispatch, and abort bookkeeping
        (abort counter, scrub clean-streak) defers to resolution.
        Routing (`dirty_pages` vs `dirty_words`) matches `commit`.
        """
        assert self.prot is not None, "Pool.commit_async before init()"
        t0 = time.perf_counter()
        staged = not isinstance(canary_ok, (bool, np.bool_))
        if self._engine is not None:
            assert not verify_old, \
                "verify_old is a synchronous-engine feature (window=1)"
            if staged:
                self._est, ok = self._engine.commit_staged(
                    self._est, state_new, canary=canary_ok,
                    dirty_words=dirty_words, data_cursor=data_cursor,
                    rng_key=rng_key)
            else:
                self._est, ok = self._engine.commit(
                    self._est, state_new, dirty_words=dirty_words,
                    data_cursor=data_cursor, rng_key=rng_key,
                    canary_ok=bool(canary_ok))
        else:
            if staged:
                # no donation: the old state is the select's False arm
                prot_old = self._prot
                prot_new, ok_c = self.protector.commit(
                    prot_old, state_new, dirty_pages=dirty_pages,
                    verify_old=verify_old, donate=False,
                    data_cursor=data_cursor, rng_key=rng_key,
                    canary_ok=True)
                self._prot, ok = self._staged_sel_fn()(
                    canary_ok, ok_c, prot_new, prot_old)
            else:
                self._prot, ok = self.protector.commit(
                    self._prot, state_new, dirty_pages=dirty_pages,
                    verify_old=verify_old, donate=self.donate,
                    data_cursor=data_cursor, rng_key=rng_key,
                    canary_ok=bool(canary_ok))
            if self._arrival_fn is not None:
                new = self._arrival_fn(self._prot, 1, True)
                if new is not None:
                    self._prot = new
        seq = self._ticket_seq
        self._ticket_seq += 1
        span_id = self.tracer.emit("commit_dispatch", seq=seq,
                                   staged=bool(staged))
        if not staged:
            # host-known canary: dispatch-time bookkeeping identical to
            # the synchronous commit path
            self.scrubber.on_commit(clean=bool(canary_ok))
            if not canary_ok:
                self._m_aborted.inc()
        self._m_commits.inc()
        self._m_commit_ms.observe((time.perf_counter() - t0) * 1e3)
        return self._ring.submit(CommitTicket(
            seq, ok, dispatched_at=t0, span_id=span_id, extras=extras,
            staged=staged, on_resolve=self._on_ticket_resolved))

    def _on_ticket_resolved(self, ticket: CommitTicket) -> None:
        """Resolution bookkeeping (fires exactly once per ticket): the
        resolve-latency histogram carries the ticket's trace span id as
        an exemplar, and staged canaries settle their abort accounting
        now that the verdict is host-known."""
        lat = ticket.resolve_latency_ms
        if lat is not None:
            self._m_resolve_ms.observe(lat, exemplar=ticket.span_id)
        if ticket.staged:
            v = bool(ticket.result())
            self.scrubber.on_commit(clean=v)
            if not v:
                self._m_aborted.inc()

    def poll(self) -> list:
        """Resolve any in-flight tickets whose device verdicts already
        landed (out of dispatch order); returns them."""
        return self._ring.poll()

    def drain(self) -> list:
        """Resolve EVERY in-flight ticket (dispatch order) — the
        deterministic pipeline boundary.  `flush`, scrub, recovery and
        rescale all drain first, so a pipeline interrupted anywhere
        lands exactly where synchronous resolution would."""
        return self._ring.drain()

    @property
    def in_flight(self) -> int:
        """Unresolved commit tickets currently in the ring."""
        return len(self._ring)

    def flush(self) -> None:
        """Bring deferred redundancy current (no-op when synchronous);
        resolves the commit pipeline first and closes any open
        transaction merge group — a flush is the deterministic boundary
        every coalesced window telescopes into."""
        self.drain()
        if self._engine is not None and self._est is not None:
            self._est = self._engine.flush_if_pending(self._est)
        self._merge_open = False
        self._merge_all = False
        self._merge_pages = set()

    # -- transactions (merged-window protocol) -----------------------------------

    def _enter_footprint(self, pages) -> bool:
        """The page-granular conflict check at `transaction()` entry
        (lock-free dirty-union semantics): a footprint disjoint from the
        open merge group joins it — its commits coalesce into the SAME
        deferred window, one telescoped flush for all of them; a
        conflicting footprint (overlap, or either side whole-state)
        seals the group first (drain + flush), so conflicting
        transactions serialize across windows.  Returns True when this
        entry serialized."""
        whole = pages is None
        fp = set() if whole else set(int(p) for p in pages)
        if not self._merge_open:
            self._merge_open = True
            self._merge_all = whole
            self._merge_pages = fp
            return False
        conflict = self._merge_all or whole or bool(
            self._merge_pages & fp)
        if conflict:
            self._m_txn_serialized.inc()
            self.flush()              # seal: drain + telescoped flush
            self._merge_open = True
            self._merge_all = whole
            self._merge_pages = fp
            return True
        self._m_txn_coalesced.inc()
        self._merge_pages |= fp
        return False

    def transaction(self, *, data_cursor=0, rng_key=None,
                    pages: Optional[Sequence[int]] = None) -> Transaction:
        """`pgl_tx_begin`: returns the staging context manager.

        `pages` declares the transaction's page footprint for the
        merged-window protocol (`_enter_footprint`): concurrent open
        transactions with DISJOINT footprints coalesce into one deferred
        window (one telescoped flush); overlapping footprints — or any
        transaction that declares none (None = whole state) — serialize
        behind a seal.  Omitting `pages` preserves the classic
        serial-transaction behavior exactly.
        """
        self._enter_footprint(pages)
        return Transaction(self, data_cursor=data_cursor,
                           rng_key=rng_key, pages=pages)

    # -- fault-arrival hook (chaos harness) -------------------------------------

    def set_arrival_hook(self, fn: Optional[Callable]) -> None:
        """Register `fn(prot, since, at_boundary) -> Optional[ProtectedState]`
        at the commit loop's fault-arrival point.

        Deferred engine: the hook fires inside `commit`, between
        in-window commits and BEFORE any boundary flush (the
        `DeferredProtector.arrival_hook` point) — a returned
        ProtectedState replaces the window's, modeling corruption landing
        concurrent with traffic.  Synchronous engine: the hook fires
        right after each commit (every commit is its own boundary).
        Pass None to clear.
        """
        self._arrival_fn = fn
        if self._engine is not None:
            if fn is None:
                self._engine.arrival_hook = None
            else:
                def _hook(est, since, at_boundary):
                    new = fn(est.prot, since, at_boundary)
                    return (None if new is None
                            else dataclasses.replace(est, prot=new))
                self._engine.arrival_hook = _hook

    def note_fault(self, kind: str, **fields) -> int:
        """Record a fault's arrival in the telemetry plane; returns the
        trace id.  The id stays "open" until the next recovery (or
        repairing scrub) span consumes it into its `faults` list — the
        linkage `validate_events` / scripts/trace_check.py enforce.
        Injectors routed through `inject` are noted automatically; a
        harness that corrupts state by other means (e.g. an arrival-hook
        scribble inside an open window) must call this itself so the
        trace stays connected.
        """
        self.metrics.counter("pool_faults_total", kind=str(kind)).inc()
        fid = self.tracer.emit("fault", fault_kind=str(kind), **fields)
        self._open_fault_ids.append(fid)
        return fid

    def note_event(self, event) -> int:
        """`note_fault` from a FailureEvent (duck-typed) — what a
        harness calls when it corrupted state without going through
        `inject` (e.g. inside an arrival hook)."""
        fields = {}
        if getattr(event, "lost_rank", None) is not None:
            fields["lost_rank"] = int(event.lost_rank)
        if getattr(event, "lost_ranks", None):
            fields["lost_ranks"] = [int(r) for r in event.lost_ranks]
        if getattr(event, "locations", None):
            fields["pages"] = [[int(r), int(p)]
                               for r, p in event.locations]
        return self.note_fault(getattr(event, "kind", "inject"),
                               **fields)

    def set_tracer(self, tracer: Tracer) -> None:
        """Swap the trace sink (e.g. for a file-backed tracer after the
        pool was built) — threaded through `_open_kw` so pools built by
        `rescale` keep emitting into the new sink."""
        self.tracer = tracer
        self._open_kw["tracer"] = tracer

    def inject(self, fn: Callable):
        """Apply a failure injector `fn(protector, prot) -> (prot, event)`
        to the live protected state IN PLACE, preserving any open
        window's bookkeeping (the `prot` setter would wrap a fresh
        window, silently discarding the accumulator a later flush
        needs).  Returns the injector's FailureEvent — the chaos
        harness's between-commit corruption point.  The event is noted
        as a fault in the trace (see `note_fault`).
        """
        assert self.prot is not None, "Pool.inject before init()"
        new_prot, event = fn(self.protector, self.prot)
        if self._engine is not None:
            self._est = dataclasses.replace(self._est, prot=new_prot)
        else:
            self._prot = new_prot
        self.note_event(event)
        return event

    # -- straggler degradation path ---------------------------------------------

    @property
    def dropped_replicas(self) -> list:
        """Data ranks currently dropped by the straggler policy."""
        return sorted(self._dropped)

    def observe_commit_times(self, durations) -> np.ndarray:
        """Feed per-replica commit-loop durations (seconds, one entry per
        data rank) into the straggler policy; returns the participation
        mask.

        This is the pool-side degradation path: while any replica is
        dropped the deferred window is held collapsed at 1 (each
        observation re-collapses it, so clean-commit growth cannot
        outpace a live straggler) and the scrub clean-streak resets —
        the pool runs on the synchronous cadence until the fleet is
        healthy again, then the adaptive window regrows through the
        usual clean-scrub / clean-commit signals.
        """
        assert self.straggler is not None, (
            "no straggler policy on this pool — set "
            "ProtectConfig.straggler_threshold > 0 (or pass "
            "straggler_policy=) to enable mitigation")
        for rank, dur in enumerate(durations):
            self.straggler.observe(rank, float(dur))
        mask = self.straggler.replica_mask()
        before = self._dropped
        self._dropped = set(int(r) for r in np.flatnonzero(~mask))
        if self._dropped:
            if self._engine is not None:
                self._engine.report_pressure(True)
            self.scrubber.note_suspect()
        newly, healed = self._dropped - before, before - self._dropped
        if newly:
            self.metrics.counter(
                "pool_straggler_drop_total").inc(len(newly))
            self.tracer.emit("straggler_drop",
                             replicas=sorted(int(r) for r in newly))
        if healed:
            self.metrics.counter(
                "pool_straggler_heal_total").inc(len(healed))
        self.metrics.gauge("pool_dropped_replicas").set(
            len(self._dropped))
        return mask

    # -- scrub ------------------------------------------------------------------

    def scrub(self) -> ScrubReport:
        """Force one global scrub (flushing any open window first);
        repairs detected scribbles in place and feeds the adaptive
        window."""
        assert self.prot is not None
        self.flush()                 # scrub must see current redundancy
        with self.tracer.span("scrub", scope="full") as span:
            prot, report = self.scrubber.run(
                self.prot, freeze=self._freeze, resume=self._resume)
            self.prot = prot
            span.annotate(suspect=bool(report.suspect),
                          bad_pages=len(report.bad_locations),
                          repaired=bool(report.repaired))
            # a scrub whose repair actually fixed pages resolves any
            # open fault ids — link them here exactly like a recovery
            # span would (note_fault docs the contract)
            if report.repaired and self._open_fault_ids:
                fault_ids, self._open_fault_ids = \
                    self._open_fault_ids, []
                span.annotate(faults=fault_ids)
        self._fold_scrub_health(report)
        repaired_ok = report.repaired and bool(report.repair_ok)
        if report.bad_locations and not repaired_ok:
            self._unrepaired_pages = len(report.bad_locations)
        else:
            self._unrepaired_pages = 0
        return report

    def precheck(self) -> ScrubReport:
        """The rank-local syndrome scrub (flushing any open window
        first): state blocks vs checksums, row-cache coherence, and the
        folded-syndrome compare — no full-row collective."""
        assert self.prot is not None
        self.flush()
        with self.tracer.span("scrub", scope="precheck") as span:
            report = self.scrubber.precheck(self.prot)
            span.annotate(suspect=bool(report.suspect))
        self._fold_scrub_health(report)
        return report

    def _fold_scrub_health(self, report: ScrubReport) -> None:
        """Scrub verdict -> health flags: suspicion follows the latest
        checked pass (clean clears it, symmetric with the adaptive
        window's pressure loop); a clean pass also retires a stale
        reverify-failed flag (the residual corruption it warned about
        no longer exists)."""
        if not report.checked:
            return
        self._suspect = bool(report.suspect)
        if not report.suspect:
            self._last_reverify_ok = None

    def maybe_scrub(self) -> Optional[ScrubReport]:
        """Run a scrub iff the cadence says one is due.

        With `config.full_scrub_every = N > 1`, a due scrub first runs
        the rank-local pre-check; only every Nth due scrub — or any
        pre-check that flags the pool suspect — pays for the global
        syndrome collectives (and their repair path).  N = 1 keeps the
        classic always-global cadence.
        """
        if not self.scrubber.due():
            return None
        n = self.config.full_scrub_every
        self._due_scrubs += 1
        if n > 1 and self._due_scrubs % n:
            report = self.precheck()
            if not report.suspect:
                # clean local pass counts toward the cadence; the next
                # global scrub still lands on the full_scrub_every beat
                self.scrubber.mark_checked()
                return report
        return self.scrub()

    # -- recovery ---------------------------------------------------------------

    def recover(self, fault: Fault, *,
                reverify: bool = True
                ) -> Optional[recovery_mod.RecoveryReport]:
        """One recovery path for every fault (the SIGBUS-handler
        analogue).  Flushes any open window first — the cached row is a
        separate buffer the fault never touched, so the flushed
        redundancy describes intended values and online reconstruction
        proceeds exactly as in the synchronous engine.  Stacks with
        redundancy >= e additionally solve `Fault.multi_loss` of e
        ranks; e > r raises the budget-exhausted error (naming the dead
        ranks and the available r) instead of attempting a solve the
        stack cannot carry.  After recovery
        the deferred window collapses toward 1 (failure suspicion) and,
        when window metadata was replicated, the report carries the
        survivors' window bound.

        `reverify=True` (default) re-runs the full syndrome/checksum
        verification AFTER reconstruction — `report.synd_ok` carries the
        per-syndrome verdicts and `report.reverified` the overall one,
        so residual corruption (a scribble outstanding elsewhere while a
        rank was being rebuilt) is surfaced instead of trusted.

        Re-entry is async-safe: a fault arriving while a recovery is
        already in flight (from a freeze/resume callback or a chaos
        schedule hook) is queued and drained sequentially after the
        running reconstruction completes — that call returns None and
        the outer call's report counts it in `followups`.
        """
        assert self.prot is not None
        if not isinstance(fault, Fault):
            fault = Fault.from_event(fault)   # accept raw FailureEvents
        if self._recovering:
            self._pending_faults.append((fault, time.perf_counter()))
            self.metrics.counter("pool_recovery_queued_total").inc()
            return None
        self._recovering = True
        try:
            rep = self._recover_one(fault, reverify=reverify)
            drained = 0
            while self._pending_faults:
                qfault, t_enq = self._pending_faults.pop(0)
                self._recover_one(
                    qfault, reverify=reverify,
                    queue_wait_ms=(time.perf_counter() - t_enq) * 1e3)
                drained += 1
            rep.followups = drained
            self._n_followups += drained
            return rep
        finally:
            self._recovering = False
            self._pending_faults.clear()

    def _recover_one(self, fault: Fault, *, reverify: bool,
                     queue_wait_ms: Optional[float] = None
                     ) -> recovery_mod.RecoveryReport:
        t_total = time.perf_counter()
        # consume every fault id noted since the last resolving span:
        # THIS recovery is what resolves them (a drained follow-up grabs
        # ids noted while the outer recovery ran, so the linkage stays
        # exact across the re-entry queue)
        fault_ids, self._open_fault_ids = self._open_fault_ids, []
        with self.tracer.span("recovery", fault_kind=fault.kind,
                              faults=fault_ids) as span:
            if fault.kind == "multi_loss":
                # refuse an over-budget solve up front, before the flush
                # touches anything — the actionable form of "e > r".
                # The health surface latches critical here (cleared by
                # the pool.init re-arm) and the span ends with the error
                # attached, still linking its fault ids.
                e = len(fault.ranks)
                r = (self.protector.redundancy
                     if self.protector.mode.has_parity else 0)
                if e > r:
                    self._budget_exhausted = True
                    self.metrics.counter(
                        "pool_budget_exhausted_total").inc()
                    self.metrics.gauge("pool_budget_remaining").set(0)
                    raise RuntimeError(
                        f"syndrome budget exhausted: ranks "
                        f"{list(fault.ranks)} are lost simultaneously "
                        f"(e={e}) but this pool holds redundancy={r} "
                        "syndrome row(s) — at most r losses solve "
                        "online.  Restore from the checkpoint + "
                        "redo-log tier and re-arm the stack by "
                        "re-protecting (pool.init), or raise "
                        f"ProtectConfig.redundancy>={e} (<= 4) before "
                        "the next storm")
            # survivors' copy of the window metadata, captured BEFORE
            # the flush mutates the window
            meta = (self._engine.window_meta
                    if self._engine is not None else None)
            self.flush()
            if fault.kind == "rank_loss":
                prot, rep = recovery_mod.recover_from_rank_loss(
                    self.protector, self.prot, fault.rank,
                    freeze=self._freeze, resume=self._resume)
            elif fault.kind == "multi_loss":
                prot, rep = recovery_mod.recover_from_e_loss(
                    self.protector, self.prot, fault.ranks,
                    freeze=self._freeze, resume=self._resume)
            elif fault.kind == "scribble":
                prot, rep = recovery_mod.recover_from_scribble(
                    self.protector, self.prot, fault.locations,
                    freeze=self._freeze, resume=self._resume)
            else:
                raise ValueError(
                    f"no recovery path for fault {fault.kind!r}")
            self.prot = prot
            if reverify:
                t_rv = time.perf_counter()
                self._reverify(rep)
                rep.reverify_ms = (time.perf_counter() - t_rv) * 1e3
            if self._engine is not None:
                self._engine.report_pressure(True)
                self.scrubber.note_suspect()
                if meta is not None:
                    rep.window_bound = {
                        "pending": meta["pending"],
                        "dirty_pages": meta["dirty_pages"],
                        "digest_verified":
                            self._engine.verify_window_bound(self._est),
                    }
            rep.queue_wait_ms = queue_wait_ms
            rep.total_ms = (time.perf_counter() - t_total) * 1e3
            self._publish_recovery(rep)
            ev = rep.to_event()
            # the span's own `kind` ("recovery") wins; the report's kind
            # (rank_loss/multi_loss/scribble) rides as recovery_kind
            ev["recovery_kind"] = ev.pop("kind")
            span.annotate(**ev)
            return rep

    def _publish_recovery(self,
                          rep: recovery_mod.RecoveryReport) -> None:
        self._suspect = True                  # until the next clean scrub
        self._n_recoveries += 1
        self._last_reverify_ok = rep.reverified
        reg = self.metrics
        reg.counter("pool_recoveries_total", kind=rep.kind).inc()
        for name, v in (("pool_recovery_solve_ms", rep.solve_ms),
                        ("pool_recovery_reverify_ms", rep.reverify_ms),
                        ("pool_recovery_queue_wait_ms",
                         rep.queue_wait_ms),
                        ("pool_recovery_total_ms", rep.total_ms)):
            if v is not None:
                reg.histogram(name).observe(v)
        if rep.reverified is False:
            reg.counter("pool_reverify_failed_total").inc()

    def _reverify(self, rep: recovery_mod.RecoveryReport) -> None:
        """Re-run verify_syndromes (+ checksums + row cache) after a
        reconstruction; folds the verdict into the report."""
        mode = self.protector.mode
        if not (mode.has_parity or mode.has_cksums):
            return
        out = jax.device_get(self.protector.scrub(self.prot))
        ok = True
        if "synd_ok" in out:
            rep.synd_ok = [bool(v) for v in np.asarray(out["synd_ok"])]
            ok = ok and all(rep.synd_ok)
        if "bad_pages" in out:
            ok = ok and not bool(np.asarray(out["bad_pages"]).any())
        if "row_cache_ok" in out:
            ok = ok and bool(out["row_cache_ok"])
        rep.reverified = ok
        rep.verified = bool(rep.verified) and ok

    # -- rescale ----------------------------------------------------------------

    def rescale(self, new_mesh, *, into: Optional["Pool"] = None) -> "Pool":
        """Move the pool to `new_mesh` (elastic resize), returning the
        new pool.

        Flush-before-rescale lands any open window, then the state
        reshards bit-exactly through the host and protection is rebuilt
        for the new zone geometry (G changes the row padding, the
        page->owner map, and every syndrome's Vandermonde coefficients
        g^(k·i), so no plane of the stack can move with the state).
        `into`
        reuses a cold pool already built for the new mesh (a runtime's
        own); otherwise a fresh pool with this one's config is built.
        """
        assert self.prot is not None
        self.flush()
        with self.tracer.span("rescale") as span:
            if into is None:
                # _open_kw carries metrics= and tracer=, so the new pool
                # publishes into this one's registry and trace — one
                # campaign stays one metric namespace across resizes
                into = Pool(new_mesh, self.abstract_state,
                            self.state_specs, self.config,
                            **self._open_kw)
            # elastic.rescale owns the reshard -> rebuild -> step-carry
            # sequence; the facade adds flush-before-rescale and wiring
            _, prot_new = elastic.rescale(
                self.protector, self.prot, lambda _m: into.protector,
                new_mesh)
            into.prot = prot_new
            span.annotate(
                groups=(self.protector.group_size,
                        into.protector.group_size))
        self.metrics.counter("pool_rescales_total").inc()
        return into

    # -- freeze/resume hooks ----------------------------------------------------

    def _freeze(self):
        """Paper's pool freeze: drain outstanding work before repair."""
        if self.on_freeze is not None:
            self.on_freeze()
        elif self.prot is not None:
            jax.block_until_ready(jax.tree.leaves(self.prot.state)[0])

    def _resume(self):
        if self.on_resume is not None:
            self.on_resume()


class PoolHost:
    """Mixin for runtimes that own `self.pool` (possibly None — an
    unprotected runtime).  Delegates the low-level handles tests and
    benchmarks poke (`protector`, `scrubber`, `prot`, `_engine`,
    `_est`) plus `flush()`, so every host exposes the same surface
    without re-implementing the shim."""

    pool: Optional[Pool] = None

    @property
    def protector(self):
        return self.pool.protector if self.pool is not None else None

    @property
    def scrubber(self):
        return self.pool.scrubber if self.pool is not None else None

    @property
    def prot(self):
        return self.pool.prot if self.pool is not None else None

    @prot.setter
    def prot(self, value):
        if self.pool is not None:
            self.pool.prot = value
        else:
            assert value is None, "unprotected host holds no prot"

    @property
    def _engine(self):
        return self.pool.engine if self.pool is not None else None

    @property
    def _est(self):
        return self.pool._est if self.pool is not None else None

    @_est.setter
    def _est(self, value):
        self.pool._est = value

    def flush(self) -> None:
        """Bring deferred redundancy current (no-op when synchronous)."""
        if self.pool is not None:
            self.pool.flush()
