from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, adafactor, build_optimizer, clip_by_global_norm,
    cosine_schedule)
