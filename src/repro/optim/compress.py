"""Cross-pod gradient compression with error feedback.

Between pods, the data-center interconnect is the scarcest link.  When
`TrainConfig.grad_compression` is on, batches shard only *within* a pod
(rule override), so autodiff's gradient psum covers the in-pod data axis
only; the cross-pod combine is then explicit and quantized:

    q  = int8(round((g + ef) / scale)),  scale = max|g + ef| / 127
    g' = mean_pods(dequant(q));          ef' = (g + ef) - dequant(q)

Error feedback keeps the quantization bias from accumulating (standard
EF-SGD result); wire traffic across pods drops 2x vs bf16 / 4x vs f32.
Implemented as a shard_map over the full mesh operating on each leaf's
local shard with a ppermute exchange across the pod axis.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

PyTree = Any


def _quantize(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _leaf_crosspod_mean(g: jax.Array, ef: jax.Array, axis: str):
    """One leaf: quantized all-reduce-mean across `axis` + error feedback."""
    n = lax.axis_size(axis)
    xf = g.astype(jnp.float32) + ef
    q, scale = _quantize(xf)
    ef_new = xf - _dequantize(q, scale)
    # exchange: rotate quantized payloads around the pod ring, accumulating
    # dequantized values (n is small — 2..8 pods)
    acc = _dequantize(q, scale)
    perm = [(i, (i + 1) % n) for i in range(n)]
    q_r, s_r = q, scale
    for _ in range(n - 1):
        q_r = lax.ppermute(q_r, axis, perm)
        s_r = lax.ppermute(s_r, axis, perm)
        acc = acc + _dequantize(q_r, s_r)
    return (acc / n).astype(g.dtype), ef_new.astype(ef.dtype)


def make_crosspod_compressed_mean(mesh, grad_specs: PyTree,
                                  pod_axis: str = "pod"):
    """Returns f(grads, ef) -> (mean grads, new ef), shard_mapped."""

    def _fn(grads, ef):
        return jax.tree.map(
            lambda g, e: _leaf_crosspod_mean(g, e, pod_axis), grads, ef)

    def split(out):
        g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return g, e

    smapped = shard_map(_fn, mesh=mesh, in_specs=(grad_specs, grad_specs),
                        out_specs=jax.tree.map(
                            lambda s: (s, s), grad_specs,
                            is_leaf=lambda x: isinstance(x, P)),
                        check_vma=False)

    def apply(grads, ef):
        return split(smapped(grads, ef))

    return apply


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
