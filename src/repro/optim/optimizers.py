"""Sharded functional optimizers: AdamW and Adafactor.

Moments inherit the parameter's sharding (the Protector protects them as
ordinary zone objects).  Dtype policy: `moment_dtype` lets very large models
(llama4-400b) hold m/v in bf16 so total optimizer state fits HBM; the update
math always runs in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]      # (grads, opt_state, params, step) -> (new_params, new_opt_state)
    state_specs: Callable[[PyTree], PyTree]  # param specs -> opt-state specs


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads: PyTree, max_norm: float):
    gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          moment_dtype: Optional[str] = None) -> Optimizer:
    def init(params):
        def zeros_like_m(p):
            dt = jnp.dtype(moment_dtype) if moment_dtype else p.dtype
            return jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros_like_m, params),
                "v": jax.tree.map(zeros_like_m, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(stepf)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / bc1
            vhat = vf / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
                p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init=init, update=update, state_specs=state_specs)


def adafactor(lr_fn, eps: float = 1e-30, decay: float = 0.8,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments: O(n+m) state for an (n, m) matrix — the
    memory-efficient option for the 400B-class configs."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def mk(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(mk, params)

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(stepf)
        beta = 1.0 - stepf ** (-decay)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None],
                                       eps))
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                news = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd_ = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                news = {"v": v}
            # update clipping (RMS <= 1), Adafactor-style
            rms = jnp.sqrt(jnp.mean(upd_ ** 2))
            upd_ = upd_ / jnp.maximum(1.0, rms)
            newp = (p.astype(jnp.float32) - lr *
                    (upd_ + weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), news

        out = jax.tree.map(upd, grads, state, params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("v" in x or "vr" in x))
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, new_state

    def state_specs(param_specs):
        # factored moments drop the last / second-to-last axis of the spec
        from jax.sharding import PartitionSpec as P

        def mk(spec):
            parts = tuple(spec)
            if len(parts) >= 2:
                return {"vr": P(*parts[:-1]),
                        "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": spec}
        return jax.tree.map(mk, param_specs,
                            is_leaf=lambda x: isinstance(x, P))

    return Optimizer(init=init, update=update, state_specs=state_specs)


def build_optimizer(train_cfg, model_cfg) -> Optimizer:
    lr_fn = cosine_schedule(train_cfg.learning_rate, train_cfg.warmup_steps,
                            train_cfg.total_steps)
    if train_cfg.optimizer == "adafactor":
        return adafactor(lr_fn, weight_decay=train_cfg.weight_decay)
    return adamw(lr_fn, b1=train_cfg.b1, b2=train_cfg.b2, eps=train_cfg.eps,
                 weight_decay=train_cfg.weight_decay,
                 moment_dtype=model_cfg.moment_dtype)
