"""Multi-tenant `PoolGroup` — many pools, one dispatch.

A serving host protects many small model/cache pools at once.  Running
them as N independent `Pool`s costs N compiled-program dispatches per
commit wave and N copies of every compiled program; this module layers
a tenancy plane over the Pool facade that collapses both:

  * **Cohorts.** Tenants whose (state signature x specs x config) match
    share one `Cohort`: ONE `Protector` (so one zone layout and one
    `_jit_cache` — commit/scrub/recovery programs compile once for the
    whole cohort, the `protector=` kwarg Pool grew for exactly this)
    and, for deferred engines, one shared engine jit dict.
  * **Batched commit programs.** A commit wave over a cohort's tenants
    runs ONE jitted program: the per-tenant rows are stacked *inside*
    the traced computation, the fused verify/commit kernels dispatch
    once over the (T·n_blocks, block_words) page grid (`kernels.ops`
    `_tb` wrappers — per-block kernels, so the reshape is bit-exact),
    and the r-syndrome collectives of all T tenants fold into a single
    (T·r)-row batched all-to-all.  Per-tenant verdicts, redo-log
    appends and `ProtectedState`s come back out, bit-identical to T
    sequential `pool.commit` calls (tests/test_tenancy.py pins this
    across engines and redundancies) — N tenants cost one dispatch
    instead of N.
  * **Shared scrub scheduler** (`tenancy/scheduler.py`): verification
    pressure round-robins across tenants under a global page budget,
    weighted by QoS class, starvation-free.
  * **Admission control.** `capacity` bounds the tenant count; at
    capacity, `admit` either refuses or evicts the least-recently
    committed tenant (flush-before-evict: the victim's open window
    lands and its final state is returned to the caller).
  * **Quarantined recovery.** `group.recover(tid, fault)` quarantines
    only the faulted tenant — the rest of the group keeps committing
    (quarantined tenants are excluded from batched rosters and their
    updates are rejected) — runs the tenant's own recovery, and lifts
    the quarantine on success.  A failed recovery (budget exhausted)
    leaves the tenant quarantined.

Scope of the batched fast path: the bulk engines only — synchronous
bulk commits (no `dirty_pages`) and bulk deferred steps/flushes, on
parity/checksum modes.  Patch commits (static dirty footprints), modes
without parity+checksums, tenants with arrival hooks, and every rare
operation (scrub, precheck, recover, rescale, inject) route through
the tenant's own `Pool` — which shares the cohort `Protector`, so even
the looped paths compile once per cohort.  The batched programs use
the flat `_tb` kernels regardless of row size (the streamed variants
are bit-identical per kernels/ops.py, so verdicts and bytes still
match a streaming single pool).

Telemetry: the group owns one `MetricsRegistry` and one `Tracer`; each
tenant's Pool publishes through `registry.labeled(tenant=tid)`, so
every pool metric rides a `tenant=` Prometheus label and a tenant's
own view filters to its slice.  Group-level events (admit / evict /
quarantine) land in the shared trace with tenant ids attached.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProtectConfig
from repro.core import checksum as ck
from repro.core import gf
from repro.core import layout as layout_mod
from repro.core import redolog
from repro.core.epoch import EpochState
from repro.core.pipeline import CommitRing, CommitTicket
from repro.core.txn import ProtectedState, Protector, tree_select
from repro.dist import collectives as coll
from repro.kernels import ops as kops
from repro.obs import health as obs_health
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.pool import Fault, Pool, _is_abstract
from repro.tenancy.qos import QoSClass
from repro.tenancy.scheduler import ScrubScheduler

PyTree = Any
U32 = jnp.uint32


def _spec_leaf(x):
    return isinstance(x, P)


def cohort_key(abstract_state: PyTree, state_specs: PyTree,
               config: ProtectConfig, data_axis: str) -> tuple:
    """Tenants sharing this key share a Protector and commit programs:
    same leaf shapes/dtypes + treedef, same sharding, same config —
    exactly the inputs that determine a zone layout and its programs."""
    leaves, treedef = jax.tree.flatten(abstract_state)
    sig = tuple((tuple(l.shape), str(jnp.dtype(l.dtype))) for l in leaves)
    specs = tuple(str(s) for s in jax.tree.leaves(
        state_specs, is_leaf=_spec_leaf))
    return (treedef, sig, specs, config, data_axis)


@dataclasses.dataclass
class TenantHandle:
    """The group's per-tenant record.  `pool` is a full `Pool` (sharing
    its cohort's Protector) — every single-tenant operation is available
    on it directly; the group only owns batching, scheduling, admission
    and quarantine."""
    tenant_id: str
    pool: Pool
    cohort: "Cohort"
    qos: Optional[QoSClass]
    weight: int
    last_used: int = 0


class Cohort:
    """Same-shape x same-config tenants: one Protector, batched programs."""

    def __init__(self, mesh, abstract_state: PyTree, state_specs: PyTree,
                 config: ProtectConfig, *, data_axis: str = "data",
                 name: str = "c0"):
        self.name = name
        self.config = config
        mode = config.resolved_mode
        self.protector = Protector(
            mesh, abstract_state, state_specs, data_axis=data_axis,
            mode=mode, redundancy=config.resolved_redundancy,
            block_words=config.block_words,
            hybrid_threshold=config.hybrid_threshold,
            log_capacity=config.log_capacity,
            stream_threshold_words=config.stream_threshold_words,
            stream_chunk_words=config.stream_chunk_words)
        self.members: Dict[str, Pool] = {}     # insertion order = roster
        self._cache: dict = {}                 # batched program cache
        # deferred engines of this cohort share one jit dict: their
        # step/flush closures only read protector-derived statics, which
        # are identical cohort-wide, so the first engine to compile
        # serves them all.  `donate` is the one per-engine flag baked
        # into those programs — the first member pins it and a mismatch
        # opts out of sharing (engine_donate below).
        self.engine_jit: dict = {}
        self.engine_donate: Optional[bool] = None

    # -- batching eligibility ---------------------------------------------

    def batchable(self, pool: Pool) -> bool:
        mode = self.protector.mode
        if not (mode.has_parity or mode.has_cksums):
            return False
        if pool._arrival_fn is not None:       # chaos hook: loop path
            return False
        if pool.engine is not None and pool.engine.patch:
            return False                       # patch engines: loop path
        return True

    # -- batched synchronous commit ---------------------------------------

    def _sync_program(self, t: int, verify_old: bool):
        """One compiled commit for T tenants (synchronous engines).

        Mirrors `Protector.make_commit`'s bulk path with a leading
        tenant axis: stack rows, dispatch the fused kernels once over
        (T·nb, bw), fold all T syndrome stacks into one (T·r)-row
        collective, select per tenant on its own verdict.  The canary
        verdicts ride in as a traced (T,) vector, exactly like the
        single program's traced `canary_ok` scalar — one compiled
        program serves every abort pattern.
        """
        key = ("sync", t, verify_old)
        if key in self._cache:
            return self._cache[key]
        p = self.protector
        lo, ax, mode, r = p.layout, p.data_axis, p.mode, p.redundancy
        bw, nb, seg = lo.block_words, lo.n_blocks, lo.seg_words
        cc = p.coll_chunks()
        z = p._zone_spec
        n_axes = p.n_axes

        def _protect(row_caches, synds, cksums, digests, states_old,
                     states_new, canary_ok):
            coeffs = (gf.rank_syndrome_coeffs(p.group_size, r, ax)
                      if r > 1 else None)
            # with verify_old the old rows re-flatten from the live
            # states (a scribble lives in the state; a clean cache
            # would launder it) — exactly the single program's choice
            rows_old = jnp.stack([
                layout_mod.flatten_row(lo, s) if verify_old
                else p._unpack(rc)
                for s, rc in zip(states_old, row_caches)])     # (T, rw)
            rows_new = jnp.stack([layout_mod.flatten_row(lo, s)
                                  for s in states_new])        # (T, rw)
            dig_l = jnp.stack([p._unpack(d) for d in digests])  # (T, 2)
            synd_l = (jnp.stack([p._unpack(s) for s in synds])
                      if mode.has_parity else None)        # (T, r, seg)
            cks_l = (jnp.stack([p._unpack(c) for c in cksums])
                     if mode.has_cksums else None)         # (T, nb, 2)
            pages_new = rows_new.reshape(t, nb, bw)
            ok = canary_ok                                     # (T,)
            new_synd, new_cks = synd_l, cks_l
            if verify_old and mode.has_cksums:
                sdelta, fresh, bad = kops.fused_verify_commit_s_tb(
                    rows_old.reshape(t, nb, bw), pages_new, cks_l,
                    coeffs)
                # per-tenant _zone_clean: pmin over the data axis is
                # elementwise on the (T,) verdict vector
                ok = jnp.logical_and(
                    ok, jnp.logical_not(jnp.any(bad, axis=1)))
                ok = lax.pmin(ok.astype(jnp.int32), ax) > 0
                if mode.has_parity:
                    # T syndrome stacks fold into ONE (T·r)-row batched
                    # all-to-all — each row rides independently, so the
                    # fold is bit-identical to T separate collectives
                    new_synd = coll.syndrome_apply_delta(
                        synd_l.reshape(t * r, seg),
                        sdelta.reshape(t * r, -1), ax,
                        chunks=cc).reshape(t, r, seg)
            else:
                fresh = kops.fletcher_blocks_tb(pages_new)
                if mode.has_parity:
                    # rebuild-from-new as apply-onto-zeros: XOR is
                    # exact/associative, so 0 ^ rs(weighted new rows)
                    # equals build_syndromes(row_new) bit-for-bit
                    sdelta = kops.syndrome_scale_tb(rows_new, coeffs)
                    new_synd = coll.syndrome_apply_delta(
                        jnp.zeros((t * r, seg), U32),
                        sdelta.reshape(t * r, -1), ax,
                        chunks=cc).reshape(t, r, seg)
            if mode.has_cksums:
                new_cks = fresh
            new_dig = jax.vmap(lambda c: ck.combine(c, bw))(fresh)
            outs = {"ok": ok,
                    "row": p._pack(jnp.where(ok[:, None], rows_new,
                                             rows_old)),
                    "digest": p._pack(jnp.where(ok[:, None], new_dig,
                                                dig_l))}
            if mode.has_parity:
                outs["synd"] = p._pack(jnp.where(ok[:, None, None],
                                                 new_synd, synd_l))
            if mode.has_cksums:
                outs["cksums"] = p._pack(jnp.where(ok[:, None, None],
                                                   new_cks, cks_l))
            return outs

        out_specs = {"ok": P(), "row": z, "digest": z}
        if mode.has_parity:
            out_specs["synd"] = z
        if mode.has_cksums:
            out_specs["cksums"] = z
        protect = p._smap(
            _protect,
            in_specs=((z,) * t, (z,) * t, (z,) * t, (z,) * t,
                      (p.state_specs,) * t, (p.state_specs,) * t, P()),
            out_specs=out_specs)

        def commit_b(prots, states_new, data_cursors, rng_keys,
                     canaries):
            canaries = jnp.asarray(canaries, bool)
            outs = protect(tuple(pr.row for pr in prots),
                           tuple(pr.synd for pr in prots),
                           tuple(pr.cksums for pr in prots),
                           tuple(pr.digest for pr in prots),
                           tuple(pr.state for pr in prots),
                           tuple(states_new), canaries)
            ok_all = outs["ok"]                            # (T,)
            new_prots, oks = [], []
            for i, pr in enumerate(prots):
                ok = ok_all[i]
                oks.append(ok)
                step = pr.step + U32(1)

                def sl(name, _i=i):
                    return lax.index_in_dim(outs[name], _i, axis=n_axes,
                                            keepdims=False)

                new_digest = sl("digest")
                log = pr.log
                if mode.has_log:
                    rk = rng_keys[i]
                    if rk is None:
                        rk = jax.random.PRNGKey(0)
                    log = redolog.append(pr.log, step, data_cursors[i],
                                         rk, new_digest.reshape(-1, 2)[0])
                    log = tree_select(ok, redolog.commit_mark(log, step),
                                      log)
                new_prots.append(ProtectedState(
                    state=tree_select(ok, states_new[i], pr.state),
                    synd=sl("synd") if mode.has_parity else pr.synd,
                    cksums=sl("cksums") if mode.has_cksums else pr.cksums,
                    digest=new_digest, replica=pr.replica, log=log,
                    step=jnp.where(ok, step, pr.step), row=sl("row")))
            # per-tenant ok scalars split INSIDE the program: indexing
            # the (T,) verdict on the host would dispatch one eager
            # gather per tenant — pure host overhead per wave
            return tuple(new_prots), tuple(oks)

        self._cache[key] = jax.jit(commit_b, donate_argnums=(0,))
        return self._cache[key]

    def commit_sync(self, items: list, *, verify_old: bool = False) -> dict:
        """Batched commit for synchronous-engine tenants.

        `items`: [(tid, state_new, canary_ok, data_cursor, rng_key)] in
        roster order.  Returns {tid: device ok}.  Canary-aborted tenants
        still get their redo record appended (mark unset) and their
        state untouched — exactly the single program's abort semantics.
        """
        t0 = time.perf_counter()
        tids = [it[0] for it in items]
        pools = [self.members[tid] for tid in tids]
        canaries = tuple(bool(it[2]) for it in items)
        prog = self._sync_program(len(items), bool(verify_old))
        new_prots, oks = prog(
            tuple(pool._prot for pool in pools),
            tuple(it[1] for it in items),
            tuple(it[3] for it in items),
            tuple(it[4] for it in items),
            np.asarray(canaries, bool))
        wall_ms = (time.perf_counter() - t0) * 1e3
        out = {}
        for i, (tid, pool) in enumerate(zip(tids, pools)):
            pool._prot = new_prots[i]
            self._bookkeep(pool, canaries[i], wall_ms / len(items))
            out[tid] = oks[i]
        return out

    # -- batched deferred step + flush -------------------------------------

    def _step_program(self, t: int, canaries: tuple):
        """One compiled in-window step for T bulk deferred tenants.

        Mirrors `DeferredProtector.make_step_commit`'s bulk branch with
        a leading tenant axis; canary-aborted tenants are compiled as
        pure pass-throughs (the single engine's static no-op), so only
        the live tenants ride the stacked kernel.
        """
        key = ("step", t, canaries)
        if key in self._cache:
            return self._cache[key]
        p = self.protector
        lo, ax, mode = p.layout, p.data_axis, p.mode
        bw, nb = lo.block_words, lo.n_blocks
        z = p._zone_spec
        n_axes = p.n_axes
        live = tuple(i for i in range(t) if canaries[i])
        tl = len(live)

        def _step(accs, row_caches, states_new):
            rows_new = jnp.stack([layout_mod.flatten_row(lo, s)
                                  for s in states_new])        # (Tl, rw)
            old_v = jnp.stack([p._unpack(rc)
                               for rc in row_caches]).reshape(tl, nb, bw)
            acc_v = jnp.stack([p._unpack(a)
                               for a in accs]).reshape(tl, nb, bw)
            acc_v, _, new_ck = kops.fused_accum_commit_tb(
                acc_v, old_v, rows_new.reshape(tl, nb, bw))
            new_dig = jax.vmap(lambda c: ck.combine(c, bw))(new_ck)
            outs = {"row": p._pack(rows_new),
                    "acc": p._pack(acc_v.reshape(tl, -1)),
                    "digest": p._pack(new_dig)}
            if mode.has_cksums:
                outs["cksums"] = p._pack(new_ck)
            return outs

        out_specs = {"row": z, "acc": z, "digest": z}
        if mode.has_cksums:
            out_specs["cksums"] = z
        protect = p._smap(
            _step,
            in_specs=((z,) * tl, (z,) * tl, (p.state_specs,) * tl),
            out_specs=out_specs)

        def step_b(prots, pendings, accs, states_new, data_cursors,
                   rng_keys):
            outs = (protect(tuple(accs[i] for i in live),
                            tuple(prots[i].row for i in live),
                            tuple(states_new[i] for i in live))
                    if live else None)
            new = []
            for i in range(t):
                pr = prots[i]
                if not canaries[i]:
                    new.append((pr, pendings[i], accs[i],
                                jnp.zeros((), bool)))
                    continue
                j = live.index(i)

                def sl(name, _j=j):
                    return lax.index_in_dim(outs[name], _j, axis=n_axes,
                                            keepdims=False)

                step = pr.step + U32(1)
                new_digest = sl("digest")
                log = pr.log
                if mode.has_log:
                    rk = rng_keys[i]
                    if rk is None:
                        rk = jax.random.PRNGKey(0)
                    # deferred ordering: the record persists per step
                    # and is marked unconditionally (canary aborts were
                    # short-circuited statically above)
                    log = redolog.append(pr.log, step, data_cursors[i],
                                         rk, new_digest.reshape(-1, 2)[0])
                    log = redolog.commit_mark(log, step)
                new_prot = ProtectedState(
                    state=states_new[i], synd=pr.synd,
                    cksums=sl("cksums") if mode.has_cksums else pr.cksums,
                    digest=new_digest, replica=pr.replica, log=log,
                    step=step, row=sl("row"))
                new.append((new_prot, pendings[i] + U32(1), sl("acc"),
                            jnp.ones((), bool)))
            prots_o, pend_o, accs_o, oks = zip(*new)
            return tuple(prots_o), tuple(pend_o), tuple(accs_o), \
                tuple(oks)

        self._cache[key] = jax.jit(step_b, donate_argnums=(0, 1, 2))
        return self._cache[key]

    def _flush_program(self, tf: int):
        """One compiled epoch flush for Tf bulk deferred tenants: all
        accumulators weight into their syndrome stacks through one
        (Tf·r)-row batched collective (`make_flush`'s bulk branch)."""
        key = ("flush", tf)
        if key in self._cache:
            return self._cache[key]
        p = self.protector
        lo, ax, mode, r = p.layout, p.data_axis, p.mode, p.redundancy
        seg = lo.seg_words
        cc = p.coll_chunks()
        z = p._zone_spec
        n_axes = p.n_axes

        def _flush(synds, accs):
            acc_l = jnp.stack([p._unpack(a) for a in accs])    # (Tf, rw)
            outs = {"acc": p._pack(jnp.zeros_like(acc_l))}
            if mode.has_parity:
                coeffs = (gf.rank_syndrome_coeffs(p.group_size, r, ax)
                          if r > 1 else None)
                synd_l = jnp.stack([p._unpack(s) for s in synds])
                sdelta = kops.syndrome_scale_tb(acc_l, coeffs)
                outs["synd"] = p._pack(coll.syndrome_apply_delta(
                    synd_l.reshape(tf * r, seg),
                    sdelta.reshape(tf * r, -1), ax,
                    chunks=cc).reshape(tf, r, seg))
            return outs

        out_specs = {"acc": z}
        if mode.has_parity:
            out_specs["synd"] = z
        fn = p._smap(_flush, in_specs=((z,) * tf, (z,) * tf),
                     out_specs=out_specs)

        def flush_b(prots, accs):
            outs = fn(tuple(pr.synd for pr in prots), tuple(accs))
            new_prots, new_accs = [], []
            for i, pr in enumerate(prots):

                def sl(name, _i=i):
                    return lax.index_in_dim(outs[name], _i, axis=n_axes,
                                            keepdims=False)

                new_prots.append(dataclasses.replace(
                    pr, synd=sl("synd") if mode.has_parity else pr.synd))
                new_accs.append(sl("acc"))
            return tuple(new_prots), tuple(new_accs)

        self._cache[key] = jax.jit(flush_b, donate_argnums=(0, 1))
        return self._cache[key]

    def commit_deferred(self, items: list) -> dict:
        """Batched commit for bulk deferred-engine tenants.

        One stacked step program, then ONE stacked flush over exactly
        the tenants whose windows came due — per-tenant host
        bookkeeping (`_since`, adaptive window, flush metrics, meta
        mirror, scrub cadence) mirrors `DeferredProtector.commit` +
        `Pool.commit` in their exact order.
        """
        t0 = time.perf_counter()
        tids = [it[0] for it in items]
        pools = [self.members[tid] for tid in tids]
        canaries = tuple(bool(it[2]) for it in items)
        prog = self._step_program(len(items), canaries)
        ests = [pool._est for pool in pools]
        prots, pendings, accs, oks = prog(
            tuple(e.prot for e in ests),
            tuple(e.pending for e in ests),
            tuple(e.acc for e in ests),
            tuple(it[1] for it in items),
            tuple(it[3] for it in items),
            tuple(it[4] for it in items))
        due = []
        for i, pool in enumerate(pools):
            pool._est = EpochState(prot=prots[i], dirty=None,
                                   pending=pendings[i], acc=accs[i])
            # the host cadence counts every commit — aborts included —
            # exactly like DeferredProtector.commit's unconditional
            # `_since += 1`
            eng = pool.engine
            eng._since += 1
            if eng._since >= eng.window:
                due.append(i)
        if due:
            fprog = self._flush_program(len(due))
            d_ests = [pools[i]._est for i in due]
            f_prots, f_accs = fprog(tuple(e.prot for e in d_ests),
                                    tuple(e.acc for e in d_ests))
            for j, i in enumerate(due):
                eng = pools[i].engine
                pending = eng._since
                eng._since = 0
                if eng.metrics is not None:
                    eng.metrics.counter("pool_window_flush_total").inc()
                    eng.metrics.histogram(
                        "pool_flush_pending").observe(pending)
                pools[i]._est = EpochState(
                    prot=f_prots[j], dirty=None,
                    pending=jnp.zeros((), U32), acc=f_accs[j])
        wall_ms = (time.perf_counter() - t0) * 1e3
        out = {}
        for i, (tid, pool) in enumerate(zip(tids, pools)):
            if pool.engine.replicate_meta:
                pool.engine._mirror_meta(pool._est)
            self._bookkeep(pool, canaries[i], wall_ms / len(items))
            out[tid] = oks[i]
        return out

    # -- shared per-tenant post-commit bookkeeping -------------------------

    @staticmethod
    def _bookkeep(pool: Pool, canary_ok: bool, wall_ms: float) -> None:
        """`Pool.commit`'s host bookkeeping, in its exact order."""
        pool.scrubber.on_commit(clean=bool(canary_ok))
        pool._m_commits.inc()
        if not canary_ok:
            pool._m_aborted.inc()
        pool._m_commit_ms.observe(wall_ms)


class PoolGroup:
    """The multi-tenant front door: admit / commit / scrub_tick /
    recover / evict / rescale over a fleet of cohort-sharing pools."""

    def __init__(self, mesh, *, capacity: int = 0,
                 evict_on_full: bool = True, data_axis: str = "data",
                 scrub_page_budget: int = 0, full_scrub_every: int = 4,
                 pipeline_depth: int = 1,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        assert capacity >= 0, capacity
        self.mesh = mesh
        self.capacity = int(capacity)          # 0 = unbounded
        self.evict_on_full = bool(evict_on_full)
        self.data_axis = data_axis
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        # wave pipeline: `commit_async` dispatches whole commit waves
        # through this ring, one ticket per wave (the group-level
        # analogue of Pool's commit ring)
        self.pipeline_depth = int(pipeline_depth)
        self._ring = CommitRing(
            self.pipeline_depth,
            on_depth=self.metrics.gauge("group_inflight_waves").set)
        self._ticket_seq = 0
        self.scheduler = ScrubScheduler(page_budget=scrub_page_budget,
                                        full_every=full_scrub_every)
        self._cohorts: Dict[tuple, Cohort] = {}
        self._tenants: Dict[str, TenantHandle] = {}
        self._quarantined: set = set()
        self._clock = 0
        self._m_admit = self.metrics.counter("group_admissions_total")
        self._m_evict = self.metrics.counter("group_evictions_total")
        self._m_batches = self.metrics.counter(
            "group_commit_batches_total")
        self._m_rejected = self.metrics.counter(
            "group_commit_rejected_total")

    # -- membership --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, tid) -> bool:
        return tid in self._tenants

    def __getitem__(self, tid) -> TenantHandle:
        return self._tenants[tid]

    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenants)

    @property
    def quarantined(self) -> Tuple[str, ...]:
        return tuple(sorted(self._quarantined))

    @property
    def cohorts(self) -> Tuple[Cohort, ...]:
        return tuple(self._cohorts.values())

    def admit(self, tid: str, state: PyTree, specs: PyTree, *,
              config: Optional[ProtectConfig] = None,
              qos: Optional[QoSClass] = None,
              weight: Optional[int] = None, **open_kw) -> TenantHandle:
        """Admit a tenant (the multi-tenant `pgl_open`).

        `state` may be concrete or a ShapeDtypeStruct pytree (a cold
        tenant — call `handle.pool.init(state)` later).  The protection
        config comes from `config`, else the QoS class, else defaults;
        the QoS weight feeds the scrub scheduler.  At capacity the
        least-recently-committed tenant is evicted (flush-before-evict)
        when `evict_on_full`, otherwise admission raises.
        """
        if tid in self._tenants:
            raise ValueError(f"tenant {tid!r} already admitted")
        if self.capacity and len(self._tenants) >= self.capacity:
            if not self.evict_on_full:
                raise RuntimeError(
                    f"group at capacity ({self.capacity} tenants) and "
                    "evict_on_full=False — evict explicitly or raise "
                    "capacity")
            victims = [t for t in self._tenants
                       if t not in self._quarantined]
            if not victims:
                raise RuntimeError(
                    "group at capacity with every tenant quarantined — "
                    "nothing is safely evictable")
            self.evict(min(victims,
                           key=lambda t: self._tenants[t].last_used))
        if config is None:
            config = (qos.config if qos is not None else ProtectConfig())
        abstract = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
        key = cohort_key(abstract, specs, config, self.data_axis)
        cohort = self._cohorts.get(key)
        if cohort is None:
            cohort = Cohort(self.mesh, abstract, specs, config,
                            data_axis=self.data_axis,
                            name=f"c{len(self._cohorts)}")
            self._cohorts[key] = cohort
        pool = Pool(self.mesh, abstract, specs, config,
                    data_axis=self.data_axis,
                    metrics=self.metrics.labeled(tenant=str(tid)),
                    tracer=self.tracer,
                    protector=cohort.protector, **open_kw)
        if pool.engine is not None:
            if cohort.engine_donate is None:
                cohort.engine_donate = pool.engine.donate
            if pool.engine.donate == cohort.engine_donate:
                pool.engine._jit = cohort.engine_jit
        if not _is_abstract(state):
            pool.init(state)
        cohort.members[tid] = pool
        w = int(weight if weight is not None
                else (qos.weight if qos is not None else 1))
        handle = TenantHandle(tenant_id=tid, pool=pool, cohort=cohort,
                              qos=qos, weight=w)
        self._tenants[tid] = handle
        self.scheduler.register(tid, pool, weight=w)
        self._clock += 1
        handle.last_used = self._clock
        self._m_admit.inc()
        self.metrics.gauge("group_tenants").set(len(self._tenants))
        self.tracer.emit("tenant_admit", tenant=str(tid),
                         cohort=cohort.name,
                         qos=qos.name if qos is not None else None)
        return handle

    def evict(self, tid: str) -> PyTree:
        """Remove a tenant, flushing its open window first; returns its
        final (redundancy-current) state for the caller to persist."""
        handle = self._tenants.pop(tid)
        handle.pool.flush()                    # flush-before-evict
        state = handle.pool.state
        del handle.cohort.members[tid]
        self.scheduler.unregister(tid)
        self._quarantined.discard(tid)
        self._m_evict.inc()
        self.metrics.gauge("group_tenants").set(len(self._tenants))
        self.tracer.emit("tenant_evict", tenant=str(tid))
        return state

    # -- commit ------------------------------------------------------------

    def commit(self, updates: Dict[str, PyTree], *,
               canary_ok=True, data_cursor=0, rng_keys=None,
               batched: bool = True, verify_old: bool = False) -> dict:
        """Commit a wave of per-tenant updates; returns {tid: verdict}.

        Tenants are grouped by cohort; each cohort's batchable members
        commit through ONE stacked program (sync or deferred by the
        cohort's window), the rest loop through their own `pool.commit`
        — verdicts and bytes are identical either way (`batched=False`
        forces the loop, which is the benchmark baseline).  `canary_ok`
        is a bool or a {tid: bool} dict; quarantined tenants' updates
        are rejected with a host `False` verdict.
        """
        self._clock += 1
        rng_keys = rng_keys or {}
        out: dict = {}

        def canary(tid):
            return (canary_ok.get(tid, True)
                    if isinstance(canary_ok, dict) else canary_ok)

        for tid in updates:
            if tid not in self._tenants:
                raise KeyError(f"unknown tenant {tid!r}")
            if tid in self._quarantined:
                out[tid] = False
                self._m_rejected.inc()
            else:
                self._tenants[tid].last_used = self._clock
        for cohort in self._cohorts.values():
            items, loop = [], []
            for tid, pool in cohort.members.items():
                if tid not in updates or tid in self._quarantined:
                    continue
                it = (tid, updates[tid], canary(tid), data_cursor,
                      rng_keys.get(tid))
                if batched and cohort.batchable(pool):
                    items.append(it)
                else:
                    loop.append(it)
            if len(items) == 1:
                loop += items
                items = []
            if items:
                self._m_batches.inc()
                if cohort.config.window > 1:
                    out.update(cohort.commit_deferred(items))
                else:
                    out.update(cohort.commit_sync(
                        items, verify_old=verify_old))
            for tid, state_new, can, dc, rk in loop:
                pool = cohort.members[tid]
                # verify_old is a synchronous-engine feature; Pool.commit
                # asserts on it for deferred pools
                vkw = ({"verify_old": verify_old}
                       if pool.engine is None else {})
                out[tid] = pool.commit(
                    state_new, canary_ok=can, data_cursor=dc,
                    rng_key=rk, **vkw)
        return out

    def commit_async(self, updates: Dict[str, PyTree], *,
                     extras: Optional[dict] = None,
                     **kw) -> CommitTicket:
        """Dispatch a commit wave through the group's ring: one
        `CommitTicket` per wave, whose verdict is the AND of every
        tenant's device verdict (`kernels.ops.stage_verdict`) and whose
        `extras["verdicts"]` carries the per-tenant {tid: verdict} map
        — each still lazily fetchable on its own.  Up to
        `pipeline_depth` waves stay in flight; `drain()` is the
        deterministic boundary (recovery and eviction resolve per-pool
        state, so tenant operations never race a wave — the batched
        programs already updated host-side prots at dispatch)."""
        t0 = time.perf_counter()
        verdicts = self.commit(updates, **kw)
        ok = kops.stage_verdict(
            [jnp.asarray(v, bool) for v in verdicts.values()])
        seq = self._ticket_seq
        self._ticket_seq += 1
        span = self.tracer.emit("wave_dispatch", seq=seq,
                                tenants=len(verdicts))
        ex = {"verdicts": verdicts}
        if extras:
            ex.update(extras)
        return self._ring.submit(CommitTicket(
            seq, ok, dispatched_at=t0, span_id=span, extras=ex,
            on_resolve=self._on_wave_resolved))

    def _on_wave_resolved(self, ticket: CommitTicket) -> None:
        lat = ticket.resolve_latency_ms
        if lat is not None:
            self.metrics.histogram("group_wave_resolve_ms").observe(
                lat, exemplar=ticket.span_id)

    def poll(self) -> list:
        """Resolve any waves whose verdicts already landed."""
        return self._ring.poll()

    def drain(self) -> list:
        """Resolve every in-flight wave (dispatch order)."""
        return self._ring.drain()

    # -- scrub / recover / rescale ----------------------------------------

    def scrub_tick(self, page_budget: Optional[int] = None) -> list:
        """One shared-scheduler pass: serve scrub/precheck pressure by
        QoS-weighted commit age under the global page budget."""
        return self.scheduler.tick(page_budget)

    def recover(self, tid: str, fault: Fault, **kw):
        """Quarantined recovery: only the faulted tenant stops taking
        commits; the rest of the group keeps going.  Re-raises the
        tenant's recovery error (budget exhausted) with the tenant left
        quarantined; lifts the quarantine on success."""
        handle = self._tenants[tid]
        self._quarantined.add(tid)
        self.scheduler.set_quarantined(tid, True)
        self.metrics.counter("group_quarantines_total").inc()
        self.tracer.emit("tenant_quarantine", tenant=str(tid),
                         fault_kind=fault.kind)
        rep = handle.pool.recover(fault, **kw)
        self._quarantined.discard(tid)
        self.scheduler.set_quarantined(tid, False)
        self.tracer.emit("tenant_unquarantine", tenant=str(tid))
        return rep

    def release(self, tid: str) -> None:
        """Lift a quarantine manually (after an out-of-band repair,
        e.g. `handle.pool.init` re-arm following a budget exhaust)."""
        self._quarantined.discard(tid)
        self.scheduler.set_quarantined(tid, False)

    def rescale(self, new_mesh) -> "PoolGroup":
        """Move every tenant to `new_mesh`; returns the new group.

        Tenants re-admit into fresh cohorts built for the new zone
        geometry and each pool reshards through `Pool.rescale` (flush →
        bit-exact reshard → re-protect).  The metric registry and trace
        are shared, so tenant labels survive the move."""
        self.drain()                   # waves never survive a rescale
        new = PoolGroup(
            new_mesh, capacity=self.capacity,
            evict_on_full=self.evict_on_full, data_axis=self.data_axis,
            scrub_page_budget=self.scheduler.page_budget,
            full_scrub_every=self.scheduler.full_every,
            pipeline_depth=self.pipeline_depth,
            metrics=self.metrics, tracer=self.tracer)
        for tid, handle in self._tenants.items():
            cold = new.admit(tid, handle.pool.abstract_state,
                             handle.pool.state_specs,
                             config=handle.pool.config, qos=handle.qos,
                             weight=handle.weight)
            handle.pool.rescale(new_mesh, into=cold.pool)
        return new

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        return {
            "tenants": len(self._tenants),
            "cohorts": {c.name: sorted(c.members)
                        for c in self._cohorts.values()},
            "quarantined": sorted(self._quarantined),
            "scheduler": self.scheduler.stats(),
            "per_tenant": {tid: h.pool.stats()
                           for tid, h in self._tenants.items()},
        }

    def health(self) -> dict:
        """Worst-of aggregation over tenant health, plus per-tenant
        reports: a group is only as healthy as its sickest tenant (a
        quarantined tenant is at least degraded)."""
        rank = {obs_health.GREEN: 0, obs_health.DEGRADED: 1,
                obs_health.CRITICAL: 2}
        per = {tid: h.pool.health()
               for tid, h in self._tenants.items()}
        worst = obs_health.GREEN
        for tid, rep in per.items():
            status = rep.status
            if tid in self._quarantined and rank[status] < 1:
                status = obs_health.DEGRADED
            if rank[status] > rank[worst]:
                worst = status
        return {"status": worst, "per_tenant": per,
                "quarantined": sorted(self._quarantined)}
