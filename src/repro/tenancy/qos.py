"""Per-tenant QoS classes (repro.tenancy).

A QoS class is nothing more than a named `ProtectConfig` plus a scrub
weight: the protection ladder (mode / redundancy / window) IS the
quality dial this library already has, so mapping tenants to service
levels means mapping them to configs.  Because `PoolGroup` keys its
cohorts by (state signature x config), tenants of the same class and
shape land in the same cohort and share one compiled commit program —
the QoS class doubles as the batching key.

The presets span the ladder the paper evaluates:

  * GOLD   — synchronous mlpc, r=3: every commit refreshes checksums
    and a 3-row syndrome stack (survives 3 simultaneous rank losses);
    scrub weight 4, so the shared scheduler verifies gold pools ~4x as
    eagerly per committed transaction.
  * SILVER — mlpc, r=2 behind a 4-commit deferred window; weight 2.
  * BRONZE — mlpc, r=1 behind an 8-commit window; weight 1 — the
    cheapest protected tier (single XOR parity, redundancy refresh
    amortized over 8 commits, last in line for scrub pressure).

`QoSClass.configure(**overrides)` derives a variant (e.g. a scrub
cadence or streaming threshold tweak) without leaving the class's tier.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ProtectConfig


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """A named protection tier: the config tenants of this class get,
    plus the weight the shared scrub scheduler gives their pressure."""
    name: str
    config: ProtectConfig
    weight: int = 1

    def __post_init__(self):
        if self.weight < 1:
            raise ValueError(
                f"QoSClass.weight={self.weight} — the scrub scheduler "
                "multiplies commit age by this, so it must be >= 1 "
                "(larger = served sooner)")

    def configure(self, **overrides) -> "QoSClass":
        """Same tier, adjusted config knobs (dataclasses.replace)."""
        return dataclasses.replace(
            self, config=dataclasses.replace(self.config, **overrides))


GOLD = QoSClass("gold", ProtectConfig(mode="mlpc", redundancy=3,
                                      window=1), weight=4)
SILVER = QoSClass("silver", ProtectConfig(mode="mlpc", redundancy=2,
                                          window=4), weight=2)
BRONZE = QoSClass("bronze", ProtectConfig(mode="mlpc", redundancy=1,
                                          window=8), weight=1)

PRESETS = {q.name: q for q in (GOLD, SILVER, BRONZE)}
