"""Multi-tenant plane over the Pool facade.

`PoolGroup` hosts many protected pools at once: same-shape same-config
tenants share one `Cohort` (one Protector, one jit cache) and commit
through batched compiled programs — N tenants per dispatch instead of
N dispatches — while a shared `ScrubScheduler` spreads verification
pressure across tenants under a global page budget and `QoSClass`
presets map tenants onto the protection ladder.  See group.py for the
full design notes.
"""
from repro.tenancy.group import (Cohort, PoolGroup, TenantHandle,
                                 cohort_key)
from repro.tenancy.qos import BRONZE, GOLD, PRESETS, SILVER, QoSClass
from repro.tenancy.scheduler import ScrubScheduler

__all__ = [
    "PoolGroup", "TenantHandle", "Cohort", "cohort_key",
    "QoSClass", "GOLD", "SILVER", "BRONZE", "PRESETS",
    "ScrubScheduler",
]
