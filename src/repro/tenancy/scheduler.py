"""Shared scrub scheduler: one verification budget across many pools.

A PoolGroup hosts N tenants, but scrub bandwidth is a *shared* resource
— every pass reads a pool's worth of pages.  Running each pool's own
cadence independently lets a chatty tenant starve the others of
verification (or, with a naive global cadence, lets an idle tenant eat
passes the busy ones need).  This scheduler round-robins the pressure:

  * Each tick spends at most `page_budget` pages (0 = unlimited: every
    tenant with pending pressure is served), each tenant at most once
    per tick.  A pass over tenant t costs `scrubber.pool_pages` — the
    exact coverage accounting the Scrubber already keeps.
  * Tenants are served in priority order.  Priority is
    `commits_since_check * weight + ticks_waiting`: commit age scaled
    by the tenant's QoS weight, plus one point per tick spent unserved.
    The additive aging term makes the policy starvation-free by
    construction — an idle bronze tenant's priority still grows every
    tick, so its wait is bounded no matter how hot its neighbors run
    (age * weight alone would let a never-committing tenant wait
    forever).
  * Every `full_every`-th serve of a tenant is a FULL scrub
    (syndrome collectives + repair path); the others are the cheap
    rank-local pre-check.  A suspect pre-check escalates to a full
    scrub immediately (budget permitting) — mirroring
    `Pool.maybe_scrub`'s escalation.  Together with the bounded wait
    this bounds every tenant's *full-scrub age*: at most
    `full_every - 1` prechecks (each within a bounded wait) separate
    consecutive full scrubs, so `commits_since_full` cannot grow
    unboundedly for any registered tenant.

The scheduler reads exactly three things off each pool's Scrubber —
`commits_since_check`, `commits_since_full`, `pool_pages` — and calls
`pool.precheck()` / `pool.scrub()`; it never touches engine internals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class _Entry:
    pool: object                 # repro.pool.Pool
    weight: int = 1
    ticks_waiting: int = 0       # ticks since last served (aging term)
    serves: int = 0              # lifetime passes served
    quarantined: bool = False    # excluded from scheduling


class ScrubScheduler:
    def __init__(self, *, page_budget: int = 0, full_every: int = 4):
        assert page_budget >= 0, page_budget
        assert full_every >= 1, full_every
        self.page_budget = int(page_budget)
        self.full_every = int(full_every)
        self._tenants: dict = {}          # tid -> _Entry (insertion order)
        self.ticks = 0
        self.pages_spent = 0              # lifetime page cost
        self.passes = 0                   # lifetime serves (all kinds)

    # -- membership --------------------------------------------------------

    def register(self, tid, pool, weight: int = 1) -> None:
        assert tid not in self._tenants, f"tenant {tid!r} already registered"
        assert weight >= 1, weight
        self._tenants[tid] = _Entry(pool=pool, weight=int(weight))

    def unregister(self, tid) -> None:
        self._tenants.pop(tid, None)

    def set_quarantined(self, tid, flag: bool) -> None:
        if tid in self._tenants:
            self._tenants[tid].quarantined = bool(flag)

    # -- introspection -----------------------------------------------------

    def priority(self, tid) -> int:
        e = self._tenants[tid]
        return (e.pool.scrubber.commits_since_check * e.weight
                + e.ticks_waiting)

    def max_check_age(self) -> int:
        """Largest commits-since-any-verification across tenants."""
        return max((e.pool.scrubber.commits_since_check
                    for e in self._tenants.values()), default=0)

    def max_full_age(self) -> int:
        """Largest commits-since-full-scrub across tenants — the bound
        the starvation-freedom argument is about."""
        return max((e.pool.scrubber.commits_since_full
                    for e in self._tenants.values()), default=0)

    def stats(self) -> dict:
        return {"tenants": len(self._tenants), "ticks": self.ticks,
                "passes": self.passes, "pages_spent": self.pages_spent,
                "max_check_age": self.max_check_age(),
                "max_full_age": self.max_full_age()}

    # -- the tick ----------------------------------------------------------

    def tick(self, page_budget: Optional[int] = None) -> list:
        """Serve scrub passes by priority until the page budget is spent.

        Returns [(tid, kind, report)] for the passes run this tick
        (kind in {"precheck", "full"}); an escalated suspect pre-check
        contributes two entries for the same tenant.
        """
        budget = self.page_budget if page_budget is None else int(page_budget)
        self.ticks += 1
        served = []
        spent = 0
        # snapshot the candidate order once; each tenant served <= once
        remaining = [tid for tid, e in self._tenants.items()
                     if not e.quarantined]
        while remaining:
            tid = max(remaining, key=self.priority)
            e = self._tenants[tid]
            cost = e.pool.scrubber.pool_pages
            if budget and spent + cost > budget:
                break
            remaining.remove(tid)
            e.serves += 1
            e.ticks_waiting = 0
            spent += cost
            # full-scrub cadence: the full_every-th serve pays for the
            # global collectives; the rest run the rank-local pre-check
            if e.serves % self.full_every == 0:
                served.append((tid, "full", e.pool.scrub()))
            else:
                report = e.pool.precheck()
                served.append((tid, "precheck", report))
                if report.suspect and (not budget
                                       or spent + cost <= budget):
                    # escalation: a suspect pre-check buys the full
                    # scrub (and its repair path) right away
                    spent += cost
                    served.append((tid, "full", e.pool.scrub()))
        # aging: everyone not served this tick moves up the queue
        served_tids = {tid for tid, _, _ in served}
        for tid, e in self._tenants.items():
            if tid not in served_tids and not e.quarantined:
                e.ticks_waiting += 1
        self.passes += len(served)
        self.pages_spent += spent
        return served
