"""qwen2-0.5b [dense] — 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias, tied embeddings.  [arXiv:2407.10671; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=56, n_heads=14, n_kv=2, d_ff=128,
        vocab=512, head_dim=4,
        param_dtype="float32", compute_dtype="float32")
