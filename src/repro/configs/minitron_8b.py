"""minitron-8b [dense] — width/depth-pruned Nemotron: 32L d=4096 32H
(GQA kv=8) d_ff=16384 vocab=256000.  [arXiv:2407.14679; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=16384,
    vocab=256000,
    head_dim=128,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32")
