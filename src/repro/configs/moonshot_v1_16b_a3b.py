"""moonshot-v1-16b-a3b [moe] — 48L d=2048 16H (kv=16, i.e. MHA) d_ff=1408
per expert, vocab=163840, MoE 64 experts top-6 + shared expert
(kimi/moonlight family).  [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=163840,
    head_dim=128,
    rope_theta=50000.0,
    moe=MoESpec(num_experts=64, top_k=6, d_expert=1408, interleave=1,
                shared_expert=True, capacity_factor=1.25),
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=96,
        vocab=512, head_dim=16,
        moe=MoESpec(num_experts=8, top_k=2, d_expert=96, interleave=1,
                    shared_expert=True, capacity_factor=2.0),
        param_dtype="float32", compute_dtype="float32")
