"""xlstm-1.3b [ssm] — 48 blocks, d=2048, 4 heads, vocab=50304, d_ff=0
(projections live inside the blocks): xLSTM[7:1] — 7 mLSTM (matrix
memory, chunkwise-parallel training, O(1) decode) per 1 sLSTM (scalar
memory with true state-mixing recurrence).  [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    subquadratic=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=4, vocab=512,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        param_dtype="float32", compute_dtype="float32")
