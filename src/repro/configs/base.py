"""Config system: model architecture, training, mesh, protection.

Every assigned architecture gets a `src/repro/configs/<id>.py` exporting
`CONFIG` (the exact published configuration) and `reduced()` (a small
same-family variant for CPU smoke tests).  `repro.configs.registry` resolves
`--arch <id>` strings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    interleave: int = 1           # 1 = every layer MoE; 2 = alternate dense/MoE
    shared_expert: bool = False   # llama4-style always-on shared expert
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # default d_model // n_heads
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    act: str = "silu"                      # GLU activation
    moe: Optional[MoESpec] = None
    # layer pattern for hybrid/ssm families; None = homogeneous decoder
    block_pattern: Optional[Tuple[str, ...]] = None   # e.g. ("rglru","rglru","attn")
    window: Optional[int] = None           # sliding-window attention size
    enc_layers: int = 0                    # >0 => encoder-decoder
    mm_positions: int = 0                  # frontend stub embedding positions
    subquadratic: bool = False             # True => long_500k runnable
    # numerics
    param_dtype: str = "float32"           # master/param dtype
    compute_dtype: str = "bfloat16"
    moment_dtype: Optional[str] = None     # Adam m/v dtype; None = param_dtype
    logical_overrides: dict = dataclasses.field(default_factory=dict)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        if self.moe is not None and self.moe.interleave == 2:
            return ("dense", "moe")
        if self.moe is not None:
            return ("moe",)
        return ("dense",)

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        from repro.models import api
        return api.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import api
        return api.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class Workload:
    """One input-shape cell from the assignment."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


WORKLOADS = {
    "train_4k": Workload("train_4k", "train", 4096, 256),
    "prefill_32k": Workload("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Workload("decode_32k", "decode", 32768, 128),
    "long_500k": Workload("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1             # gradient accumulation
    remat: bool = True
    optimizer: str = "adamw"          # adamw | adafactor
    z_loss: float = 1e-4
    grad_compression: bool = False    # int8 all-reduce with error feedback


_PROTECT_MODES = ("none", "ml", "mlp", "mlpc", "replica", "mlp2", "mlpc2")


@dataclasses.dataclass(frozen=True)
class ProtectConfig:
    mode: str = "mlpc"                # none | ml | mlp | mlpc | replica
                                      # (mlp2/mlpc2 = legacy dual-parity
                                      # aliases for redundancy=2)
    block_words: int = 1024
    hybrid_threshold: float = 0.5
    scrub_period: int = 0             # transactions between scrubs; 0 = off
    log_capacity: int = 64
    overlap_commit: bool = False      # dispatch step t+1 before awaiting
                                      # epoch t's protection program
    pipeline_depth: int = 1           # async commit ring: up to this many
                                      # commits stay in flight with
                                      # unresolved verdicts (commit t+k
                                      # dispatches before t resolves);
                                      # 1 = resolve-per-commit.  The
                                      # runtimes fold overlap_commit into
                                      # an effective depth >= 2
    window: int = 1                   # deferred-epoch window W; 1 = the
                                      # synchronous per-commit engine
    redundancy: int = 1               # syndrome stack height r (1..4) =
                                      # simultaneous rank losses survived:
                                      # S_0 = XOR parity P, S_1 = GF(2^32)
                                      # Q, S_2/S_3 = higher Vandermonde
                                      # rows (any e <= r losses solve)
    window_growth_commits: int = 32   # consecutive clean commits before a
                                      # shrunken adaptive window regrows
                                      # under load (0 = grow on clean
                                      # scrubs only)
    full_scrub_every: int = 1         # 1 = every due scrub is global; N>1
                                      # runs the rank-local syndrome
                                      # pre-check on due scrubs and pays
                                      # for the global collective only
                                      # every Nth (or when the pre-check
                                      # flags the pool suspect)
    stream_threshold_words: int = 1 << 20
                                      # local rows at least this many u32
                                      # words take the blockwise
                                      # double-buffered streaming commit
                                      # kernels; smaller rows keep the
                                      # flat whole-grid sweep.  0 = flat
                                      # always (streaming disabled)
    stream_chunk_words: int = 1 << 16
                                      # words per streamed VMEM chunk
                                      # (256 KB at u32); each operand
                                      # stages 2 chunks for the DMA
                                      # double buffer
    straggler_threshold: float = 0.0  # > 0 wires dist/straggler.py's
                                      # StragglerPolicy into the pool
                                      # commit loop: replicas whose mean
                                      # step time exceeds threshold x the
                                      # fleet median are dropped from the
                                      # loss and the adaptive window
                                      # collapses while any replica is
                                      # degraded.  0 = disabled

    @property
    def resolved_mode(self):
        """The effective base protection Mode (aliases folded: mlp2 ->
        MLP).  This is the single source of truth together with
        `resolved_redundancy`; `core.txn.resolved_mode` is the resolver."""
        from repro.core.txn import resolved_mode
        return resolved_mode(self.mode, self.redundancy)[0]

    @property
    def resolved_redundancy(self) -> int:
        """The effective syndrome stack height (aliases folded: mlp2 ->
        max(redundancy, 2))."""
        from repro.core.txn import resolved_mode
        return resolved_mode(self.mode, self.redundancy)[1]

    def __post_init__(self):
        if self.mode not in _PROTECT_MODES:
            raise ValueError(
                f"ProtectConfig.mode={self.mode!r} is not a protection "
                f"level; pick one of {', '.join(_PROTECT_MODES)} "
                "(Table 2 ladder: none < ml < mlp < mlpc; replica = 2x "
                "storage baseline)")
        if self.window < 1:
            raise ValueError(
                f"ProtectConfig.window={self.window} — the deferred-epoch "
                "window counts commits per redundancy refresh, so it must "
                "be >= 1 (1 = synchronous per-commit protection)")
        if self.scrub_period < 0:
            raise ValueError(
                f"ProtectConfig.scrub_period={self.scrub_period} — use 0 "
                "to disable scrubbing or a positive transaction count "
                "between scrubs")
        # single source of truth for the stack-height bound (core.txn
        # enforces the same limit inside resolved_mode); imported lazily
        # so building a config never drags jax in before XLA flags land
        from repro.core.txn import MAX_REDUNDANCY
        if not 1 <= self.redundancy <= MAX_REDUNDANCY:
            raise ValueError(
                f"ProtectConfig.redundancy={self.redundancy} — the "
                f"syndrome stack holds 1 to {MAX_REDUNDANCY} rows "
                "(1 = XOR parity P, 2 adds the GF(2^32) Q row, higher "
                "values add higher Vandermonde rows); note it must also "
                "stay <= num_ranks - 1 on the zone, which the Protector "
                "checks against the mesh")
        if self.redundancy > 1 and self.mode not in ("mlp", "mlpc",
                                                     "mlp2", "mlpc2"):
            raise ValueError(
                f"ProtectConfig.redundancy={self.redundancy} with "
                f"mode={self.mode!r} — extra syndromes extend parity, so "
                "redundancy>1 requires a parity mode (mlp or mlpc)")
        if self.window > 1 and self.mode in ("none", "ml", "replica"):
            raise ValueError(
                f"ProtectConfig.window={self.window} with "
                f"mode={self.mode!r} — the deferred-epoch window batches "
                "parity/checksum refreshes, which this mode does not "
                "maintain; use a parity/checksum mode (mlp or mlpc) or "
                "window=1")
        if self.pipeline_depth < 1:
            raise ValueError(
                f"ProtectConfig.pipeline_depth={self.pipeline_depth} — "
                "the async commit ring holds at least one in-flight "
                "commit (1 = resolve every verdict before the next "
                "dispatch; larger depths pipeline dispatches ahead of "
                "resolution)")
        if self.window_growth_commits < 0:
            raise ValueError(
                f"ProtectConfig.window_growth_commits="
                f"{self.window_growth_commits} — use 0 to regrow the "
                "adaptive window on clean scrubs only, or a positive "
                "count of consecutive clean commits")
        if self.full_scrub_every < 1:
            raise ValueError(
                f"ProtectConfig.full_scrub_every={self.full_scrub_every} "
                "— 1 makes every due scrub global; N > 1 runs the cheap "
                "rank-local pre-check and goes global every Nth scrub "
                "(or as soon as the pre-check flags corruption)")
        if self.block_words < 1:
            raise ValueError(
                f"ProtectConfig.block_words={self.block_words} — the "
                "page-column unit must be a positive word count "
                "(paper default: 1024 words = 4 KB pages)")
        if not 0.0 <= self.hybrid_threshold <= 1.0:
            raise ValueError(
                f"ProtectConfig.hybrid_threshold={self.hybrid_threshold} "
                "— the patch/bulk crossover is a dirty-page *fraction* "
                "and must lie in [0, 1]")
        if self.log_capacity < 1:
            raise ValueError(
                f"ProtectConfig.log_capacity={self.log_capacity} — the "
                "redo log needs at least one record slot")
        if self.stream_threshold_words < 0:
            raise ValueError(
                f"ProtectConfig.stream_threshold_words="
                f"{self.stream_threshold_words} — rows at least this many "
                "words stream through the blockwise commit kernels; use 0 "
                "to disable streaming (flat kernels always)")
        if self.stream_chunk_words < 1:
            raise ValueError(
                f"ProtectConfig.stream_chunk_words="
                f"{self.stream_chunk_words} — the streamed VMEM chunk "
                "needs a positive word count (it is clamped to at least "
                "one block_words page per chunk)")
        if self.straggler_threshold < 0:
            raise ValueError(
                f"ProtectConfig.straggler_threshold="
                f"{self.straggler_threshold} — replicas are dropped past "
                "threshold x the fleet-median step time, so the knob must "
                "be a positive ratio (sensible values are >= 1.5; 0 "
                "disables straggler mitigation)")


def workload_skips(cfg: ModelConfig, wl: Workload) -> Optional[str]:
    """Reason string if this (arch, workload) cell is skipped, else None."""
    if wl.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention architecture: 524k-token decode requires "
                "sub-quadratic attention (see DESIGN.md §4)")
    return None
