"""qwen3-0.6b [dense] — 28L d=1024 16H (GQA kv=8) d_ff=3072 vocab=151936,
qk-norm (per-head RMS norm on q/k), head_dim=128, tied embeddings.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    d_ff=3072,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32")
