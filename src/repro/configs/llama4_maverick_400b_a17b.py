"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (GQA kv=8) vocab=202048,
MoE 128 experts top-1, alternating dense/MoE layers + shared expert (the
interleave that lands at ~400B total / ~17B active), early-fusion
multimodal stub.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Numerics: bf16 params + bf16 Adam moments — at 400B parameters a full-f32
optimizer (16 B/param = 6.4 TB) exceeds a 256-chip v5e pod's 4 TB HBM;
bf16 policy (8 B/param = 3.2 TB) fits with room for activations.  The
replica protection mode is *infeasible* at this scale (2x state), which is
exactly the paper's storage argument; parity mode costs 1/G.
"""
from repro.configs.base import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    rope_theta=500000.0,
    moe=MoESpec(num_experts=128, top_k=1, d_expert=8192, interleave=2,
                shared_expert=True, capacity_factor=1.25),
    mm_positions=256,            # early-fusion image-patch stub positions
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    moment_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16, mm_positions=4,
        moe=MoESpec(num_experts=4, top_k=1, d_expert=128, interleave=2,
                    shared_expert=True, capacity_factor=2.0),
        param_dtype="float32", compute_dtype="float32",
        moment_dtype=None)
