"""Architecture registry: resolve --arch <id> strings."""
from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "minitron-8b": "minitron_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "glm4-9b": "glm4_9b",
    "qwen3-0.6b": "qwen3_0_6b",
    "chameleon-34b": "chameleon_34b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
}


def list_archs() -> list:
    return sorted(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str, reduced: bool = False):
    mod = _module(arch)
    return mod.reduced() if reduced else mod.CONFIG
