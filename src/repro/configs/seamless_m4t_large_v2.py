"""seamless-m4t-large-v2 [audio] — encoder-decoder backbone: 24L encoder +
24L decoder, d=1024, 16H MHA, d_ff=8192, vocab=256206.  The speech frontend
(fbank conformer adaptor) is a stub per the assignment: `input_specs`
provides precomputed frame embeddings (B, S, d).  [arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,              # decoder layers
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32")
