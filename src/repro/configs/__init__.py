from repro.configs.base import (  # noqa: F401
    ModelConfig, MoESpec, ProtectConfig, TrainConfig, Workload, WORKLOADS,
    workload_skips)
from repro.configs.registry import get_config, list_archs  # noqa: F401
