"""chameleon-34b [vlm] — early-fusion: 48L d=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536 (text + VQ image codes share the vocabulary);
qk-norm for stability as in the release.  The VQ tokenizer is a stub:
`input_specs` provides precomputed patch-embedding positions in addition
to the discrete token stream.  [arXiv:2405.09818; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=22016,
    vocab=65536,
    head_dim=128,
    qk_norm=True,
    mm_positions=256,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16, mm_positions=4,
        param_dtype="float32", compute_dtype="float32")
