"""glm4-9b [dense] — 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE.  kv=2 is the extreme-GQA case: the KV cache cannot shard its 2 heads
over a 16-way model axis, so the cache shards its sequence dimension
instead (`seq_shard` rule).  [hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=2,
    d_ff=13696,
    vocab=151552,
    head_dim=128,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
        vocab=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32")
