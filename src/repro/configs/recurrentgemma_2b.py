"""recurrentgemma-2b [hybrid] — Griffin: 26 blocks in a 2:1
RG-LRU : local-attention pattern, d=2560, 10H (MQA kv=1, head_dim=256),
d_ff=7680, vocab=256000, attention window 2048.  O(1) recurrent state +
windowed KV make the 524k decode cell runnable.  [arXiv:2402.19427; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048,
    tie_embeddings=True,   # Griffin/RG releases share input/output embeddings
    subquadratic=True,
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv=1, d_ff=128,
        vocab=512, head_dim=16, window=16,
        param_dtype="float32", compute_dtype="float32")
