"""repro — Pangolin-JAX: fault-tolerant protection of distributed training/serving state.

A JAX/TPU adaptation of "Pangolin: A Fault-Tolerant Persistent Memory
Programming Library" (Zhang & Swanson, 2019).  See DESIGN.md for the
NVMM -> multi-pod-HBM mapping.

Public surface (the pgl analogue — see repro/pool.py for the mapping):

    from repro import Pool, Fault, ProtectConfig

    pool = Pool.open(state, specs, mesh=mesh,
                     config=ProtectConfig(mode="mlpc"))
    with pool.transaction() as tx:
        tx.stage(new_state)
    pool.recover(Fault.rank_loss(2))

`Protector` / `DeferredProtector` remain importable as the low-level
engine layer; everything above them should go through `Pool`.
"""

__version__ = "0.1.0"

from repro import compat as _compat  # noqa: E402,F401  (jax API shims)

__all__ = ["Pool", "Fault", "Transaction", "ProtectConfig", "Mode",
           "Protector", "DeferredProtector", "ProtectedState",
           "MetricsRegistry", "Tracer", "HealthReport"]

# Lazy re-exports (PEP 562): `python -m repro.launch.*` imports this
# package before the launchers set XLA_FLAGS, and several core modules
# create device scalars at import time — eager re-exports here would
# lock the backend's device count before --host-devices applies.
_EXPORTS = {
    "ProtectConfig": ("repro.configs.base", "ProtectConfig"),
    "DeferredProtector": ("repro.core.epoch", "DeferredProtector"),
    "Mode": ("repro.core.txn", "Mode"),
    "ProtectedState": ("repro.core.txn", "ProtectedState"),
    "Protector": ("repro.core.txn", "Protector"),
    "Fault": ("repro.pool", "Fault"),
    "Pool": ("repro.pool", "Pool"),
    "Transaction": ("repro.pool", "Transaction"),
    # telemetry plane (repro.obs is jax-free, but Pool re-exports pull
    # in the full stack, so these stay lazy with the rest)
    "MetricsRegistry": ("repro.obs.metrics", "MetricsRegistry"),
    "Tracer": ("repro.obs.trace", "Tracer"),
    "HealthReport": ("repro.obs.health", "HealthReport"),
}


def __getattr__(name):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value        # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
