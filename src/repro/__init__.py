"""repro — Pangolin-JAX: fault-tolerant protection of distributed training/serving state.

A JAX/TPU adaptation of "Pangolin: A Fault-Tolerant Persistent Memory
Programming Library" (Zhang & Swanson, 2019).  See DESIGN.md for the
NVMM -> multi-pod-HBM mapping.
"""

__version__ = "0.1.0"

from repro import compat as _compat  # noqa: E402,F401  (jax API shims)
