from repro.data.synthetic import SyntheticStream, batch_for  # noqa: F401
