"""Deterministic, resumable synthetic data pipeline.

Crash recovery (Pangolin §3.6) requires replaying logged steps *exactly*:
the redo log stores a `data_cursor`, and the pipeline must regenerate the
identical batch for any cursor — so batches are a pure function of
(seed, cursor).  This mirrors a production deterministic input pipeline
(e.g. Grain index sampling); the token content is a mixed Markov/Zipf
stream so losses move, which is all the benchmarks need.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mm_positions: int = 0
    d_model: int = 0              # for mm/src embed stubs
    enc_dec: bool = False

    def batch_at(self, cursor: int) -> dict:
        """Pure function of (seed, cursor) -> host numpy batch."""
        rng = np.random.default_rng((self.seed << 32) ^ cursor)
        n_tok = self.seq_len - self.mm_positions
        # Zipf-ish marginal with a cursor-dependent shift so content varies
        ranks = rng.zipf(1.3, size=(self.global_batch, n_tok))
        tokens = (ranks + cursor) % self.vocab
        batch = {"tokens": tokens.astype(np.int32)}
        if self.mm_positions:
            batch["mm_embeds"] = rng.standard_normal(
                (self.global_batch, self.mm_positions, self.d_model)
            ).astype(np.float32) * 0.02
        if self.enc_dec:
            batch["src_embeds"] = rng.standard_normal(
                (self.global_batch, self.seq_len, self.d_model)
            ).astype(np.float32) * 0.02
        return batch

    def device_batch(self, cursor: int, shardings: Optional[dict] = None
                     ) -> dict:
        batch = self.batch_at(cursor)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}


def batch_for(cfg, seq_len: int, global_batch: int, seed: int = 0
              ) -> SyntheticStream:
    return SyntheticStream(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed, mm_positions=cfg.mm_positions, d_model=cfg.d_model,
        enc_dec=cfg.enc_layers > 0)
