"""Straggler mitigation for synchronous data parallelism.

Synchronous zones move at the pace of their slowest replica.  The policy
tracks per-replica step durations (host-side, a sliding window) and drops
replicas whose mean exceeds `threshold` x the fleet median — bounded by
`max_drop_fraction` so a mass slowdown (network event, thermal) never
silently shrinks the batch below a floor.  Dropped replicas keep running;
their loss contribution is masked so the gradient stays an average over
healthy replicas only.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict

import numpy as np


class StragglerPolicy:
    def __init__(self, n_replicas: int, threshold: float = 2.0,
                 max_drop_fraction: float = 0.25, window: int = 32):
        assert n_replicas > 0 and threshold > 0
        self.n_replicas = n_replicas
        self.threshold = threshold
        self.max_drop_fraction = max_drop_fraction
        self.window = window
        self._times: Dict[int, Deque[float]] = {
            r: collections.deque(maxlen=window) for r in range(n_replicas)}

    def observe(self, replica: int, duration_s: float) -> None:
        self._times[int(replica)].append(float(duration_s))

    def _means(self) -> np.ndarray:
        return np.asarray([
            np.mean(self._times[r]) if self._times[r] else 0.0
            for r in range(self.n_replicas)])

    def replica_mask(self) -> np.ndarray:
        """(n_replicas,) bool; True = replica participates."""
        means = self._means()
        observed = means > 0
        mask = np.ones(self.n_replicas, bool)
        if not observed.any():
            return mask
        median = float(np.median(means[observed]))
        slow = observed & (means > self.threshold * max(median, 1e-12))
        budget = int(self.max_drop_fraction * self.n_replicas)
        if budget <= 0 or not slow.any():
            return mask
        # drop the slowest first, never more than the budget
        victims = sorted(np.flatnonzero(slow),
                         key=lambda r: (-means[r], r))[:budget]
        mask[list(victims)] = False
        return mask

    def loss_mask(self, global_batch: int) -> np.ndarray:
        """(global_batch,) f32 0/1 mask zeroing dropped replicas' examples.

        The batch is laid out replica-major (replica r owns the contiguous
        slice [r*B/G, (r+1)*B/G)), matching the data-axis sharding.
        """
        per = max(global_batch // self.n_replicas, 1)
        mask = np.repeat(self.replica_mask().astype(np.float32), per)
        if mask.shape[0] < global_batch:     # remainder examples always count
            mask = np.concatenate(
                [mask, np.ones(global_batch - mask.shape[0], np.float32)])
        return mask[:global_batch]
