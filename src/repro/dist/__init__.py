"""Distributed substrate: XOR collectives, sharding rules, elasticity.

  collectives — the XOR algebra Pangolin's parity scheme runs on, realized
                as mesh collectives (reduce-scatter / all-reduce / gather).
  sharding    — logical-axis -> PartitionSpec rules with divisibility
                fallback, shared by models, optimizer state and caches.
  elastic     — cross-mesh resharding + protection rebuild (zone geometry
                depends on the data-axis size G).
  straggler   — replica drop policy for synchronous data parallelism.
"""
