"""Elastic rescale: move protected state between meshes.

Zone geometry is a function of the data-axis size G (row padding, parity
segment length, page->owner mapping, and — under redundancy=2 — Q's
Vandermonde coefficients), so protection cannot move with the state —
exactly as Pangolin rebuilds parity when chunk-row geometry changes.
The flow is:

    state' = reshard_state(prot.state, new_mesh, new_specs)   # bit-exact
    prot'  = new_protector.init(state')                       # rebuild

`reshard_state` round-trips through host memory, which works across
arbitrary mesh shape changes (including device-count changes that XLA's
device-to-device resharding cannot express).

The public entry point is `Pool.rescale(new_mesh)` (repro/pool.py),
which adds flush-before-rescale and the host step-counter carry on top
of `reshard_state`; `rescale` / `rescale_windowed` below are the
low-level engine forms it mirrors.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def _is_spec(x) -> bool:
    return isinstance(x, P)


def reshard_state(state: PyTree, new_mesh, new_specs: PyTree) -> PyTree:
    """Re-shard a state pytree onto a new mesh (bit-exact, via host)."""
    def _move(x, spec):
        host = np.asarray(jax.device_get(x))
        return jax.device_put(host, NamedSharding(new_mesh, spec))
    return jax.tree.map(_move, state, new_specs, is_leaf=_is_spec)


def rescale(protector, prot, make_protector: Callable, new_mesh):
    """Move a protected job to `new_mesh`; returns (protector', prot').

    `make_protector(new_mesh)` builds the Protector for the new geometry
    (same abstract state / mode, new mesh).  Parity, checksums, digest and
    the cached row are rebuilt from the resharded state; the step counter
    carries over as a host value so no device array leaks across meshes.
    """
    p_new = make_protector(new_mesh)
    state = reshard_state(prot.state, new_mesh, p_new.state_specs)
    prot_new = p_new.init(state)
    step = int(jax.device_get(prot.step))
    return p_new, dataclasses.replace(
        prot_new, step=jnp.asarray(step, jnp.uint32))


def rescale_windowed(engine, est, make_protector: Callable, new_mesh):
    """`rescale` for a deferred-epoch engine: flush-before-rescale.

    A pending window means parity/checksums (and Q) describe the
    epoch-start state; resharding mid-window would rebuild redundancy
    from a state the old geometry's log still had in flight.  The flush
    lands the window first, then the move rebuilds P — and, in
    redundancy=2 modes, Q with the *new* zone's Vandermonde coefficients
    (the g^i weights depend on the data-axis size G, so Q can never move
    with the state either).  Returns (protector', prot').
    """
    est = engine.flush_if_pending(est)
    return rescale(engine.p, est.prot, make_protector, new_mesh)
